"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Mapping to the paper:

* bench_ff_timing   — Tables 1, 5, 10 (ff time, DENSE vs DYAD variants) and
                      §3.4.3 (the -CAT variant)
* bench_quality     — Tables 2, 3 (quality parity; offline stand-in stream)
* bench_memory      — Table 11 (params / checkpoint / in-training memory)
* bench_width_sweep — Figure 6 (speedup vs model width)
* bench_mnist       — §3.4.5 (vision probe on CPU)
* bench_serve_throughput — beyond-paper: end-to-end serving tokens/sec
                      (single-pass prefill + scan decode vs the seed loops)

Roofline terms (EXPERIMENTS §Roofline) come from the dry-run
(``python -m repro.launch.dryrun``), which needs the 512-device env and is
therefore not run from here.
"""
from __future__ import annotations

import os
import sys
import time

# allow `python benchmarks/run.py` from the repo root (the documented form):
# the `benchmarks` package lives next to this file's parent directory.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import (bench_ff_timing, bench_memory, bench_mnist,
                            bench_quality, bench_serve_throughput,
                            bench_width_sweep)

    suites = {
        "ff_timing": bench_ff_timing.run,
        "quality": bench_quality.run,
        "memory": bench_memory.run,
        "width_sweep": bench_width_sweep.run,
        "mnist": bench_mnist.run,
        "serve_throughput": bench_serve_throughput.run,
    }
    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        suites[name]()
        print(f"# suite {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
