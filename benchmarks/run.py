"""Benchmark harness — one registered suite per paper table/figure.

Each suite prints ``name,us_per_call,derived`` CSV (the seed contract) and
writes a machine-readable ``BENCH_<suite>.json`` at the repo root — the
performance trajectory that ``python -m repro.perf.check`` gates against
the last committed baseline.  Mapping to the paper:

* ff_timing        — Tables 1, 5, 10 (ff time, DENSE vs DYAD variants),
                     §3.4.3 (-CAT), plus the fused-kernel autotune cells
* ff_fused         — beyond-paper: the whole-ff megakernel (one Pallas
                     grid, hidden never leaves VMEM) vs the split kernel
                     chain vs DENSE at OPT-125m/350m ff dims
* attention        — beyond-paper: flash prefill/decode kernels vs the
                     XLA sdpa paths at OPT dims (4k/32k), decode-step
                     latency for both serve engines
* quality          — Tables 2, 3 (quality parity; offline stand-in stream)
* memory           — Table 11 (params / checkpoint / in-training memory)
* width_sweep      — Figure 6 (speedup vs model width)
* mnist            — §3.4.5 (vision probe on CPU)
* quant            — beyond-paper: int8/fp8 quantized weight streams
                     (in-kernel dequant) vs the fp megakernel at a
                     decode-shaped batch, int8 paged-KV capacity, and
                     end-to-end greedy token match vs the fp routes
* serve_throughput — beyond-paper: end-to-end serving tokens/sec
* train_step       — §1 headline (training speed): full fwd+bwd+AdamW step
                     on DYAD vs DENSE ff blocks, einsum-VJP vs fused bwd
* smoke            — tiny CI suite (< 1 min): dense-vs-dyad ff + train-step
                     cells plus an autotune cache exercise

Roofline terms (EXPERIMENTS §Roofline) come from the dry-run
(``python -m repro.launch.dryrun``), which needs the 512-device env and is
therefore not run from here; per-record FLOP/byte counts are attached by
the suites via ``repro.perf.record.hlo_metrics``.

    python benchmarks/run.py --suite ff_timing
    python benchmarks/run.py                       # every suite
    python -m repro.perf.check                     # gate vs committed JSON
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# allow `python benchmarks/run.py` from the repo root (the documented form):
# the `benchmarks` package lives next to this file's parent directory.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)


def main(argv=None) -> int:
    from repro import obs
    from repro.perf import registry

    # importing the suite modules registers them (repro.perf.register)
    from benchmarks import (bench_attention, bench_ff_fused,  # noqa: F401
                            bench_ff_timing, bench_memory, bench_mnist,
                            bench_quality, bench_quant,
                            bench_serve_throughput, bench_smoke,
                            bench_tp_scaling, bench_train_step,
                            bench_width_sweep)

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--suite", action="append", default=None,
                   help="suite to run (repeatable; default: all)")
    p.add_argument("--out-dir", default=_ROOT,
                   help="where BENCH_<suite>.json is written "
                        "(default: repo root)")
    p.add_argument("--no-json", action="store_true",
                   help="print CSV only, skip BENCH_<suite>.json")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="record suite/autotune spans while benchmarking and "
                        "export Chrome-trace JSON here (diff two runs with "
                        "python -m repro.perf.timeline)")
    p.add_argument("--list", action="store_true",
                   help="list registered suites and exit")
    p.add_argument("legacy_suites", nargs="*",
                   help="positional suite names (seed-compatible form)")
    args = p.parse_args(argv)

    if args.list:
        print("\n".join(registry.available_suites()))
        return 0

    if args.trace:
        obs.enable()

    wanted = (args.suite or []) + args.legacy_suites
    wanted = wanted or registry.available_suites()
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        with obs.span(f"suite:{name}", cat="bench"):
            rec = registry.run_suite(name, out_dir=args.out_dir,
                                     write=not args.no_json)
        note = "" if args.no_json else f" -> {rec.path}"
        print(f"# suite {name} done in {time.time() - t0:.1f}s"
              f" ({len(rec.results)} records){note}", file=sys.stderr)

    if args.trace:
        obs.export(args.trace)
        print(f"# trace -> {args.trace}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
