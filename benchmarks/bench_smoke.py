"""Tiny CI suite (< 1 min on a cold GitHub runner).

One dense-vs-DYAD ff cell with hlo_stats FLOP/byte counts (so the gate's
roofline columns are exercised end-to-end), an autotune sweep over a
deliberately small candidate space to keep the block cache and the
``BENCH_smoke.json`` trajectory alive in CI, ff-megakernel fused-vs-split
cells, and train-step fused-backward cells.  This is the suite the
``bench-smoke`` CI job runs and gates with ``python -m repro.perf.check``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import perf
from repro.core import dyad, linear
from repro.perf.autotune import autotune_dyad
from repro.perf.record import hlo_metrics

TOKENS = 256
D, FF = 256, 1024
KERNEL_SHAPE = (32, 2, 128, 128)      # (B, n_dyad, d_in, d_out) — tiny


@perf.register("smoke")
def run():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (TOKENS, D))
    spec = dyad.DyadSpec(n_dyad=4, variant="it")

    pd = {"up": linear.init(key, D, FF), "down": linear.init(key, FF, D)}
    pv = {"up": dyad.init(key, D, FF, spec),
          "down": dyad.init(key, FF, D, spec)}

    dense = jax.jit(lambda p, x: linear.apply(
        p["down"], jax.nn.relu(linear.apply(p["up"], x))))
    dy = jax.jit(lambda p, x: dyad.apply(
        p["down"], jax.nn.relu(dyad.apply(p["up"], x, spec)), spec))

    td = time_fn(dense, pd, x, iters=3)
    tv = time_fn(dy, pv, x, iters=3)
    roof_d = hlo_metrics(dense, pd, x)
    roof_y = hlo_metrics(dy, pv, x)
    emit("smoke_ff_dense_fwd", td, shape=(TOKENS, D, FF), ratio=1.00,
         **roof_d)
    emit("smoke_ff_dyad_it4_fwd", tv, shape=(TOKENS, D, FF),
         ratio=round(td / tv, 2), **roof_y)

    B, n, d_in, d_out = KERNEL_SHAPE
    blocks, us = autotune_dyad("dyad_mm_blocks", B, n, d_in, d_out,
                               iters=2, force=True)
    emit("smoke_kernel_autotune", us, shape=KERNEL_SHAPE, **blocks)

    # tiny ff-megakernel cells: one-grid fused vs the split kernel chain
    # (same op, route forced via REPRO_KERNEL_FF) so ff-fusion regressions
    # fail the bench-smoke CI gate.  Mirrors the ff_fused suite at smoke
    # dims.
    from benchmarks.common import force_ff_route
    from repro.kernels import ops as kops

    pf = {"up": dyad.init(key, D, FF, spec, bias=False),
          "down": dyad.init(key, FF, D, spec, bias=False)}
    t_route = {}
    for route in ("split", "fused"):
        with force_ff_route(route):
            f = jax.jit(lambda p, x: kops.dyad_ff(p, x, act="relu"))
            # median of 5: these two cells gate CI, damp scheduler outliers
            t_route[route] = time_fn(f, pf, x, iters=5)
    emit("smoke_ff_megakernel_fused", t_route["fused"], shape=(TOKENS, D, FF),
         fused_vs_split=round(t_route["split"] / t_route["fused"], 2))
    emit("smoke_ff_megakernel_split", t_route["split"], shape=(TOKENS, D, FF))

    # tiny quantized-ff cell: the int8 weight-stream megakernel
    # (in-kernel dequant) vs the fp megakernel above, same module —
    # numerical drift is pinned by tests/test_quant.py; this cell keeps
    # the quant route's dispatch + timing alive in the CI trajectory.
    from repro import obs, quant

    pq = quant.quantize_params(pf)
    obs.reset_route_counts()
    fq = jax.jit(lambda p, x: kops.dyad_ff_quant(p, x, act="relu"))
    t_q = time_fn(fq, pq, x, iters=5)
    emit("smoke_ff_megakernel_int8", t_q, shape=(TOKENS, D, FF),
         vs_fp_fused=round(t_route["fused"] / t_q, 2),
         weight_bytes_ratio=4.0)

    # tiny flash-attention cells: the Pallas prefill kernel vs the chunked
    # XLA fallback at smoke dims, so attention-kernel regressions fail the
    # bench-smoke CI gate.  Mirrors the attention suite's protocol.
    from repro.kernels import flash_attn as fa
    from repro.layers import attention as attn_lib

    S, K, G, h = 128, 2, 2, 32
    ks = jax.random.split(key, 3)
    aq = jax.random.normal(ks[0], (2, S, K, G, h))
    ak = jax.random.normal(ks[1], (2, S, K, h))
    av = jax.random.normal(ks[2], (2, S, K, h))
    qpos = jnp.arange(S)
    chunked = jax.jit(lambda q, k, v: attn_lib._chunked_sdpa(
        q, k, v, qpos, qpos, True, None, 64))
    flash = jax.jit(lambda q, k, v: fa.flash_prefill(
        q, k, v, causal=True, block_q=64, block_k=128, interpret=True)[0])
    t_x = time_fn(chunked, aq, ak, av, iters=5)
    t_f = time_fn(flash, aq, ak, av, iters=5)
    emit("smoke_attn_chunked", t_x, shape=(2, S, K * G, h))
    emit("smoke_attn_flash", t_f, shape=(2, S, K * G, h),
         flash_vs_chunked=round(t_x / t_f, 2))

    # tiny paged-decode cells: the block-table-gathered decode kernel vs the
    # dense ring decode kernel over the same logical K/V, so paged-gather
    # regressions fail the bench-smoke CI gate.
    Bd, L, P = 4, 128, 16
    nb = L // P
    dq = jax.random.normal(ks[0], (Bd, 1, K, G, h))
    dk = jax.random.normal(ks[1], (Bd, L, K, h))
    dv = jax.random.normal(ks[2], (Bd, L, K, h))
    pk = dk.reshape(Bd * nb, P, K, h)
    pk = jnp.concatenate([jnp.zeros_like(pk[:1]), pk])   # scratch page 0
    pv = dv.reshape(Bd * nb, P, K, h)
    pv = jnp.concatenate([jnp.zeros_like(pv[:1]), pv])
    bt = 1 + jnp.arange(Bd * nb, dtype=jnp.int32).reshape(Bd, nb)
    idx = jnp.full((Bd,), L - 1, jnp.int32)
    ring = jax.jit(lambda q, k, v: fa.flash_decode(
        q, k, v, idx, block_k=128, interpret=True))
    paged = jax.jit(lambda q, k, v, b: fa.flash_decode_paged(
        q, k, v, b, idx, block_k=128, interpret=True))
    t_r = time_fn(ring, dq, dk, dv, iters=5)
    t_p = time_fn(paged, dq, pk, pv, bt, iters=5)
    emit("smoke_decode_ring", t_r, shape=(Bd, L, K * G, h))
    emit("smoke_decode_paged", t_p, shape=(Bd, L, K * G, h),
         paged_vs_ring=round(t_r / t_p, 2))

    # tiny train-step record: fused backward vs the einsum-VJP oracle, so
    # backward regressions fail the bench-smoke CI gate.  Reuses the
    # train_step suite's step builder — same computation, smaller dims.
    from benchmarks.bench_train_step import dyad_ff_apply, make_adam_step

    sk = dyad.DyadSpec(n_dyad=4, variant="it", use_kernel=True)
    se = dyad.DyadSpec(n_dyad=4, variant="it", use_kernel=True,
                       use_kernel_bwd=False)
    pt = {"up": dyad.init(key, D, FF, sk), "down": dyad.init(key, FF, D, sk)}
    opt, step_fused = make_adam_step(dyad_ff_apply(sk))
    _, step_einsum = make_adam_step(dyad_ff_apply(se))
    state = (pt, opt.init(pt))
    t_fused = time_fn(step_fused, state, x, iters=3)
    t_einsum = time_fn(step_einsum, state, x, iters=3)
    emit("smoke_train_step_dyad_fused_bwd", t_fused, shape=(TOKENS, D, FF),
         vs_einsum_vjp=round(t_einsum / t_fused, 2))
    emit("smoke_train_step_dyad_einsum_vjp", t_einsum, shape=(TOKENS, D, FF))


if __name__ == "__main__":
    run()
