"""Paper §3.4.5 analog (MNIST probe): a 784->512->512->10 MLP classifier on
the synthetic-clusters task, DENSE vs DYAD-IT(4) — accuracy parity and
ff timing, on CPU exactly as the paper's probe ran on a Macbook CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro import perf
from repro.core import dyad, linear
from repro.data import SyntheticClassification

STEPS = 60


def _mlp_init(key, use_dyad):
    ks = jax.random.split(key, 3)
    spec = dyad.DyadSpec(n_dyad=4, variant="it")
    if use_dyad:
        return {
            "l1": dyad.init(ks[0], 784, 512, spec),
            "l2": dyad.init(ks[1], 512, 512, spec),
            "out": linear.init(ks[2], 512, 10),     # head stays dense
        }, spec
    return {
        "l1": linear.init(ks[0], 784, 512),
        "l2": linear.init(ks[1], 512, 512),
        "out": linear.init(ks[2], 512, 10),
    }, None


def _apply(p, x, spec):
    h = jax.nn.relu(dyad.apply(p["l1"], x, spec) if spec
                    else linear.apply(p["l1"], x))
    h = jax.nn.relu(dyad.apply(p["l2"], h, spec) if spec
                    else linear.apply(p["l2"], h))
    return linear.apply(p["out"], h)


def _train_eval(use_dyad):
    data = SyntheticClassification(n_classes=10, dim=784, batch=128)
    p, spec = _mlp_init(jax.random.PRNGKey(0), use_dyad)

    def loss_fn(p, b):
        logits = _apply(p, b["x"], spec)
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, b["labels"][:, None], 1).mean()

    @jax.jit
    def step(p, b):
        g = jax.grad(loss_fn)(p, b)
        return jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)

    for i in range(STEPS):
        p = step(p, data.batch_at(i))
    test = data.batch_at(10_000)
    acc = float((jnp.argmax(_apply(p, test["x"], spec), -1)
                 == test["labels"]).mean())
    fwd = jax.jit(lambda p, x: _apply(p, x, spec))
    t = time_fn(fwd, p, test["x"], iters=3)
    return acc, t


@perf.register("mnist")
def run():
    acc_d, t_d = _train_eval(False)
    acc_y, t_y = _train_eval(True)
    emit("mnist_dense", t_d, acc=round(acc_d, 4), ratio=1.00)
    emit("mnist_dyad_it4", t_y, acc=round(acc_y, 4),
         ratio=round(t_d / t_y, 2),
         acc_parity="PASS" if acc_y >= 0.95 * acc_d else "FAIL")


if __name__ == "__main__":
    run()
