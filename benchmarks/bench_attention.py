"""Attention suite: flash kernels vs the XLA sdpa paths, plus decode-step
latency for both serve engines.

Prefill cells compare the Pallas flash kernel (:mod:`repro.kernels.
flash_attn`, tiles pre-tuned through ``autotune_dyad`` exactly like the
launchers do) against the einsum paths it subsumes — ``_naive_sdpa``
(materializes the (S, T) scores), ``_chunked_sdpa`` (online-softmax key
chunks, re-reads q per chunk), and at 32k the ``_q_block_sdpa`` scan (the
non-Pallas fallback dispatched there) — at OPT-125m/350m attention dims.
On CPU the kernel executes the compiled interpret path, so as everywhere
in this repo the wall-clock RATIO is the deliverable, not a TPU time.
The 32k cells run a 2-KV-head slice (``heads`` metric) to keep the suite
minutes, not hours; the full-head chunked path at 32k is the quadratic
blow-up this kernel exists to delete and is not timed.

Decode cells record one decode-step latency for the homogeneous
``Engine`` (jitted scan step) and the per-slot ``ContinuousBatchingEngine``
(padded batch step incl. slot bookkeeping) on the qwen3 smoke config,
flash route vs the einsum route (``REPRO_KERNEL_ATTN`` forced, same
protocol as the ff suites).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, force_attn_route, time_fn
from repro import perf
from repro.kernels import flash_attn as fa
from repro.layers import attention as attn_lib
from repro.perf.autotune import autotune_dyad

# (n_heads, head_dim) at the paper's experimental dims; n_kv == n_heads
DIMS = {
    "opt125m": (12, 64),
    "opt350m": (16, 64),
}
S_SHORT = 4096
S_LONG = 32768
LONG_HEADS = 2          # 32k cells run a KV-head slice (CPU-feasible)
CHUNK = 2048            # the serving configs' attn_chunk scale

# the plausible large-tile candidates at these dims; the sweep still runs
# through autotune_dyad so the winner lands in the block cache the same
# way the launchers' --autotune does
CANDS = [{"block_b": 1024, "block_o": 128, "block_k": 1024},
         {"block_b": 512, "block_o": 128, "block_k": 1024}]


def _qkv(key, S, K, h):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, S, K, 1, h))
    k = jax.random.normal(ks[1], (1, S, K, h))
    v = jax.random.normal(ks[2], (1, S, K, h))
    return q, k, v


def _flash_fn(S, **kw):
    return jax.jit(lambda q, k, v: fa.flash_prefill(
        q, k, v, causal=True, interpret=True, **kw)[0])


def _prefill_cells(key):
    for model_name, (K, h) in DIMS.items():
        S = S_SHORT
        q, k, v = _qkv(jax.random.fold_in(key, K), S, K, h)
        qpos = jnp.arange(S)
        shape = (1, S, K, h)

        naive = jax.jit(lambda q, k, v: attn_lib._naive_sdpa(
            q, k, v, qpos, qpos, True, None))
        t_n = time_fn(naive, q, k, v, iters=2, warmup=1)
        emit(f"attn_{model_name}_s4k_naive", t_n, shape=shape, ratio=1.0)

        chunked = jax.jit(lambda q, k, v: attn_lib._chunked_sdpa(
            q, k, v, qpos, qpos, True, None, CHUNK))
        t_c = time_fn(chunked, q, k, v, iters=2, warmup=1)
        emit(f"attn_{model_name}_s4k_chunked", t_c, shape=shape,
             vs_naive=round(t_n / t_c, 2))

        blocks, _ = autotune_dyad("flash_prefill", S, K, h, S, d_mid=1,
                                  candidates=CANDS, iters=1, warmup=1)
        t_f = time_fn(_flash_fn(S), q, k, v, iters=2, warmup=1)
        emit(f"attn_{model_name}_s4k_flash", t_f, shape=shape,
             flash_vs_chunked=round(t_c / t_f, 2),
             flash_vs_naive=round(t_n / t_f, 2), **blocks)

    # 32k: the q-block scan is the XLA fallback actually dispatched there
    # (the plain chunked path re-reads the full 32k q per key chunk and is
    # the quadratic blow-up being deleted — not timed).
    K, h = LONG_HEADS, DIMS["opt125m"][1]
    S = S_LONG
    q, k, v = _qkv(jax.random.fold_in(key, 99), S, K, h)
    qpos = jnp.arange(S)
    shape = (1, S, K, h)
    qblock = jax.jit(lambda q, k, v: attn_lib._q_block_sdpa(
        q, k, v, qpos, qpos, True, None, CHUNK))
    t_q = time_fn(qblock, q, k, v, iters=1, warmup=1)
    emit("attn_opt125m_s32k_qblock", t_q, shape=shape, heads=K)
    autotune_dyad("flash_prefill", S, K, h, S, d_mid=1, candidates=CANDS[:1],
                  iters=1, warmup=1)
    t_f = time_fn(_flash_fn(S), q, k, v, iters=1, warmup=1)
    emit("attn_opt125m_s32k_flash", t_f, shape=shape, heads=K,
         flash_vs_qblock=round(t_q / t_f, 2))


def _decode_cells(key):
    from repro import configs
    from repro.models import model
    from repro.serve import ContinuousBatchingEngine, Engine

    cfg = configs.get("qwen3_0_6b", smoke=True)
    params = model.init_params(cfg, key)
    B, P, MAX = 4, 16, 96
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    for route in ("xla", "flash"):
        with force_attn_route(route):
            eng = Engine(cfg, params, max_len=MAX)
            cache = model.init_cache(cfg, B, MAX, jnp.float32)
            logits, cache = eng._prefill(params, cache, prompts, None)
            tok = jnp.argmax(logits[:, -1:], axis=-1)
            t = time_fn(eng._step, params, cache, tok, iters=3, warmup=1)
            emit(f"attn_decode_batch_{route}", t, shape=(B, 1, MAX),
                 engine="batch")

            ce = ContinuousBatchingEngine(cfg, params, n_slots=B,
                                          max_len=MAX)
            import numpy as np
            for i in range(B):
                ce.submit(np.asarray(prompts[i % B, :P - i]), MAX - P)
            step = lambda: (ce.step(), jnp.zeros(()))[1]
            t = time_fn(step, iters=3, warmup=1)
            emit(f"attn_decode_continuous_{route}", t, shape=(B, 1, MAX),
                 engine="continuous")


@perf.register("attention")
def run():
    key = jax.random.PRNGKey(0)
    _prefill_cells(key)
    _decode_cells(key)


if __name__ == "__main__":
    run()
