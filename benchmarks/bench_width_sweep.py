"""Paper Fig 6 analog: DYAD-vs-DENSE ff speedup at increasing model width
(6-layer-capped OPT-like architecture, widths up to 4096).

Emits measured CPU ratios and the analytic FLOP-bound ratio per width —
the paper's claim is that the speedup GROWS with width.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro import perf
from repro.core import dyad, linear

TOKENS = 256
WIDTHS = [768, 1024, 2048, 4096]


@perf.register("width_sweep")
def run():
    key = jax.random.PRNGKey(0)
    for d in WIDTHS:
        ff = 4 * d
        x = jax.random.normal(key, (TOKENS, d))
        pd = {"up": linear.init(key, d, ff), "down": linear.init(key, ff, d)}
        dense = jax.jit(lambda p, x: linear.apply(
            p["down"], jax.nn.relu(linear.apply(p["up"], x))))
        td = time_fn(dense, pd, x, iters=3)

        spec = dyad.DyadSpec(n_dyad=4, variant="it")
        pv = {"up": dyad.init(key, d, ff, spec),
              "down": dyad.init(key, ff, d, spec)}
        dy = jax.jit(lambda p, x: dyad.apply(
            p["down"], jax.nn.relu(dyad.apply(p["up"], x, spec)), spec))
        tv = time_fn(dy, pv, x, iters=3)
        emit(f"width_{d}_dense_fwd", td, shape=(TOKENS, d, ff), ratio=1.00)
        emit(f"width_{d}_dyad_it4_fwd", tv, shape=(TOKENS, d, ff),
             ratio=round(td / tv, 2), flop_bound=2.0)


if __name__ == "__main__":
    run()
