"""quant suite: quantized DYAD serving — int8/fp8 weight streams through
the in-kernel-dequant megakernel, int8 paged KV capacity, and end-to-end
greedy quality vs the fp routes.

Decode batches are weight-bound: the ff cell times the quantized
megakernel (``ops.dyad_ff_quant``) against the fp megakernel at a
decode-shaped batch and attaches the roofline-modeled per-device times
(constants from ``launch.roofline``, bf16 serving compute) where the ONLY
difference is the weight-stream bytes — payload + fp32 scale sidecars vs
bf16 tensors.  ``bound_speedup`` (fp bound / quant bound) is the
deliverable and must exceed 1.5x at these dims.  On CPU both routes
execute the Pallas interpreter, so (as everywhere in this repo) the
absolute wall-clock is NOT a TPU number.

The KV cell doesn't model anything: it allocates the real paged pools
(``init_paged_kv_cache``) both ways and reports bytes/token from leaf
``nbytes`` — ``capacity_ratio`` (tokens per HBM byte, >= 1.8x required)
is exact arithmetic on the layouts.

The quality cell runs the continuous engine twice on the real smoke model
— fp routes vs int8 weights + int8 paged KV (flash decode) — and reports
the greedy token match fraction, which must be >= 0.99.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, force_attn_route, time_fn
from repro import configs, obs, perf, quant
from repro.kernels import ops as kops
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.layers import attention as attn_lib
from repro.layers import mlp
from repro.models import model
from repro.perf.autotune import autotune_dyad

TOKENS = 32                 # decode-shaped batch: weight-bound regime
D, DFF = 768, 3072          # opt125m ff dims
N_DYAD = 4
ACT = "gelu"

KV_HEADS, HEAD_DIM, PAGE, N_PAGES = 8, 64, 16, 32


def _ff_bound_us(w_bytes_per_elem: float, scales: bool) -> float:
    """Roofline per-device microseconds for one decode-shaped ff call:
    bf16 activations either way; only the weight stream changes."""
    act = 2                                      # bf16 serving compute
    flops = 8 * TOKENS * D * DFF / N_DYAD
    w_elems = 4 * D * DFF / N_DYAD               # up x2 + down x2
    w_bytes = w_elems * w_bytes_per_elem
    if scales:                                   # fp32 (block, out_row)
        w_bytes += 2 * (DFF + D) * 4
    hbm = TOKENS * D * act * 2 + w_bytes         # x in + y out + weights
    return max(flops / PEAK_FLOPS, hbm / HBM_BW) * 1e6


def _pretune(qdt: str):
    n = N_DYAD
    k, j = D // n, DFF // n
    autotune_dyad("dyad_ff_fused", TOKENS, n, k, k, d_mid=j, act=ACT,
                  iters=1)
    autotune_dyad("dyad_ff_fused_w8", TOKENS, n, k, k, qdt, d_mid=j,
                  act=ACT, iters=1)


def _ff_cells():
    lin = configs.linear_cfg("dyad_it_4_kernel_ffused_w8")
    params = mlp.init_mlp(jax.random.PRNGKey(0), D, DFF, lin, act=ACT)
    x = jax.random.normal(jax.random.PRNGKey(1), (TOKENS, D))
    shape = (TOKENS, D, DFF)
    w_mb = round(4 * D * DFF / N_DYAD * 4 / 2 ** 20, 2)

    t_fp = time_fn(jax.jit(lambda p, x: kops.dyad_ff(p, x, act=ACT)),
                   params, x, iters=3, warmup=1)
    b_fp = _ff_bound_us(2, scales=False)
    emit("quant_ff_fp", t_fp, shape=shape, weight_mb=w_mb,
         bound_us=round(b_fp, 3))

    for qdt in ["int8"] + (["fp8"] if quant.supports_fp8() else []):
        _pretune("float8_e4m3fn" if qdt == "fp8" else qdt)
        pq = quant.quantize_params(params, qdt)
        obs.reset_route_counts()
        t_q = time_fn(jax.jit(lambda p, x: kops.dyad_ff_quant(p, x,
                                                              act=ACT)),
                      pq, x, iters=3, warmup=1)
        b_q = _ff_bound_us(1, scales=True)
        emit(f"quant_ff_{qdt}", t_q, shape=shape, weight_mb=round(w_mb / 4, 2),
             bound_us=round(b_q, 3),
             bound_speedup=round(b_fp / b_q, 3),
             wall_vs_fp=round(t_fp / t_q, 3))


def _kv_cells():
    for name, dtype in (("fp32", np.float32), ("bf16", jax.numpy.bfloat16)):
        full = attn_lib.init_paged_kv_cache(
            2, 64, KV_HEADS, HEAD_DIM, dtype, page_size=PAGE,
            n_pages=N_PAGES)
        q = attn_lib.init_paged_kv_cache(
            2, 64, KV_HEADS, HEAD_DIM, dtype, page_size=PAGE,
            n_pages=N_PAGES, quant="int8")
        pools = ("pages_k", "pages_v", "scales_k", "scales_v")
        slots = N_PAGES * PAGE
        bt_full = sum(full[nm].nbytes for nm in pools if nm in full) / slots
        bt_q = sum(q[nm].nbytes for nm in pools if nm in q) / slots
        emit(f"quant_kv_capacity_{name}", 0.0,
             shape=(N_PAGES, PAGE, KV_HEADS, HEAD_DIM),
             bytes_per_token_fp=int(bt_full), bytes_per_token_int8=int(bt_q),
             capacity_ratio=round(bt_full / bt_q, 3))


def _engine_tokens(cfg, params, prompts, new_tokens):
    from repro.serve import ContinuousBatchingEngine

    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=24,
                                   page_size=4)
    uids = [eng.submit(p, new_tokens) for p in prompts]
    t0 = time.perf_counter()
    out = eng.run()
    dt = time.perf_counter() - t0
    toks = [out[u] for u in uids]
    return toks, dt, sum(len(t) for t in toks)


def _quality_cell():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(s,)) for s in (11, 7, 9)]

    with force_attn_route("flash"):
        want, _, _ = _engine_tokens(cfg, params, prompts, 6)
        qcfg = cfg.replace(
            linear=configs.linear_cfg("dyad_it_4_kernel_ffused_w8"),
            kv_quant="int8")
        obs.reset_route_counts()
        got, dt, n_tok = _engine_tokens(
            qcfg, quant.quantize_params(params), prompts, 6)
    routes = obs.routes_snapshot()
    matched = sum(int(a == b) for w, g in zip(want, got)
                  for a, b in zip(w, g))
    total = sum(len(w) for w in want)
    emit("quant_quality_greedy", dt / max(n_tok, 1) * 1e6,
         shape=(len(prompts), 6),
         token_match=round(matched / max(total, 1), 4),
         ff_quant_events=routes.get("ff_quant:int8", 0),
         kv_quant_events=routes.get("kv_quant:int8", 0))


@perf.register("quant")
def run():
    _ff_cells()
    _kv_cells()
    _quality_cell()


if __name__ == "__main__":
    run()
