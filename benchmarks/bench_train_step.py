"""Training hot path: full fwd+bwd+AdamW step on a DYAD vs DENSE ff block.

The paper's headline claim is TRAINING speed (§1: 7-15% faster pretraining),
so this suite times the exact unit the claim lives in — one optimizer step
over an ff module at OPT-125m dimensions — across backward routes:

* ``train_ff_dense``           — dense up/down baseline.
* ``train_ff_dyad_einsum_vjp`` — kernel forward, pre-PR einsum-VJP backward
                                 (``use_kernel_bwd=False``: the ref.py
                                 oracle, which materializes the strided
                                 views and the dx un-view).
* ``train_ff_dyad_fused_bwd``  — kernel forward + the fused backward route
                                 (``use_kernel_bwd=True``): Pallas
                                 dgrad/wgrad kernels on TPU, the compiled
                                 direct-layout lowering of the same
                                 dataflow elsewhere.
* ``train_ff_dyad_pallas_bwd`` — the true Pallas backward kernels forced
                                 via ``REPRO_KERNEL_BWD=pallas`` with
                                 autotuned tiles (interpret-mode off-TPU;
                                 recorded for the tile-tuning trajectory,
                                 not expected to win on CPU).

The fwd kernel tiles AND the dgrad/wgrad tiles come from the autotuner —
the suite pre-tunes them the same way ``launch/train.py --autotune`` does,
so the recorded numbers are what a tuned training run sees.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro import perf
from repro.core import dyad, linear
from repro.optim import AdamW, schedule
from repro.perf.autotune import autotune_dyad, bwd_ops_for_variant
from repro.perf.record import hlo_metrics

TOKENS = 2048
D, FF = 768, 3072            # OPT-125m ff dimensions
N_DYAD = 4
VARIANT = "it"


def make_adam_step(apply_fn):
    """(opt, jitted step) for one fwd+bwd+AdamW iteration over an ff block
    ``{"up": ..., "down": ...}``.  Shared with the smoke suite's tiny
    train-step cells so both gates measure the same computation."""
    opt = AdamW(lr=schedule.constant(1e-3))

    def loss(p, x):
        h = jax.nn.relu(apply_fn(p["up"], x, "up"))
        y = apply_fn(p["down"], h, "down")
        return (y ** 2).mean()

    def step(state, x):
        params, opt_state = state
        grads = jax.grad(loss)(params, x)
        new_params, new_opt, _ = opt.update(grads, opt_state, params)
        return new_params, new_opt

    return opt, jax.jit(step)


def dyad_ff_apply(spec_up, spec_down=None):
    spec_down = spec_down if spec_down is not None else spec_up

    def apply_fn(p, x, which):
        return dyad.apply(p, x, spec_up if which == "up" else spec_down)
    return apply_fn


def _cells():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (TOKENS, D))
    shape = (TOKENS, D, FF)

    # dense baseline
    pd = {"up": linear.init(key, D, FF), "down": linear.init(key, FF, D)}
    opt, step = make_adam_step(lambda p, x, _: linear.apply(p, x))
    sd = (pd, opt.init(pd))
    t_dense = time_fn(step, sd, x, iters=7, warmup=2)
    emit("train_ff_dense", t_dense, shape=shape, ratio=1.00)

    def dyad_cell(name, use_kernel_bwd, **metrics):
        su = dyad.DyadSpec(n_dyad=N_DYAD, variant=VARIANT, use_kernel=True,
                           use_kernel_bwd=use_kernel_bwd)
        p = {"up": dyad.init(key, D, FF, su), "down": dyad.init(key, FF, D, su)}
        opt, step = make_adam_step(dyad_ff_apply(su))
        st = (p, opt.init(p))
        t = time_fn(step, st, x, iters=7, warmup=2)
        emit(name, t, shape=shape, ratio=round(t_dense / t, 3), **metrics)
        return t, step, st

    t_einsum, _, _ = dyad_cell("train_ff_dyad_einsum_vjp", False)
    t_fused, step_f, st_f = dyad_cell("train_ff_dyad_fused_bwd", True)
    roof = hlo_metrics(step_f, st_f, x)
    emit("train_ff_dyad_fused_bwd_roofline", t_fused, shape=shape,
         fused_vs_einsum_vjp=round(t_einsum / t_fused, 3), **roof)
    return t_einsum


def _pallas_bwd_cell():
    """Time the true Pallas dgrad/wgrad kernels (tuned tiles) through a
    jitted grad — interpret-mode off-TPU, so tiles (not wall-parity with
    XLA) are the deliverable of this cell."""
    for f_in, f_out in [(D, FF), (FF, D)]:
        n, d_in, d_out = N_DYAD, f_in // N_DYAD, f_out // N_DYAD
        for op in ["dyad_mm_blocks"] + bwd_ops_for_variant(VARIANT):
            autotune_dyad(op, TOKENS, n, d_in, d_out, iters=1, warmup=1)

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (TOKENS, D))
    spec = dyad.DyadSpec(n_dyad=N_DYAD, variant=VARIANT, use_kernel=True)
    p = {"up": dyad.init(key, D, FF, spec), "down": dyad.init(key, FF, D, spec)}

    def loss(p, x):
        h = jax.nn.relu(dyad.apply(p["up"], x, spec))
        return (dyad.apply(p["down"], h, spec) ** 2).mean()

    prev = os.environ.get("REPRO_KERNEL_BWD")
    os.environ["REPRO_KERNEL_BWD"] = "pallas"
    try:
        from repro.kernels import ops as kops
        kops._make_dyad_mm.cache_clear()      # drop traces of other routes
        g = jax.jit(jax.grad(loss))
        t = time_fn(g, p, x, iters=3, warmup=1)
        emit("train_ff_dyad_pallas_bwd", t, shape=(TOKENS, D, FF),
             route="pallas_interpret" if jax.default_backend() != "tpu"
             else "pallas")
    finally:
        kops._make_dyad_mm.cache_clear()
        if prev is None:
            os.environ.pop("REPRO_KERNEL_BWD", None)
        else:
            os.environ["REPRO_KERNEL_BWD"] = prev


@perf.register("train_step")
def run():
    _cells()
    _pallas_bwd_cell()


if __name__ == "__main__":
    run()
