"""Paper Table 11 analog: memory & parameter footprint, DENSE vs DYAD,
for the paper's OPT-125m (full config):

* parameter counts (total + non-embedding, as in Pythia/the paper),
* checkpoint size (exact on-disk bytes of the serialized pytree),
* in-training memory (XLA memory_analysis of the compiled train step).
"""
from __future__ import annotations

import jax

from benchmarks.common import emit
from repro import configs, perf
from repro.checkpoint.manager import flatten_with_paths
from repro.optim import AdamW, schedule
from repro.train import init_train_state, make_train_step


def _stats(linear_spec: str):
    cfg = configs.get("opt125m", linear=configs.linear_cfg(linear_spec),
                      iota_embed=False)
    specs = configs.params_specs(cfg)
    flat = flatten_with_paths(specs)
    total = sum(int(v.size) for v in jax.tree.leaves(specs))
    emb = sum(int(v.size) for k, v in flat.items()
              if k.startswith(("embed/", "pos/")))
    ckpt_mb = sum(
        int(v.size) * v.dtype.itemsize for v in jax.tree.leaves(specs)) / 2**20

    opt = AdamW(lr=schedule.constant(1e-4))
    state_specs = jax.eval_shape(
        lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
    batch = configs.input_specs(
        cfg, configs.Shape("bench", "train", 128, 8))
    compiled = jax.jit(make_train_step(cfg, opt),
                       donate_argnums=0).lower(state_specs, batch).compile()
    mem = compiled.memory_analysis()
    train_mb = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**20
    return total, total - emb, ckpt_mb, train_mb


@perf.register("memory")
def run():
    base = None
    for spec in ("dense", "dyad_it_4", "dyad_ot_4", "dyad_dt_4", "dyad_it_8"):
        total, nonemb, ckpt_mb, train_mb = _stats(spec)
        if base is None:
            base = train_mb
        drop = 100.0 * (1 - train_mb / base)
        emit(f"mem_opt125m_{spec}", 0.0,
             params=total, nonemb=nonemb, ckpt_mb=round(ckpt_mb),
             train_mb=round(train_mb), gpu_mem_drop_pct=round(drop, 1))


if __name__ == "__main__":
    run()
