"""Paper Tables 1/5/10 + §3.4.3 (-CAT): ff-module time per minibatch,
DENSE vs DYAD variants, forward and forward+backward, at OPT-125m and
OPT-350m ff dimensions.

CPU wall-times are not TPU times — the deliverable (as in the paper) is the
RATIO column.  FLOP-derived speedup bounds are emitted alongside, and each
forward record carries loop-aware HLO FLOP/byte counts so the regression
gate can print roofline-annotated tables.

The ``kernel_*`` cells exercise the Pallas-kernel autotuner on a
non-default shape (d_out not a multiple of the hardcoded 256 tile): the
``_default`` cell times the hardcoded blocks, the ``_tuned`` cell times
whatever ``repro.perf.autotune`` picked, demonstrating that tuned tiles
are real and at least as fast.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro import perf
from repro.core import dyad, linear
from repro.perf.autotune import DEFAULT_BLOCKS, autotune_dyad
from repro.perf.record import hlo_metrics

TOKENS = 2048           # minibatch tokens for timing (matmul-bound on CPU)

DIMS = {
    "opt125m": (768, 3072),
    "opt350m": (1024, 4096),
}

VARIANTS = [
    ("dyad_it_4", dyad.DyadSpec(n_dyad=4, variant="it")),
    ("dyad_ot_4", dyad.DyadSpec(n_dyad=4, variant="ot")),
    ("dyad_dt_4", dyad.DyadSpec(n_dyad=4, variant="dt")),
    ("dyad_it_8", dyad.DyadSpec(n_dyad=8, variant="it")),
    ("dyad_it_4_cat", dyad.DyadSpec(n_dyad=4, variant="it", cat=True)),
]

# autotune demo shape: B typical of a decode microbatch; d_out=384 has no
# 256-divisor, so the hardcoded default tiles the o-axis in two 192-wide
# columns where a tuned 384-wide tile needs one grid step.
KERNEL_SHAPE = (64, 2, 512, 384)       # (B, n_dyad, d_in, d_out)


def _ff_dense(p, x):
    h = jax.nn.relu(linear.apply(p["up"], x))
    return linear.apply(p["down"], h)


def _ff_dyad(p, x, spec, spec_down):
    h = jax.nn.relu(dyad.apply(p["up"], x, spec))
    return dyad.apply(p["down"], h, spec_down)


def _kernel_autotune_cells():
    from repro.kernels.dyad_mm import dyad_mm_blocks
    from repro.kernels.ops import _interpret

    B, n, d_in, d_out = KERNEL_SHAPE
    key = jax.random.PRNGKey(0)
    x1 = jax.random.normal(key, (B, n, d_in))
    x2 = jax.random.normal(jax.random.fold_in(key, 1), (B, n, d_in))
    w1 = jax.random.normal(jax.random.fold_in(key, 2), (n, d_out, d_in))
    w2 = jax.random.normal(jax.random.fold_in(key, 3), (n, d_out, d_in))
    interpret = _interpret()

    t_default = time_fn(
        lambda: dyad_mm_blocks(x1, x2, w1, w2, interpret=interpret,
                               **DEFAULT_BLOCKS), iters=3, warmup=1)
    tuned, _ = autotune_dyad("dyad_mm_blocks", B, n, d_in, d_out, iters=3)
    t_tuned = time_fn(
        lambda: dyad_mm_blocks(x1, x2, w1, w2, interpret=interpret,
                               **tuned), iters=3, warmup=1)
    tag = f"kernel_dyad_it_B{B}_n{n}_k{d_in}_o{d_out}"
    emit(f"{tag}_default", t_default, shape=KERNEL_SHAPE, **DEFAULT_BLOCKS)
    emit(f"{tag}_tuned", t_tuned, shape=KERNEL_SHAPE,
         tuned_speedup=round(t_default / t_tuned, 3), **tuned)


@perf.register("ff_timing")
def run():
    key = jax.random.PRNGKey(0)
    for model_name, (d, ff) in DIMS.items():
        x = jax.random.normal(key, (TOKENS, d))

        pd = {"up": linear.init(key, d, ff), "down": linear.init(key, ff, d)}
        fwd = jax.jit(lambda p, x: _ff_dense(p, x))
        bwd = jax.jit(jax.grad(lambda p, x: _ff_dense(p, x).sum()))
        t_fwd_dense = time_fn(fwd, pd, x)
        t_tot_dense = t_fwd_dense + time_fn(bwd, pd, x)
        roof = hlo_metrics(fwd, pd, x)
        emit(f"ff_{model_name}_dense_fwd", t_fwd_dense,
             shape=(TOKENS, d, ff), ratio=1.00, **roof)
        emit(f"ff_{model_name}_dense_total", t_tot_dense,
             shape=(TOKENS, d, ff), ratio=1.00)

        for vname, spec in VARIANTS:
            sd = dyad.DyadSpec(n_dyad=spec.n_dyad, variant=spec.variant,
                               cat=spec.cat)
            pv = {"up": dyad.init(key, d, ff, spec),
                  "down": dyad.init(key, ff, d, sd)}
            f = jax.jit(lambda p, x, s=spec, s2=sd: _ff_dyad(p, x, s, s2))
            g = jax.jit(jax.grad(
                lambda p, x, s=spec, s2=sd: _ff_dyad(p, x, s, s2).sum()))
            t_fwd = time_fn(f, pv, x)
            t_tot = t_fwd + time_fn(g, pv, x)
            flop_bound = spec.n_dyad / 2
            roof = hlo_metrics(f, pv, x)
            emit(f"ff_{model_name}_{vname}_fwd", t_fwd, shape=(TOKENS, d, ff),
                 ratio=round(t_fwd_dense / t_fwd, 2),
                 flop_bound=flop_bound, **roof)
            emit(f"ff_{model_name}_{vname}_total", t_tot,
                 shape=(TOKENS, d, ff),
                 ratio=round(t_tot_dense / t_tot, 2))

    _kernel_autotune_cells()


if __name__ == "__main__":
    run()
