"""Paper Tables 1/5/10 + §3.4.3 (-CAT): ff-module time per minibatch,
DENSE vs DYAD variants, forward and forward+backward, at OPT-125m and
OPT-350m ff dimensions.

CPU wall-times are not TPU times — the deliverable (as in the paper) is the
RATIO column.  FLOP-derived speedup bounds are emitted alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import dyad, linear

TOKENS = 2048           # minibatch tokens for timing (matmul-bound on CPU)

DIMS = {
    "opt125m": (768, 3072),
    "opt350m": (1024, 4096),
}

VARIANTS = [
    ("dyad_it_4", dyad.DyadSpec(n_dyad=4, variant="it")),
    ("dyad_ot_4", dyad.DyadSpec(n_dyad=4, variant="ot")),
    ("dyad_dt_4", dyad.DyadSpec(n_dyad=4, variant="dt")),
    ("dyad_it_8", dyad.DyadSpec(n_dyad=8, variant="it")),
    ("dyad_it_4_cat", dyad.DyadSpec(n_dyad=4, variant="it", cat=True)),
]


def _ff_dense(p, x):
    h = jax.nn.relu(linear.apply(p["up"], x))
    return linear.apply(p["down"], h)


def _ff_dyad(p, x, spec, spec_down):
    h = jax.nn.relu(dyad.apply(p["up"], x, spec))
    return dyad.apply(p["down"], h, spec_down)


def run():
    key = jax.random.PRNGKey(0)
    for model_name, (d, ff) in DIMS.items():
        x = jax.random.normal(key, (TOKENS, d))

        pd = {"up": linear.init(key, d, ff), "down": linear.init(key, ff, d)}
        fwd = jax.jit(lambda p, x: _ff_dense(p, x))
        bwd = jax.jit(jax.grad(lambda p, x: _ff_dense(p, x).sum()))
        t_fwd_dense = time_fn(fwd, pd, x)
        t_tot_dense = t_fwd_dense + time_fn(bwd, pd, x)
        emit(f"ff_{model_name}_dense_fwd", t_fwd_dense, "ratio=1.00")
        emit(f"ff_{model_name}_dense_total", t_tot_dense, "ratio=1.00")

        for vname, spec in VARIANTS:
            sd = dyad.DyadSpec(n_dyad=spec.n_dyad, variant=spec.variant,
                               cat=spec.cat)
            pv = {"up": dyad.init(key, d, ff, spec),
                  "down": dyad.init(key, ff, d, sd)}
            f = jax.jit(lambda p, x, s=spec, s2=sd: _ff_dyad(p, x, s, s2))
            g = jax.jit(jax.grad(
                lambda p, x, s=spec, s2=sd: _ff_dyad(p, x, s, s2).sum()))
            t_fwd = time_fn(f, pv, x)
            t_tot = t_fwd + time_fn(g, pv, x)
            flop_bound = spec.n_dyad / 2
            emit(f"ff_{model_name}_{vname}_fwd", t_fwd,
                 f"ratio={t_fwd_dense / t_fwd:.2f};flop_bound={flop_bound:.1f}x")
            emit(f"ff_{model_name}_{vname}_total", t_tot,
                 f"ratio={t_tot_dense / t_tot:.2f}")


if __name__ == "__main__":
    run()
