"""Shared benchmark utilities: timing + record emission.

``emit`` keeps the seed's ``name,us_per_call,derived`` CSV on stdout AND
appends a typed :class:`repro.perf.record.BenchResult` to the active
recorder when the suite runs under ``benchmarks/run.py`` (which wraps each
suite in ``repro.perf.record.recording`` and writes ``BENCH_<suite>.json``).
Structured metrics are passed as keyword arguments; the legacy ``derived``
string (``k=v;k=v``) is parsed into metrics for callers not yet converted.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.perf.record import time_us


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit'd fn) — the shared
    timer from repro.perf.record, so suites and the autotuner measure
    identically."""
    return time_us(fn, *args, iters=iters, warmup=warmup)


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k.strip()] = float(v)
        except ValueError:
            out[k.strip()] = v.strip()
    return out


def emit(name: str, us_per_call: float, derived: str = "", *,
         shape: Optional[Sequence[int]] = None, dtype: str = "float32",
         **metrics):
    from repro.perf.record import current_recorder

    merged = {**_parse_derived(derived), **metrics}
    shown = derived or ";".join(f"{k}={v}" for k, v in metrics.items())
    print(f"{name},{us_per_call:.1f},{shown}")
    rec = current_recorder()
    if rec is not None:
        rec.add(name, us_per_call, shape=shape, dtype=dtype, **merged)
