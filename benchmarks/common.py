"""Shared benchmark utilities: timing + record emission.

``emit`` keeps the seed's ``name,us_per_call,derived`` CSV on stdout AND
appends a typed :class:`repro.perf.record.BenchResult` to the active
recorder when the suite runs under ``benchmarks/run.py`` (which wraps each
suite in ``repro.perf.record.recording`` and writes ``BENCH_<suite>.json``).
Structured metrics are passed as keyword arguments; the legacy ``derived``
string (``k=v;k=v``) is parsed into metrics for callers not yet converted.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional, Sequence

from repro.perf.record import time_us


@contextlib.contextmanager
def force_ff_route(route: str):
    """Force the ``ops.dyad_ff`` forward route (``fused`` | ``split``) for
    the duration of the block: sets ``REPRO_KERNEL_FF`` and clears the op's
    trace cache on entry AND exit, so neither the forced route nor a stale
    trace of it leaks into other cells.  The ONE route-forcing protocol
    shared by the ff_fused and smoke suites — the two gates must never
    drift in how they select what they time."""
    from repro.kernels import ops as kops

    prev = os.environ.get("REPRO_KERNEL_FF")
    os.environ["REPRO_KERNEL_FF"] = route
    kops._make_dyad_ff.cache_clear()
    try:
        yield
    finally:
        kops._make_dyad_ff.cache_clear()
        if prev is None:
            os.environ.pop("REPRO_KERNEL_FF", None)
        else:
            os.environ["REPRO_KERNEL_FF"] = prev


@contextlib.contextmanager
def force_attn_route(route: str):
    """Force the attention route (``flash`` | ``xla``) for the duration of
    the block: sets ``REPRO_KERNEL_ATTN`` and clears the flash op's trace
    cache on entry AND exit — the same protocol as :func:`force_ff_route`,
    shared by the attention and smoke suites."""
    from repro.kernels import ops as kops

    prev = os.environ.get("REPRO_KERNEL_ATTN")
    os.environ["REPRO_KERNEL_ATTN"] = route
    kops._make_flash_attention.cache_clear()
    try:
        yield
    finally:
        kops._make_flash_attention.cache_clear()
        if prev is None:
            os.environ.pop("REPRO_KERNEL_ATTN", None)
        else:
            os.environ["REPRO_KERNEL_ATTN"] = prev


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (jit'd fn) — the shared
    timer from repro.perf.record, so suites and the autotuner measure
    identically."""
    return time_us(fn, *args, iters=iters, warmup=warmup)


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k.strip()] = float(v)
        except ValueError:
            out[k.strip()] = v.strip()
    return out


def emit(name: str, us_per_call: float, derived: str = "", *,
         shape: Optional[Sequence[int]] = None, dtype: str = "float32",
         **metrics):
    from repro.perf.record import current_recorder

    merged = {**_parse_derived(derived), **metrics}
    shown = derived or ";".join(f"{k}={v}" for k, v in metrics.items())
    print(f"{name},{us_per_call:.1f},{shown}")
    rec = current_recorder()
    if rec is not None:
        rec.add(name, us_per_call, shape=shape, dtype=dtype, **merged)
