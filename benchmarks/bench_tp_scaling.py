"""tp_scaling suite: the fused TP route (shard_map megakernel +
psum_scatter, ``kernels.tp``) vs the einsum fallback
(``REPRO_KERNEL_TP=off`` block-layout ff) at tp = 1 / 2 / 4.

Each tp cell re-execs in a subprocess: the forced host device count is
locked at first jax init, so a (1, tp) ``("data", "model")`` mesh needs
its own process.  Inside, both routes run the SAME ``layers.mlp.apply_mlp``
under the SAME activation-sharding context — the only difference is the
dispatch ``_ff_kernel_ready`` picks, verified via the ``ff_tp`` route
counters.

On CPU both routes execute interpret-mode Pallas, so (as everywhere in
this repo) absolute wall-clock is NOT a TPU number; each record therefore
also carries the roofline-modeled per-device time ``bound_us`` (constants
from ``launch.roofline``): compute/HBM bound + ICI wire time, where the
fused route deletes the per-shard hidden HBM round-trip (``hidden_mb`` =
0) and halves the wire (reduce-scatter with the re-gather deferred to the
next consumer, vs the fallback's full all-reduce).  ``bound_speedup`` on
the fused cells (fallback bound / fused bound) is the deliverable — it
must exceed 1 at tp > 1.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit
from repro import perf
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

TOKENS = 512
D, DFF = 256, 1024
N_DYAD = 4
ACT = "relu"
TPS = (1, 2, 4)

_CELL = """
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={tp}"
os.environ["REPRO_KERNEL_FF"] = "fused"
import jax
from repro import configs, obs
from repro.launch.mesh import make_test_mesh
from repro.layers import mlp
from repro.sharding import ctx as shard_ctx
from repro.perf.record import time_us

lin = configs.linear_cfg("dyad_it_4_kernel_ffused")
params = mlp.init_mlp(jax.random.PRNGKey(0), {d}, {dff}, lin, act="{act}")
x = jax.random.normal(jax.random.PRNGKey(1), ({tokens}, {d}))
mesh = make_test_mesh((1, {tp}))
res = {{}}
with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
    obs.reset_route_counts()
    fused = jax.jit(lambda p, x: mlp.apply_mlp(p, x, lin, act="{act}"))
    res["fused_us"] = time_us(fused, params, x, iters=3, warmup=1)
    res["routes"] = obs.routes_snapshot()
    os.environ["REPRO_KERNEL_TP"] = "off"
    fb = jax.jit(lambda p, x: mlp.apply_mlp(p, x, lin, act="{act}"))
    res["fallback_us"] = time_us(fb, params, x, iters=3, warmup=1)
print("CELL" + json.dumps(res))
"""


def _run_cell(tp: int) -> dict:
    env = dict(os.environ)
    env.pop("REPRO_KERNEL_TP", None)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep * bool(env.get("PYTHONPATH", "")) \
        + env.get("PYTHONPATH", "")
    script = _CELL.format(tp=tp, d=D, dff=DFF, tokens=TOKENS, act=ACT)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=570, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"tp{tp} cell failed:\n{r.stderr[-2000:]}")
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("CELL")][-1]
    return json.loads(line[len("CELL"):])


def _bound_us(tp: int, *, fused: bool) -> float:
    """Roofline-modeled per-device microseconds for one ff call."""
    fp = 4  # fp32 bytes
    flops = 8 * TOKENS * D * DFF / N_DYAD / tp
    w_bytes = 4 * (D * DFF / N_DYAD) * fp / tp
    y_bytes = TOKENS * D * fp / (tp if fused else 1)
    hidden = 0 if fused else 2 * TOKENS * DFF * fp / tp
    hbm = TOKENS * D * fp + y_bytes + w_bytes + hidden
    wire = (tp - 1) / tp * TOKENS * D * fp * (1 if fused else 2)
    return (max(flops / PEAK_FLOPS, hbm / HBM_BW) + wire / ICI_BW) * 1e6


@perf.register("tp_scaling")
def run():
    for tp in TPS:
        cell = _run_cell(tp)
        shape = (TOKENS, D, DFF)
        hidden_mb = round(TOKENS * DFF * 4 / tp / 2 ** 20, 2)
        b_fused = _bound_us(tp, fused=True)
        b_fb = _bound_us(tp, fused=False)
        fused_count = cell["routes"].get("ff_tp:tp_fused", 0)
        fb_count = cell["routes"].get("ff_tp:tp_fallback", 0)
        emit(f"tp_scaling_tp{tp}_fallback", cell["fallback_us"], shape=shape,
             hidden_mb=hidden_mb, bound_us=round(b_fb, 3))
        emit(f"tp_scaling_tp{tp}_fused", cell["fused_us"], shape=shape,
             hidden_mb=0.0, bound_us=round(b_fused, 3),
             bound_speedup=round(b_fb / b_fused, 3),
             wall_vs_fallback=round(cell["fallback_us"] / cell["fused_us"],
                                    3),
             tp_fused_events=fused_count, tp_fallback_events=fb_count)


if __name__ == "__main__":
    run()
