"""ff megakernel suite: one-grid fused (up → act → down, hidden in VMEM)
vs the split kernel chain vs DENSE, at OPT-125m and OPT-350m ff dims.

The fused and split cells run the SAME ``ops.dyad_ff`` op with the route
forced via ``REPRO_KERNEL_FF`` — identical math, identical tile autotuning,
the only difference is whether the ``(tokens, d_ff)`` hidden round-trips
through HBM between kernel dispatches.  On CPU both routes execute the
Pallas interpreter, so the wall-clock RATIO (dispatch count + hidden
traffic) is the deliverable, as everywhere else in this repo; the absolute
numbers are not TPU times.  ``hidden_mb`` on each record is the HBM
round-trip the megakernel deletes.

Both routes pre-tune their tiles the same way the launchers do
(``autotune_dyad`` per op key), so the recorded numbers are what a tuned
run sees.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, force_ff_route, time_fn
from repro import perf
from repro.core import dyad, linear
from repro.kernels import ops as kops
from repro.perf.autotune import autotune_dyad

TOKENS = 2048
N_DYAD = 4
ACT = "relu"                 # OPT's ff activation

DIMS = {
    "opt125m": (768, 3072),
    "opt350m": (1024, 4096),
}


def _dyad_ff_params(key, d, ff):
    spec = dyad.DyadSpec(n_dyad=N_DYAD, variant="it")
    return {"up": dyad.init(key, d, ff, spec, bias=False),
            "down": dyad.init(jax.random.fold_in(key, 1), ff, d, spec,
                              bias=False)}


def _pretune(d, ff):
    n = N_DYAD
    k, j = d // n, ff // n
    autotune_dyad("dyad_ff_fused", TOKENS, n, k, k, d_mid=j, act=ACT,
                  iters=2)
    autotune_dyad("dyad_mm_blocks", TOKENS, n, k, j, iters=2)      # up
    autotune_dyad("dyad_mm_blocks_two", TOKENS, n, j, k, iters=2)  # down


def _time_route(params, x, route):
    with force_ff_route(route):
        f = jax.jit(lambda p, x: kops.dyad_ff(p, x, act=ACT))
        return time_fn(f, params, x, iters=3, warmup=1)


@perf.register("ff_fused")
def run():
    key = jax.random.PRNGKey(0)
    for model_name, (d, ff) in DIMS.items():
        x = jax.random.normal(key, (TOKENS, d))
        shape = (TOKENS, d, ff)
        hidden_mb = round(TOKENS * ff * 4 / 2 ** 20, 1)

        pd = {"up": linear.init(key, d, ff, bias=False),
              "down": linear.init(key, ff, d, bias=False)}
        dense = jax.jit(lambda p, x: linear.apply(
            p["down"], jax.nn.relu(linear.apply(p["up"], x))))
        t_dense = time_fn(dense, pd, x, iters=3, warmup=1)
        emit(f"ff_fused_{model_name}_dense", t_dense, shape=shape,
             ratio=1.00)

        _pretune(d, ff)
        pv = _dyad_ff_params(key, d, ff)
        t_split = _time_route(pv, x, "split")
        t_fused = _time_route(pv, x, "fused")
        emit(f"ff_fused_{model_name}_split", t_split, shape=shape,
             hidden_mb=hidden_mb, vs_dense=round(t_dense / t_split, 3))
        emit(f"ff_fused_{model_name}_fused", t_fused, shape=shape,
             hidden_mb=0.0, fused_vs_split=round(t_split / t_fused, 3),
             vs_dense=round(t_dense / t_fused, 3))


if __name__ == "__main__":
    run()
