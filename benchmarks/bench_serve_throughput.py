"""Serving throughput: compiled engine vs the seed Python-loop baselines.

Measures, over a (batch x seq-len) grid and for DENSE vs DYAD ff:

* prefill tokens/sec — single-pass ``model.prefill`` (ONE jitted call per
  request batch) vs the seed token-wise loop (one jitted call per token);
* decode tokens/sec  — scan-compiled ``Engine.generate`` (one jitted
  ``lax.scan`` for the whole loop) vs the seed Python-loop
  ``Engine.generate_reference``.

CSV columns: ``name,us_per_call,derived`` where derived carries tokens/sec
and the compiled-over-baseline speedup.  The acceptance cell is
``decode b8 n128``: scan decode must be >= 5x the Python loop on CPU.

    PYTHONPATH=src python benchmarks/run.py serve_throughput
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro import configs, perf
from repro.models import model
from repro.serve import Engine, prefill_tokenwise

ARCH = "qwen3_0_6b"
PREFILL_GRID = [(1, 32), (4, 64), (8, 128)]     # (batch, prompt_len)
DECODE_GRID = [(1, 32), (8, 128)]               # (batch, new_tokens)
PROMPT_FOR_DECODE = 16


def _time(fn, iters=3, warmup=1) -> float:
    """Median wall-seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _bench_linear(tag: str, linear) -> None:
    cfg = configs.get(ARCH, smoke=True, linear=linear)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    # -- prefill: single-pass vs token-wise ---------------------------------
    for B, S in PREFILL_GRID:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        single = jax.jit(
            lambda p, c, t: model.prefill(cfg, p, c, t))

        def run_single():
            cache = model.init_cache(cfg, B, S + 1, jnp.float32)
            return single(params, cache, toks)

        def run_tokenwise():
            cache = model.init_cache(cfg, B, S + 1, jnp.float32)
            return prefill_tokenwise(cfg, params, cache, toks)

        t_new = _time(run_single)
        t_old = _time(run_tokenwise)
        emit(f"{tag}_prefill_b{B}_s{S}_single", t_new * 1e6, shape=(B, S),
             tok_s=round(B * S / t_new), speedup_vs_tokenwise=round(
                 t_old / t_new, 1))
        emit(f"{tag}_prefill_b{B}_s{S}_tokenwise", t_old * 1e6, shape=(B, S),
             tok_s=round(B * S / t_old))

    # -- decode: scan loop vs Python loop -----------------------------------
    for B, N in DECODE_GRID:
        engine = Engine(cfg, params, max_len=PROMPT_FOR_DECODE + N)
        prompts = jax.random.randint(key, (B, PROMPT_FOR_DECODE), 0,
                                     cfg.vocab_size)
        t_new = _time(lambda: engine.generate(prompts, N))
        t_old = _time(lambda: engine.generate_reference(prompts, N))
        emit(f"{tag}_decode_b{B}_n{N}_scan", t_new * 1e6, shape=(B, N),
             tok_s=round(B * N / t_new), speedup_vs_loop=round(
                 t_old / t_new, 1))
        emit(f"{tag}_decode_b{B}_n{N}_loop", t_old * 1e6, shape=(B, N),
             tok_s=round(B * N / t_old))

    # -- acceptance cell: end-to-end generate vs the SEED Engine.generate ---
    # (token-wise EAGER prefill + per-token Python decode dispatch).  One
    # iteration — the seed path costs seconds per call.
    B, N = DECODE_GRID[-1]
    engine = Engine(cfg, params, max_len=PROMPT_FOR_DECODE + N)
    prompts = jax.random.randint(key, (B, PROMPT_FOR_DECODE), 0,
                                 cfg.vocab_size)
    t_new = _time(lambda: engine.generate(prompts, N))
    t_seed = _time(lambda: engine.generate_reference(prompts, N,
                                                     jit_prefill=False),
                   iters=1, warmup=0)
    emit(f"{tag}_generate_b{B}_n{N}_seed", t_seed * 1e6, shape=(B, N),
         tok_s=round(B * N / t_seed),
         scan_engine_speedup=round(t_seed / t_new, 1))


@perf.register("serve_throughput")
def run() -> None:
    _bench_linear("dense", configs.DENSE)
    _bench_linear("dyad", configs.DYAD_DEFAULT)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
