"""Serving throughput: compiled engine vs the seed Python-loop baselines,
plus paged-vs-dense continuous batching at a FIXED KV HBM budget.

Measures, over a (batch x seq-len) grid and for DENSE vs DYAD ff:

* prefill tokens/sec — single-pass ``model.prefill`` (ONE jitted call per
  request batch) vs the seed token-wise loop (one jitted call per token);
* decode tokens/sec  — scan-compiled ``Engine.generate`` (one jitted
  ``lax.scan`` for the whole loop) vs the seed Python-loop
  ``Engine.generate_reference``.

The continuous-batching cells hold the KV token-row budget constant
(``slots * max_len`` dense rows == page pool capacity) and serve the SAME
mixed-length request trace through the dense per-slot rings and the paged
engine: paged reserves ``ceil(actual_len / page)`` pages per request
instead of a worst-case ``max_len`` row, so it runs strictly more
concurrent requests (``max_concurrent``) and finishes the trace faster
(``tok_s``).  A prefix-cache cell serves requests sharing a system prompt
and reports the prefill tokens skipped.

CSV columns: ``name,us_per_call,derived`` where derived carries tokens/sec
and the compiled-over-baseline speedup.  The acceptance cells are
``decode b8 n128`` (scan decode >= 5x the Python loop on CPU) and
``cb_paged`` (max_concurrent strictly above the dense cell's).

    PYTHONPATH=src python benchmarks/run.py serve_throughput
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro import configs, faults, perf
from repro.models import model
from repro.serve import ContinuousBatchingEngine, Engine, prefill_tokenwise

ARCH = "qwen3_0_6b"
PREFILL_GRID = [(1, 32), (4, 64), (8, 128)]     # (batch, prompt_len)
DECODE_GRID = [(1, 32), (8, 128)]               # (batch, new_tokens)
PROMPT_FOR_DECODE = 16

# continuous-batching comparison: one shared KV budget of 256 token rows.
# dense spends it as 4 worst-case slots x 64; paged as a 32-page x 8 pool
# shared by 12 slot lanes.
CB_MAX_LEN = 64
CB_PAGE = 8
CB_DENSE_SLOTS = 4
CB_PAGED_SLOTS = 12
CB_LENGTHS = [8, 12, 16, 24]
CB_NEW = 8
CB_REQUESTS = 12


def _time(fn, iters=3, warmup=1) -> float:
    """Median wall-seconds per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _bench_linear(tag: str, linear) -> None:
    cfg = configs.get(ARCH, smoke=True, linear=linear)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    # -- prefill: single-pass vs token-wise ---------------------------------
    for B, S in PREFILL_GRID:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        single = jax.jit(
            lambda p, c, t: model.prefill(cfg, p, c, t))

        def run_single():
            cache = model.init_cache(cfg, B, S + 1, jnp.float32)
            return single(params, cache, toks)

        def run_tokenwise():
            cache = model.init_cache(cfg, B, S + 1, jnp.float32)
            return prefill_tokenwise(cfg, params, cache, toks)

        t_new = _time(run_single)
        t_old = _time(run_tokenwise)
        emit(f"{tag}_prefill_b{B}_s{S}_single", t_new * 1e6, shape=(B, S),
             tok_s=round(B * S / t_new), speedup_vs_tokenwise=round(
                 t_old / t_new, 1))
        emit(f"{tag}_prefill_b{B}_s{S}_tokenwise", t_old * 1e6, shape=(B, S),
             tok_s=round(B * S / t_old))

    # -- decode: scan loop vs Python loop -----------------------------------
    for B, N in DECODE_GRID:
        engine = Engine(cfg, params, max_len=PROMPT_FOR_DECODE + N)
        prompts = jax.random.randint(key, (B, PROMPT_FOR_DECODE), 0,
                                     cfg.vocab_size)
        t_new = _time(lambda: engine.generate(prompts, N))
        t_old = _time(lambda: engine.generate_reference(prompts, N))
        emit(f"{tag}_decode_b{B}_n{N}_scan", t_new * 1e6, shape=(B, N),
             tok_s=round(B * N / t_new), speedup_vs_loop=round(
                 t_old / t_new, 1))
        emit(f"{tag}_decode_b{B}_n{N}_loop", t_old * 1e6, shape=(B, N),
             tok_s=round(B * N / t_old))

    # -- acceptance cell: end-to-end generate vs the SEED Engine.generate ---
    # (token-wise EAGER prefill + per-token Python decode dispatch).  One
    # iteration — the seed path costs seconds per call.
    B, N = DECODE_GRID[-1]
    engine = Engine(cfg, params, max_len=PROMPT_FOR_DECODE + N)
    prompts = jax.random.randint(key, (B, PROMPT_FOR_DECODE), 0,
                                 cfg.vocab_size)
    t_new = _time(lambda: engine.generate(prompts, N))
    t_seed = _time(lambda: engine.generate_reference(prompts, N,
                                                     jit_prefill=False),
                   iters=1, warmup=0)
    emit(f"{tag}_generate_b{B}_n{N}_seed", t_seed * 1e6, shape=(B, N),
         tok_s=round(B * N / t_seed),
         scan_engine_speedup=round(t_seed / t_new, 1))


def _drain_tracked(eng, prompts, max_new):
    """Submit + drain, tracking the peak number of concurrent slots."""
    for p in prompts:
        eng.submit(p, max_new)
    conc = 0
    while eng.slots.active or eng.queue:
        conc = max(conc, len(eng.slots.active))
        eng.step()
    out = eng.run()          # collects (and clears) the finished list
    return sum(len(t) for t in out.values()), conc


def _bench_continuous() -> None:
    cfg = configs.get(ARCH, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            CB_LENGTHS[i % len(CB_LENGTHS)]).astype(np.int32)
               for i in range(CB_REQUESTS)]

    def timed(make_engine, plist):
        eng = make_engine()
        _drain_tracked(eng, plist, CB_NEW)          # warm the jit traces
        base = dict(getattr(eng, "stats", {}))      # dense engines: no stats
        t0 = time.perf_counter()
        total, conc = _drain_tracked(eng, plist, CB_NEW)
        stats = {k: v - base[k] for k, v in getattr(eng, "stats", {}).items()}
        return time.perf_counter() - t0, total, conc, stats

    t_d, total, conc_d, _ = timed(lambda: ContinuousBatchingEngine(
        cfg, params, n_slots=CB_DENSE_SLOTS, max_len=CB_MAX_LEN), prompts)
    emit(f"serve_cb_dense_s{CB_DENSE_SLOTS}_m{CB_MAX_LEN}", t_d * 1e6,
         shape=(CB_REQUESTS, CB_MAX_LEN), tok_s=round(total / t_d),
         max_concurrent=conc_d, kv_rows=CB_DENSE_SLOTS * CB_MAX_LEN)
    t_p, total, conc_p, _ = timed(lambda: ContinuousBatchingEngine(
        cfg, params, n_slots=CB_PAGED_SLOTS, max_len=CB_MAX_LEN,
        page_size=CB_PAGE,
        n_pages=1 + CB_DENSE_SLOTS * CB_MAX_LEN // CB_PAGE), prompts)
    emit(f"serve_cb_paged_p{CB_PAGE}_s{CB_PAGED_SLOTS}_m{CB_MAX_LEN}",
         t_p * 1e6, shape=(CB_REQUESTS, CB_MAX_LEN),
         tok_s=round(total / t_p), max_concurrent=conc_p,
         kv_rows=CB_DENSE_SLOTS * CB_MAX_LEN,
         capacity_vs_dense=round(conc_p / conc_d, 2),
         tok_s_vs_dense=round(t_d / t_p, 2))

    # degraded mode: the SAME paged trace under 5% injected page exhaustion
    # (repro.faults) — quantifies the throughput cost of admission backoff +
    # retry when the pool misbehaves.  All requests still complete; the
    # tok_s_vs_clean ratio is the resilience overhead cell.
    faults.configure("page_exhaustion:p=0.05", seed=0)
    try:
        t_f, total_f, conc_f, _ = timed(lambda: ContinuousBatchingEngine(
            cfg, params, n_slots=CB_PAGED_SLOTS, max_len=CB_MAX_LEN,
            page_size=CB_PAGE,
            n_pages=1 + CB_DENSE_SLOTS * CB_MAX_LEN // CB_PAGE), prompts)
        fsnap = faults.snapshot()["page_exhaustion"]
    finally:
        faults.configure(None)
    emit(f"serve_cb_paged_degraded_p{CB_PAGE}_s{CB_PAGED_SLOTS}", t_f * 1e6,
         shape=(CB_REQUESTS, CB_MAX_LEN), tok_s=round(total_f / t_f),
         max_concurrent=conc_f, faults_fired=fsnap["fired"],
         tok_s_vs_clean=round((total_f / t_f) / (total / t_p), 2))

    # prefix caching: the same trace behind a shared 16-token system prompt
    system = rng.integers(0, cfg.vocab_size, 2 * CB_PAGE).astype(np.int32)
    shared_prompts = [np.concatenate([system, p]) for p in prompts]
    total_prompt = sum(len(p) for p in shared_prompts)

    def paged_prefix():
        return ContinuousBatchingEngine(
            cfg, params, n_slots=CB_PAGED_SLOTS,
            max_len=CB_MAX_LEN, page_size=CB_PAGE, prefix_cache=True)

    t_x, total, _, stats = timed(paged_prefix, shared_prompts)
    emit(f"serve_cb_paged_prefix_p{CB_PAGE}", t_x * 1e6,
         shape=(CB_REQUESTS, CB_MAX_LEN), tok_s=round(total / t_x),
         prefix_hits=stats["prefix_hits"],
         prefill_tokens=stats["prefill_tokens"],
         prefill_tokens_skipped=total_prompt - stats["prefill_tokens"])


@perf.register("serve_throughput")
def run() -> None:
    _bench_linear("dense", configs.DENSE)
    _bench_linear("dyad", configs.DYAD_DEFAULT)
    _bench_continuous()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
