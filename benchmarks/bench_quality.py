"""Paper Tables 2/3 analog: quality parity DENSE vs DYAD variants.

Offline stand-in for BLIMP/GLUE/OPENLLM: pretrain the same small LM on the
learnable synthetic stream and compare the learning gain (entropy-floor minus
final loss).  The paper's acceptance bar: DYAD >= 0.90 x DENSE.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro import perf
from repro.core import factory
from repro.data import SyntheticLM
from repro.models.config import ModelCfg
from repro.optim import AdamW, schedule
from repro.train import init_train_state, make_train_step

STEPS = 150


def _pretrain(linear_cfg, seed=0):
    cfg = ModelCfg(name="q", family="lm", n_layers=2, d_model=64,
                   vocab_size=64, n_heads=4, n_kv_heads=4, head_dim=16,
                   d_ff=256, linear=linear_cfg)
    opt = AdamW(lr=schedule.warmup_cosine(3e-3, 10, STEPS))
    data = SyntheticLM(vocab_size=64, seq_len=32, global_batch=16, seed=seed)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, opt))
    loss = None
    for i in range(STEPS):
        state, m = step(state, data.batch(i))
        loss = float(m["loss"])
    return loss


@perf.register("quality")
def run():
    floor = float(np.log(64))
    dense = _pretrain(factory.DENSE)
    gain_dense = floor - dense
    emit("quality_dense_loss", 0.0, loss=round(dense, 4),
         gain=round(gain_dense, 3))
    for spec in ("dyad_it_4", "dyad_ot_4", "dyad_dt_4", "dyad_it_8"):
        from repro.configs import linear_cfg
        loss = _pretrain(linear_cfg(spec))
        gain = floor - loss
        rel = gain / gain_dense
        verdict = "PASS" if rel >= 0.90 else "FAIL"
        emit(f"quality_{spec}_loss", 0.0, loss=round(loss, 4),
             rel_gain=round(rel, 3), ge90pct=verdict)


if __name__ == "__main__":
    run()
