"""Sharding rules: key-path -> PartitionSpec for params, optimizer state,
batches and caches.  DP/FSDP/TP/EP composition per DESIGN §5.

TP (Megatron column/row) on the ``model`` axis:
  * up-type projections (wq/wk/wv, gate/up, wz/wx) shard the OUTPUT features;
  * down-type projections (wo, down) shard the INPUT (contracting) features —
    GSPMD inserts the single all-reduce per block;
  * DYAD 3-D weights ``(n_dyad, d_out, d_in)`` shard d_out (up) / d_in (down):
    identical collective count to dense TP, n_dyad/2 x fewer weight bytes;
  * MoE experts shard the leading expert axis (EP);
  * embedding/unembedding tables shard the vocab axis.

FSDP (ZeRO) on the ``fsdp`` axes shards the remaining major dim of big leaves;
optimizer moments follow their parameters (ZeRO-1 falls out of GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

UP_NAMES = ("wq", "wk", "wv", "gate", "up", "wz", "wx")
DOWN_NAMES = ("wo", "down")


@dataclasses.dataclass(frozen=True)
class MeshRules:
    model: str = "model"
    dp: Tuple[str, ...] = ("data",)          # batch axes (pod+data when multi)
    fsdp: Optional[Tuple[str, ...]] = None   # param/optimizer ZeRO axes
    shard_experts: bool = True

    @property
    def dp_spec(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    @property
    def fsdp_spec(self):
        if not self.fsdp:
            return None
        return self.fsdp if len(self.fsdp) > 1 else self.fsdp[0]


def _path_parts(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def _axes_size(axes, axis_sizes) -> int:
    if axes is None or axis_sizes is None:
        return 1
    if isinstance(axes, str):
        return axis_sizes.get(axes, 1)
    n = 1
    for a in axes:
        n *= axis_sizes.get(a, 1)
    return n


def _guard(spec: list, shape, axis_sizes) -> list:
    """Drop axis placements whose dimension is not divisible (e.g. odd
    vocabs like whisper's 51865) — fall back to replication for that dim."""
    out = []
    for dim, axes in zip(shape, spec):
        n = _axes_size(axes, axis_sizes)
        out.append(axes if (n <= 1 or dim % n == 0) else None)
    return out


def param_spec(path, leaf, rules: MeshRules, axis_sizes=None) -> P:
    parts = _path_parts(path)
    name = "/".join(parts)
    # layer params are stacked on a leading n_layers axis
    stacked = "layers" in parts or "enc_layers" in parts
    shape = leaf.shape[1:] if stacked else leaf.shape
    ndim = len(shape)
    m, f = rules.model, rules.fsdp_spec

    def done(spec):
        spec = _guard(spec, shape, axis_sizes)
        if stacked:
            spec = [None] + spec
        return P(*spec)

    # anything tiny or <=1-D: replicate (biases, norms, scalars, A_log, ...)
    if ndim <= 1:
        return done([None] * ndim)
    if ("router" in name or "shared_gate" in name or parts[-1] == "conv"
            or "frontend" in name):
        return done([None] * ndim)

    is_expert = "experts" in parts
    parent = next((p for p in reversed(parts)
                   if p in UP_NAMES + DOWN_NAMES), None)
    is_dyad = parts[-1] in ("w1", "w2", "w1_q", "w2_q")

    if parts[-1] in ("w1_s", "w2_s") and ndim == 2:
        # quantized-sidecar scales (n_dyad, d_out): follow the PAYLOAD's
        # out axis — up-type splits d_out over model, down-type replicates
        # (the down payload shards its d_in; its out rows stay whole).
        if parent in DOWN_NAMES:
            return done([None, None])
        return done([None, m])

    if parts[-1] == "table":
        # (vocab, d_model): vocab over model (Megatron), d over fsdp
        return done([m, f])

    if is_expert:
        # leading expert axis over model (EP); inner dims over fsdp
        if not rules.shard_experts:
            return done([None] * ndim)
        if is_dyad:          # (E, n_dyad, d_out, d_in)
            return done([m, None, f, None])
        if ndim == 3:        # (E, f_out, f_in) dense expert
            return done([m, f, None])
        return done([m] + [None] * (ndim - 1))

    if is_dyad:              # (n_dyad, d_out, d_in)
        if parent in DOWN_NAMES:
            return done([None, f, m])
        return done([None, m, f])

    if ndim == 2:            # dense (f_out, f_in)
        if parent in DOWN_NAMES:
            return done([f, m])
        if parent in UP_NAMES:
            return done([m, f])
        return done([None, None])
    return done([None] * ndim)


def state_shardings(mesh, state_specs, rules: MeshRules):
    """NamedShardings for a train state {params, opt{m,v,step}, ...}."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def shard_params(tree):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(mesh, param_spec(p, l, rules, sizes)),
            tree)

    out = {"params": shard_params(state_specs["params"])}
    if "opt" in state_specs:
        out["opt"] = {
            "m": shard_params(state_specs["opt"]["m"]),
            "v": shard_params(state_specs["opt"]["v"]),
            "step": NamedSharding(mesh, P()),
        }
        if "master" in state_specs["opt"]:
            out["opt"]["master"] = shard_params(state_specs["opt"]["master"])
    if "compress" in state_specs:
        out["compress"] = {"err": shard_params(state_specs["compress"]["err"])}
    return out


def batch_shardings(mesh, batch_specs, rules: MeshRules):
    """Batch axis over the DP axes, everything else replicated."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        spec = [rules.dp_spec] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*_guard(spec, leaf.shape, sizes)))
    return jax.tree_util.tree_map_with_path(one, batch_specs)


def cache_shardings(mesh, cache_specs, rules: MeshRules):
    """KV/SSM caches: batch over DP, kv-heads over model where divisible."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = mesh.shape[rules.model]

    def one(path, leaf):
        parts = _path_parts(path)
        nd = len(leaf.shape)
        if nd == 0 or parts[-1] == "idx":
            return NamedSharding(mesh, P())
        # leading axis is n_layers (stacked), second is batch
        spec = [None] * nd
        if nd >= 2:
            spec[1] = rules.dp_spec
        leafname = parts[-1]
        if leafname in ("pages_k", "pages_v") and nd == 5:
            # (L, NP, P, K, hd) paged pool: axis 1 is the PAGE axis of one
            # pool shared by every slot (page ids in the block table are
            # global), so it must NOT shard over dp like a batch axis;
            # kv heads shard over model when divisible, so the per-device
            # pool shrinks with TP exactly like the dense rings — and
            # matches the per-shard head slice kernels.tp dispatches on.
            spec[1] = None
            if leaf.shape[3] % msize == 0:
                spec[3] = rules.model
            return NamedSharding(mesh, P(*_guard(spec, leaf.shape, sizes)))
        if leafname in ("scales_k", "scales_v") and nd == 4:
            # (L, NP, P, K) quantized-pool scale pools: same page-axis
            # contract as pages_k/pages_v, kv heads over model (axis 3 is
            # the head axis here — no trailing head_dim).
            spec[1] = None
            if leaf.shape[3] % msize == 0:
                spec[3] = rules.model
            return NamedSharding(mesh, P(*_guard(spec, leaf.shape, sizes)))
        if leafname in ("k", "v", "xk", "xv") and nd == 5:
            # (L, B, T, K, hd): kv heads over model when divisible, else
            # context-parallel cache (T over model) — never replicate a
            # multi-GB cache across the model axis.
            if leaf.shape[3] % msize == 0:
                spec[3] = rules.model
            elif leaf.shape[2] % msize == 0:
                spec[2] = rules.model
        if leafname == "state" and nd == 5:
            # (L, B, H, P, N): ssm heads over model when divisible
            if leaf.shape[2] % msize == 0:
                spec[2] = rules.model
        return NamedSharding(mesh, P(*_guard(spec, leaf.shape, sizes)))

    return jax.tree_util.tree_map_with_path(one, cache_specs)


def replicated(mesh, tree_specs):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree_specs)
