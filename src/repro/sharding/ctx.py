"""Activation-sharding context.

Model code is mesh-agnostic; launchers install this context so layers can
drop ``with_sharding_constraint`` hints where GSPMD's propagation is known to
wander (attention scores, the residual stream).  Without a context every
helper is a no-op — tests and single-device runs are untouched.

Policies:
* attention heads sharded over ``model`` when head counts divide the axis;
  otherwise **sequence-parallel attention** (q sharded over seq, k/v gathered)
  — always legal, costs one kv all-gather per layer;
* optional sequence-sharded residual stream (Megatron-SP) via
  ``constrain_residual`` — activation memory / model_axis.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ActivationCtx:
    mesh: object
    dp: Tuple[str, ...]
    model: str
    seq_shard: bool = False

    @property
    def dp_spec(self):
        return self.dp if len(self.dp) > 1 else self.dp[0]

    def axis_size(self, name) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        if isinstance(name, tuple):
            n = 1
            for a in name:
                n *= sizes[a]
            return n
        return sizes[name]


_CTX: contextvars.ContextVar[Optional[ActivationCtx]] = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, *, dp, model, seq_shard=False):
    tok = _CTX.set(ActivationCtx(mesh=mesh, dp=tuple(dp), model=model,
                                 seq_shard=seq_shard))
    try:
        yield
    finally:
        _CTX.reset(tok)


def current() -> Optional[ActivationCtx]:
    return _CTX.get()


def _constrain(x, spec_list):
    ctx = current()
    if ctx is None:
        return x
    # drop placements that don't divide
    fixed = []
    for dim, axes in zip(x.shape, spec_list):
        if axes is None:
            fixed.append(None)
            continue
        concrete = ctx.dp_spec if axes == "dp" else ctx.model
        size = ctx.axis_size(ctx.dp if axes == "dp" else ctx.model)
        fixed.append(concrete if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*fixed)))


def constrain_residual(x):
    """(B, S, D): batch over dp; seq over model when SP is enabled."""
    ctx = current()
    if ctx is None:
        return x
    seq = "model" if ctx.seq_shard else None
    return _constrain(x, ["dp", seq, None])


def constrain_heads(x):
    """(B, S, H, hd): heads over model when divisible, else seq over model."""
    ctx = current()
    if ctx is None:
        return x
    if x.shape[2] % ctx.axis_size(ctx.model) == 0:
        return _constrain(x, ["dp", None, "model", None])
    return _constrain(x, ["dp", "model", None, None])


def constrain_kv(x):
    """(B, T, K, hd): kv heads over model when divisible, else replicated
    (sequence-parallel attention gathers k/v)."""
    ctx = current()
    if ctx is None:
        return x
    if x.shape[2] % ctx.axis_size(ctx.model) == 0:
        return _constrain(x, ["dp", None, "model", None])
    return _constrain(x, ["dp", None, None, None])


def constrain_expert_batch(x):
    """(B, E, C, D) dispatch/expert tensors: batch over dp, experts over
    model (EP) — without this anchor GSPMD has been observed to all-gather
    the EXPERT WEIGHTS instead (9.7TB/step on llama4; §Perf B1)."""
    ctx = current()
    if ctx is None:
        return x
    spec = [None] * x.ndim
    spec[0] = "dp"
    spec[1] = "model"
    return _constrain(x, spec)


def constrain_ff_hidden(x):
    """(..., n_dyad, d_out) or (..., d_ff): last dim over model."""
    ctx = current()
    if ctx is None:
        return x
    spec = [None] * x.ndim
    spec[0] = "dp"
    spec[-1] = "model"
    return _constrain(x, spec)
