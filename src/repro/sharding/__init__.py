"""Mesh-aware sharding rules (DP/FSDP/TP/EP/SP composition)."""
from repro.sharding.rules import (  # noqa: F401
    MeshRules,
    batch_shardings,
    cache_shardings,
    param_spec,
    replicated,
    state_shardings,
)
