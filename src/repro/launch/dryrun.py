import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  Only the dry-run sees 512 placeholder devices;
# tests and benchmarks see the real single CPU device.

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.checkpoint.manager import flatten_with_paths  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh, make_rules  # noqa: E402
from repro.models import model  # noqa: E402
from repro.optim import AdamW, schedule  # noqa: E402
from repro.serve import make_serve_step  # noqa: E402
from repro.sharding import (batch_shardings, cache_shardings,  # noqa: E402
                            state_shardings)
from repro.sharding import ctx as shard_ctx  # noqa: E402
from repro.train import init_train_state, make_train_step  # noqa: E402

# archs big enough to need ZeRO/FSDP over the data axis.  NOTE: membership
# is sized on WEIGHT memory, which the ff route does not change; activation
# headroom DOES differ per route (the fused TP megakernel keeps the hidden
# in VMEM, the einsum fallback round-trips it through HBM) — that per-shard
# accounting is reported per cell via ``ff_route_accounting`` below rather
# than baked into this set.
FSDP_ARCHS = {"llama3_405b", "llama4_maverick_400b_a17b", "qwen2_5_32b",
              "phi3_medium_14b"}


def ff_route_accounting(cfg, shape, sizes, rules) -> dict:
    """Per-device ff-hidden HBM accounting for the route this config
    dispatches under the mesh.  The pre-TP report assumed the FALLBACK
    memory profile for every cell: ``2 * tokens * d_ff * dtype_bytes``
    per step of hidden write+read traffic.  The fused TP route
    (``kernels.tp.dyad_ff_tp``) deletes that term — the per-shard hidden
    lives only in VMEM accumulator tiles — so cells that dispatch it
    report ``ff_hidden_bytes_est = 0`` and the fallback estimate shrinks
    by the dp * tp sharding of the hidden."""
    from repro.kernels import tp as ktp
    from repro.perf.autotune import model_ff_fused_shape

    tp = int(sizes.get(rules.model, 1))
    dp = 1
    for a in rules.dp:
        dp *= int(sizes.get(a, 1))
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    dtype_bytes = 2 if getattr(cfg, "compute_dtype", "") == "bfloat16" else 4
    ff = model_ff_fused_shape(cfg)
    fused = ff is not None and (tp == 1
                                or (ff[2] % tp == 0 and ktp.tp_enabled()))
    if fused:
        route = "fused_kernel_tp" if tp > 1 else "fused_kernel"
        hidden = 0
    else:
        route = "block_einsum"
        hidden = (2 * tokens * cfg.d_ff * dtype_bytes * cfg.n_layers
                  // max(dp * tp, 1))
    # per-step ff WEIGHT stream (read once per step, sharded over tp):
    # quantized serving (linear spec ..._w8 / --quant-weights) streams
    # 1-byte payloads + the fp32 (n, d_out) scale sidecars instead of the
    # compute-dtype tensors — the term bench_quant's bound_speedup acts on.
    n_proj = 3 if getattr(cfg, "act", "gelu") == "swiglu" else 2
    if ff is not None:
        elems = n_proj * 2 * cfg.d_ff * cfg.d_model // ff[0]
        # one fp32 scale per (block, out_row): d_ff rows per up-type
        # tensor, d_model per down tensor
        scale_rows = (n_proj - 1) * 2 * cfg.d_ff + 2 * cfg.d_model
    else:
        elems = n_proj * cfg.d_ff * cfg.d_model
        scale_rows = 0
    quant = getattr(getattr(cfg, "linear", None), "quant", None)
    if quant and ff is not None and fused:
        # quant dispatch needs the kernel route; einsum fallbacks stream fp
        weight = elems * 1 + scale_rows * 4
    else:
        weight = elems * dtype_bytes
        quant = None
    weight = weight * cfg.n_layers // max(tp, 1)
    return {"ff_route": route, "ff_hidden_bytes_est": int(hidden),
            "ff_weight_bytes_est": int(weight),
            "ff_weight_quant": quant}


def active_param_count(cfg, params_specs) -> int:
    """Params participating in per-token matmuls: excludes gather-only
    embedding tables (re-added once if tied/used as the unembed head),
    scales expert leaves by top_k/n_experts."""
    flat = flatten_with_paths(params_specs)
    total = 0.0
    table = 0
    for path, leaf in flat.items():
        n = 1
        for d in leaf.shape:
            n *= d
        if path.startswith("embed/") or path.startswith("pos/"):
            if path.startswith("embed/"):
                table = n
            continue
        if "experts/" in path:
            total += n * cfg.top_k / max(cfg.n_experts, 1)
            continue
        total += n
    # the unembedding matmul is real per-token compute
    total += table if "head/table" not in flat else 0
    return int(total)


def dense_equiv_params(cfg) -> int:
    """Param count of the DENSE twin (for DYAD-vs-DENSE accounting)."""
    dense_cfg = cfg.replace(linear=configs.DENSE)
    specs = configs.params_specs(dense_cfg)
    return active_param_count(dense_cfg, specs)


def make_opt(cfg) -> AdamW:
    # bf16 params pair with an fp32 master copy (mixed-precision recipe);
    # moments drop to bf16 for the biggest archs (memory plan, DESIGN §5).
    bf16 = cfg.param_dtype == "bfloat16"
    return AdamW(lr=schedule.warmup_cosine(3e-4, 2000, 100_000),
                 moment_dtype="bfloat16" if bf16 else "float32",
                 master=bf16)


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool,
                  linear_spec: str = "dyad_it_4", fsdp=None,
                  seq_shard: bool = False, overrides=None):
    cfg = configs.get(arch, linear=configs.linear_cfg(linear_spec),
                      **(overrides or {}))
    shape = configs.SHAPES[shape_name]
    ok, reason = configs.cell_runnable(cfg, shape)
    if not ok:
        return None, {"skipped": reason, "arch": arch, "shape": shape_name}
    mesh = make_production_mesh(multi_pod=multi_pod)
    use_fsdp = (arch in FSDP_ARCHS) if fsdp is None else fsdp
    rules = make_rules(multi_pod=multi_pod, fsdp=use_fsdp)
    meta = {"arch": arch, "shape": shape_name, "linear": linear_spec,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "fsdp": use_fsdp, "kind": shape.kind}
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    meta.update(ff_route_accounting(cfg, shape, sizes, rules))
    dp_size = 1
    for a in rules.dp:
        dp_size *= sizes[a]
    # logits batch sharding must divide the (possibly tiny) batch
    out_batch_spec = (P(rules.dp_spec)
                      if shape.global_batch % dp_size == 0 else P())

    # sharding constraints bake in at trace time -> wrap the lowering
    with shard_ctx.activation_sharding(mesh, dp=rules.dp, model=rules.model,
                                       seq_shard=seq_shard):
        if shape.kind == "train":
            opt = make_opt(cfg)
            state_specs = jax.eval_shape(
                lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
            batch_specs = configs.input_specs(cfg, shape)
            st_sh = state_shardings(mesh, state_specs, rules)
            b_sh = batch_shardings(mesh, batch_specs, rules)
            fn = make_train_step(cfg, opt)
            jfn = jax.jit(fn, in_shardings=(st_sh, b_sh),
                          out_shardings=(st_sh, NamedSharding(mesh, P())),
                          donate_argnums=(0,))
            lowered = jfn.lower(state_specs, batch_specs)
        elif shape.kind == "prefill":
            params_specs = configs.params_specs(cfg)
            batch_specs = configs.input_specs(cfg, shape)
            p_sh = state_shardings(mesh, {"params": params_specs},
                                   rules)["params"]
            b_sh = batch_shardings(mesh, batch_specs, rules)

            def fn(params, batch):
                # production prefill emits last-position logits (the full
                # (B,S,V) fp32 tensor would be ~40GB/device at 32k x 152k)
                return model.forward(cfg, params, batch, last_only=True)[0]

            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh),
                          out_shardings=NamedSharding(mesh, out_batch_spec))
            lowered = jfn.lower(params_specs, batch_specs)
        else:  # decode
            params_specs = configs.params_specs(cfg)
            specs = configs.input_specs(cfg, shape)
            p_sh = state_shardings(mesh, {"params": params_specs},
                                   rules)["params"]
            c_sh = cache_shardings(mesh, specs["cache"], rules)
            t_sh = batch_shardings(mesh, {"tokens": specs["tokens"]},
                                   rules)["tokens"]
            fn = make_serve_step(cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                          out_shardings=(NamedSharding(mesh, out_batch_spec),
                                         c_sh),
                          donate_argnums=(1,))
            lowered = jfn.lower(params_specs, specs["cache"],
                                specs["tokens"])

    meta["cfg"] = cfg
    meta["shape_obj"] = shape
    meta["n_devices"] = mesh.devices.size
    return lowered, meta


def run_cell(arch, shape_name, *, multi_pod, linear_spec="dyad_it_4",
             fsdp=None, outdir=None, seq_shard=False, tag_suffix="",
             overrides=None):
    t0 = time.time()
    lowered, meta = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                  linear_spec=linear_spec, fsdp=fsdp,
                                  seq_shard=seq_shard, overrides=overrides)
    if lowered is None:
        print(f"SKIP  {arch:28s} {shape_name:12s} {meta['skipped']}")
        return meta
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cfg, shape = meta.pop("cfg"), meta.pop("shape_obj")
    n_active = active_param_count(cfg, configs.params_specs(cfg))
    res = roofline.analyze(compiled, cfg, shape, meta["n_devices"], n_active)
    res.update(meta)
    res.update({
        "active_params": n_active,
        "dense_equiv_active_params": dense_equiv_params(cfg),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    })
    mem = res["memory_analysis"]
    gb = mem.get("peak_bytes_est", 0) / 2**30
    print(f"OK    {arch:28s} {shape_name:12s} mesh={'multi' if multi_pod else 'single'} "
          f"peak={gb:6.2f}GiB/dev flops/dev={res['flops_per_device']:.3e} "
          f"compute={res['compute_s']*1e3:8.2f}ms memory={res['memory_s']*1e3:8.2f}ms "
          f"coll={res['collective_s']*1e3:8.2f}ms dom={res['bottleneck']:10s} "
          f"useful={res['useful_flops_ratio']:.2f} compile={t_compile:.0f}s")
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{linear_spec}" + tag_suffix
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(res, f, indent=1, default=str)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--linear", default="dyad_it_4")
    ap.add_argument("--fsdp", default=None, type=lambda s: s == "1")
    ap.add_argument("--sp", action="store_true", help="sequence-shard residual")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    archs = configs.ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(configs.SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    failures = []
    for mp in meshes:
        outdir = os.path.join(args.outdir, mp)
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, multi_pod=(mp == "multi"),
                             linear_spec=args.linear, fsdp=args.fsdp,
                             outdir=outdir, seq_shard=args.sp,
                             tag_suffix="__sp" if args.sp else "")
                except Exception as e:  # noqa: BLE001
                    failures.append((mp, arch, shape, repr(e)))
                    print(f"FAIL  {arch:28s} {shape:12s} {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES"); raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
