"""Loop-aware statistics from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each computation ONCE — a
scan-over-layers model reports ~1 layer of FLOPs (verified empirically).
This parser recovers honest per-device numbers by walking the computation
graph with **while-loop trip multipliers** (trip counts come from the loop
condition's comparison constant):

* ``flops``      — 2 * result_elems * contracted_elems for every ``dot``
                   (matmul-only: the >99% term for transformer workloads;
                   cross-checked against cost_analysis on loop-free modules);
* ``bytes``      — HBM traffic model: operand + result bytes of every
                   non-free top-level instruction (post-fusion boundaries
                   are exactly the HBM<->VMEM transfers);
* ``wire_bytes`` — ring-model bytes for every collective (all-reduce 2(g-1)/g,
                   all-gather/all-to-all (g-1)/g, reduce-scatter (g-1),
                   collective-permute 1), group size g from replica_groups.

All numbers are per device: SPMD modules are per-device programs.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?))\s*([\w\-]+)\(")
# NB: the while operand list may embed a tuple TYPE with its own parens —
# `while((s32[], f32[8,8]) %tuple), condition=...` — so the operand part
# cannot be matched with [^)]*; anchor on the attribute names instead.
_WHILE_RE = re.compile(
    r"\bwhile\(.*condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "reshape",
    "while", "conditional", "call", "custom-call",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems(type_str: str) -> int:
    n_total = 0
    for _, dims in _dims(type_str):
        n = 1
        for d in dims:
            n *= d
        n_total += n
    return n_total


def _wire(op: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    return {
        "all-reduce": 2.0 * result_bytes * (g - 1) / g,
        "all-gather": result_bytes * (g - 1) / g,
        "reduce-scatter": float(result_bytes * (g - 1)),
        "all-to-all": result_bytes * (g - 1) / g,
        "collective-permute": float(result_bytes),
    }.get(op, 0.0)


class _Comp:
    def __init__(self):
        self.lines: List[str] = []
        self.shapes: Dict[str, str] = {}     # instr name -> type str
        self.dus_update_bytes: int = 0       # in-place stash update size
        self.param_names: Dict[str, int] = {}    # parameter name -> index
        self.param_effective: Dict[int, int] = {}  # index -> sliced bytes


def _parse(text: str):
    comps: Dict[str, _Comp] = {}
    cur, entry = None, None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = _Comp()
                if m.group(1):
                    entry = cur
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        s = line.strip()
        comps[cur].lines.append(s)
        im = _INSTR_RE.match(s)
        if im:
            name_, rtype_, op_ = im.groups()
            comps[cur].shapes[name_] = rtype_
            if op_ == "parameter":
                pm = re.search(r"parameter\((\d+)\)", s)
                if pm:
                    comps[cur].param_names[name_] = int(pm.group(1))
            elif op_ == "dynamic-update-slice":
                body = s[s.index("("):]
                opnds = _OPND_RE.findall(body.split(")")[0])
                upd = (comps[cur].shapes.get(opnds[1])
                       if len(opnds) > 1 else None)
                if upd:
                    comps[cur].dus_update_bytes = max(
                        comps[cur].dus_update_bytes, _bytes(upd))
            elif op_ == "dynamic-slice":
                # a parameter consumed via dynamic-slice costs the SLICE,
                # not the whole (e.g. stacked-layer-weights) buffer
                body = s[s.index("("):]
                opnds = _OPND_RE.findall(body.split(")")[0])
                if opnds and opnds[0] in comps[cur].param_names:
                    idx = comps[cur].param_names[opnds[0]]
                    eff = _bytes(rtype_)
                    prev = comps[cur].param_effective.get(idx)
                    comps[cur].param_effective[idx] = (
                        eff if prev is None else max(prev, eff))
    return comps, entry


_NAMED_CONST_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_COMPARE_RE = re.compile(
    r"compare\(([^)]*)\),\s*direction=(LT|GT|LE|GE|NE)")


def _trip_count(comp: _Comp) -> int:
    """Trip count = the constant operand of the loop condition's compare.
    (Taking any max constant in the computation over-counts: conditions can
    embed unrelated constants.)"""
    consts = {}
    for line in comp.lines:
        m = _NAMED_CONST_RE.match(line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in comp.lines:
        m = _COMPARE_RE.search(line)
        if m:
            for opnd in _OPND_RE.findall(m.group(1)):
                if opnd in consts:
                    return max(consts[opnd], 1)
    # fallback: smallest plausible constant (conservative)
    return min(consts.values()) if consts else 1


def _multipliers(comps, entry) -> Dict[str, float]:
    mult = {entry: 1.0}
    frontier = [entry]
    while frontier:
        name = frontier.pop()
        for line in comps[name].lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps[cond]) if cond in comps else 1
                mult[body] = mult.get(body, 0.0) + mult[name] * trips
                frontier.append(body)
            else:
                cm = re.search(r"(?:calls)=%?([\w.\-]+)", line)
                if cm and cm.group(1) in comps and cm.group(1) not in mult:
                    # fusions: counted at the call site, not walked into
                    pass
    return mult


def _callee_dus(line: str, comps) -> int:
    """If this fusion's called computation performs an in-place
    dynamic-update-slice on a loop-carried buffer, return the slice bytes."""
    m = re.search(r"calls=%?([\w.\-]+)", line)
    if not m:
        return 0
    callee = comps.get(m.group(1))
    return callee.dus_update_bytes if callee else 0


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def module_stats(text: str, n_devices: int) -> dict:
    comps, entry = _parse(text)
    mult = _multipliers(comps, entry)

    flops = bytes_ = wire = raw = 0.0
    coll_count = 0
    by_op: Dict[str, float] = {}
    for name, comp in comps.items():
        w = mult.get(name, 0.0)
        if w <= 0:
            continue
        for line in comp.lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            _, rtype, opcode = im.groups()

            if opcode == "dot":
                cd = _CDIMS_RE.search(line)
                body = line[line.index("("):]
                opnds = _OPND_RE.findall(body.split(")")[0])
                lhs = comp.shapes.get(opnds[0]) if opnds else None
                k = 1
                if cd and lhs:
                    ldims = _dims(lhs)[0][1]
                    for d in cd.group(1).split(","):
                        if d:
                            k *= ldims[int(d)]
                flops += w * 2.0 * _elems(rtype) * k

            base = opcode.replace("-start", "")
            if base in _COLLECTIVES and not opcode.endswith("-done"):
                rb = _bytes(rtype)
                g = _group_size(line, n_devices)
                ww = _wire(base, rb, g) * w
                wire += ww
                raw += rb * w
                coll_count += int(w)
                by_op[base] = by_op.get(base, 0.0) + ww

            if opcode.endswith("-done"):
                continue        # bytes counted at the matching -start
            if opcode in _FREE_OPS and base not in _COLLECTIVES:
                continue
            body = line[line.index("("):]
            opnds = _OPND_RE.findall(body.split(")")[0])
            # in-place slice updates touch only the SLICE, not the buffer
            # (XLA aliases the operand; counting the full buffer per loop
            # iteration fabricated TBs of phantom traffic — §Perf A5)
            if opcode == "dynamic-update-slice":
                upd = (comp.shapes.get(opnds[1]) if len(opnds) > 1 else None)
                b = 2 * _bytes(upd) if upd else 2 * _bytes(rtype)
            elif opcode == "dynamic-slice":
                b = 2 * _bytes(rtype)
            elif opcode == "fusion" and _callee_dus(line, comps):
                # fusion that updates a loop-carried stash in place:
                # read slice + write slice (+ a convert pass)
                b = 3 * _callee_dus(line, comps)
            else:
                # HBM traffic: result + operands (post-fusion boundaries),
                # with slab-parameters that the callee only dynamic-slices
                # priced at the slice size
                callee = None
                if opcode == "fusion":
                    cm = re.search(r"calls=%?([\w.\-]+)", line)
                    callee = comps.get(cm.group(1)) if cm else None
                b = _bytes(rtype)
                for i, op_name in enumerate(opnds):
                    if op_name not in comp.shapes:
                        continue
                    full = _bytes(comp.shapes[op_name])
                    if callee is not None and i in callee.param_effective:
                        b += min(full, 2 * callee.param_effective[i])
                    else:
                        b += full
            bytes_ += w * b

    return {"flops": flops, "bytes": bytes_, "wire_bytes": wire,
            "raw_collective_bytes": raw, "collective_count": coll_count,
            "collectives_by_op": by_op}
