"""Launchers: mesh construction, multi-pod dry-run, train, serve.

NOTE: do not import repro.launch.dryrun from library code — it sets
XLA_FLAGS for 512 placeholder devices at import time (dry-run only).
"""
from repro.launch.mesh import make_production_mesh, make_rules, make_test_mesh  # noqa: F401
