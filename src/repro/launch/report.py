"""Assemble EXPERIMENTS.md from dry-run JSONs + the perf iteration log.

    PYTHONPATH=src python -m repro.launch.report \
        --dryrun-dir experiments/dryrun --out EXPERIMENTS.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro import configs

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _load(dryrun_dir: str, mesh: str):
    cells = {}
    for f in glob.glob(os.path.join(dryrun_dir, mesh, "*.json")):
        r = json.load(open(f))
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        tag = "__".join(parts[3:]) if len(parts) > 3 else "base"
        key = (r["arch"], r["shape"], r.get("linear", "?"), tag)
        cells[key] = r
    return cells


def variants_table(cells, triples):
    """Side-by-side §Perf points: (arch, shape, [(label, linear, tag), ...])."""
    rows = ["| cell | variant | peak GiB/dev | ff hidden GiB/dev | ff weights GiB/dev | compute s | memory s | collective s | bound s | useful |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for arch, shape, variants in triples:
        for label, linear, tag in variants:
            r = cells.get((arch, shape, linear, tag))
            if r is None:
                continue
            bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
            peak = r["memory_analysis"].get("peak_bytes_est", 0) / 2**30
            # per-shard ff-hidden traffic from dryrun.ff_route_accounting:
            # 0 for the fused route (hidden stays in VMEM), absent in JSONs
            # predating the TP kernels
            hb = r.get("ff_hidden_bytes_est")
            hidden = "n/a" if hb is None else f"{hb / 2**30:.2f}"
            # per-shard ff WEIGHT stream per step; int8/fp8 payloads show
            # the quantized dtype next to the shrunken byte count.  Absent
            # in JSONs predating quantized serving.
            wb = r.get("ff_weight_bytes_est")
            weights = "n/a" if wb is None else f"{wb / 2**30:.2f}"
            if wb is not None and r.get("ff_weight_quant"):
                weights += f" ({r['ff_weight_quant']})"
            rows.append(
                f"| {arch}/{shape} | {label} | {peak:.1f} | {hidden} | "
                f"{weights} | "
                f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                f"{r['collective_s']:.3f} | {bound:.3f} | "
                f"{r['useful_flops_ratio']:.2f} |")
    return "\n".join(rows)


def _fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def _skip_reason(arch, shape_name):
    cfg = configs.get(arch)
    ok, reason = configs.cell_runnable(cfg, configs.SHAPES[shape_name])
    return None if ok else reason


def dryrun_table(cells, linear="dyad_it_4", variant="base"):
    rows = ["| arch | shape | peak GiB/dev | params GiB/dev | FLOPs/dev | HBM GB/dev | wire GB/dev | #coll | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in configs.ARCHS:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, linear, variant))
            if r is None:
                reason = _skip_reason(arch, shape)
                if reason:
                    rows.append(f"| {arch} | {shape} | SKIP | | {reason} | | | | |")
                continue
            mem = r["memory_analysis"]
            rows.append(
                f"| {arch} | {shape} | {_fmt_bytes(mem.get('peak_bytes_est', 0))} "
                f"| {_fmt_bytes(mem.get('argument_bytes', 0))} "
                f"| {r['flops_per_device']:.3e} "
                f"| {r['bytes_per_device'] / 1e9:.1f} "
                f"| {r['collective']['wire_bytes'] / 1e9:.2f} "
                f"| {r['collective']['count']} | {r['compile_s']:.0f} |")
    return "\n".join(rows)


def roofline_table(cells, linear="dyad_it_4", variant="base"):
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in configs.ARCHS:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, linear, variant))
            if r is None:
                continue
            rows.append(
                f"| {arch} | {shape} | {r['compute_s']:.4f} | "
                f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                f"**{r['bottleneck']}** | {r['model_flops_global']:.3e} | "
                f"{r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--perf-log", default="experiments/perf_log.md")
    ap.add_argument("--preamble", default="experiments/preamble.md")
    ap.add_argument("--bench", default="bench_output.txt")
    ap.add_argument("--out", default="EXPERIMENTS.md")
    args = ap.parse_args()

    single = _load(args.dryrun_dir, "single")
    multi = _load(args.dryrun_dir, "multi")

    parts = ["# EXPERIMENTS\n"]
    if os.path.exists(args.preamble):
        parts.append(open(args.preamble).read())

    parts.append("\n## §Dry-run — single pod (16x16 = 256 chips)\n")
    parts.append(dryrun_table(single))
    parts.append("\n\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    parts.append(
        "Proves the `pod` axis shards: every runnable cell lowers AND "
        "compiles on the 512-chip mesh.\n")
    parts.append(dryrun_table(multi))

    parts.append("\n\n## §Roofline — single pod, per-device terms\n")
    parts.append(
        "`compute_s = HLO_FLOPs/197e12`, `memory_s = HLO_bytes/819e9`, "
        "`collective_s = ring-model wire bytes/50e9`; all per device from "
        "loop-aware HLO parsing (see repro/launch/hlo_stats.py). "
        "`useful` = 6·N·D (or inference analog) / global HLO FLOPs.\n")
    parts.append(roofline_table(single))

    parts.append("\n\n## §Perf — paper-faithful baseline vs optimized "
                 "(hillclimbed cells)\n")
    parts.append(variants_table(single, [
        ("qwen3_0_6b", "train_4k", [
            ("DENSE (paper baseline)", "dense", "base"),
            ("DYAD-IT(4) faithful", "dyad_it_4", "base"),
            ("DYAD-IT(4) fused ff [beyond-paper]", "dyad_it_4_fused", "base"),
            ("DYAD-IT(8) fused ff", "dyad_it_8_fused", "base"),
            ("DYAD-IT(4) ff megakernel [TP]", "dyad_it_4_kernel_ffused",
             "base"),
        ]),
        ("llama4_maverick_400b_a17b", "train_4k", [
            ("DENSE (paper baseline)", "dense", "base"),
            ("DYAD-IT(4) + EP anchors [B1+B2]", "dyad_it_4", "base"),
            ("  + accum=2 [B3, not adopted]", "dyad_it_4", "accum2"),
        ]),
        ("llama3_405b", "train_4k", [
            ("DENSE (paper baseline)", "dense", "base"),
            ("DYAD-IT(4) faithful", "dyad_it_4", "base"),
            ("DYAD-IT(4) fused ff [C3]", "dyad_it_4_fused", "base"),
            ("  + sequence-parallel [C1, mixed]", "dyad_it_4", "sp"),
            ("  + accum=4 [C2, not adopted]", "dyad_it_4", "accum4"),
        ]),
    ]))

    if os.path.exists(args.perf_log):
        parts.append("\n\n## §Perf — hillclimbing log\n")
        parts.append(open(args.perf_log).read())

    if os.path.exists(args.bench):
        parts.append(
            "\n\n## §Benchmarks (paper-table analogs, CPU)\n\n"
            "Reading guide: `quality_*` reproduces the paper's parity claim "
            "(all DYAD variants ≥ 0.99 of DENSE learning gain; bar is 0.90). "
            "`width_*` reproduces Fig 6's trend (speedup grows with width). "
            "Wall-clock `ratio`s are single-core-CPU GEMM artifacts — one "
            "large matmul beats batched small blocks on this host; the "
            "`flop_bound` column and the §Roofline compute terms carry the "
            "TPU-target speedup (paper's V100 numbers benefited from kernel-"
            "launch amortization that XLA/CPU does not exhibit).\n```\n")
        parts.append(open(args.bench).read())
        parts.append("```\n")

    with open(args.out, "w") as f:
        f.write("\n".join(parts))
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
