"""Roofline analysis from compiled artifacts (DESIGN §6).

Three terms per (arch x shape x mesh), all **per device** (SPMD modules are
per-device programs; XLA's cost_analysis already reports per-device numbers):

    compute_s    = HLO_FLOPs / peak_flops
    memory_s     = HLO_bytes / hbm_bw
    collective_s = wire_bytes / ici_bw

``wire_bytes`` comes from parsing the optimized HLO: every
all-reduce/all-gather/reduce-scatter/all-to-all/collective-permute is counted
with ring-model wire bytes (result bytes scaled by (g-1)/g terms, group size g
from replica_groups), and ops inside ``while`` loops are multiplied by the
loop trip count (recovered from the loop condition's comparison constant —
this is what makes scan-over-layers accounting honest).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# the while operand list may embed a tuple type with nested parens — anchor
# on the attribute names (see hlo_stats._WHILE_RE)
_WHILE_RE = re.compile(
    r"\bwhile\(.*condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(op: str, result_bytes: int, g: int) -> float:
    """Ring-model wire bytes per device."""
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-gather":
        return result_bytes * (g - 1) / g          # result is the full gather
    if op == "reduce-scatter":
        return result_bytes * (g - 1)              # result is the shard
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


def _parse_computations(text: str) -> Dict[str, list]:
    comps, cur, entry = {}, None, None
    for line in text.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            elif line.startswith("}"):
                cur = None
        elif cur is not None:
            comps[cur].append(line.strip())
    comps["__entry__"] = [entry]
    return comps


def _trip_count(cond_lines: list) -> int:
    """Loop trip count from the condition's comparison constant."""
    consts = [int(m.group(1)) for line in cond_lines
              for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


def collective_stats(hlo_text: str, n_devices: int) -> dict:
    comps = _parse_computations(hlo_text)
    entry = comps.pop("__entry__")[0]

    # multiplier per computation: while bodies run trip-count times
    mult: Dict[str, float] = {entry: 1.0}
    frontier = [entry]
    while frontier:
        name = frontier.pop()
        for line in comps.get(name, ()):
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond, []))
                mult[body] = mult.get(body, 0.0) + mult[name] * trips
                frontier.append(body)
            for cm in re.finditer(r"(?:calls|body)=%?([\w.\-]+)", line):
                callee = cm.group(1)
                if callee in comps and callee not in mult:
                    mult[callee] = mult[name]
                    frontier.append(callee)

    total_wire, total_raw, count = 0.0, 0.0, 0
    by_op: Dict[str, float] = {}
    for name, lines in comps.items():
        w = mult.get(name)
        if not w:
            continue
        for line in lines:
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            op = cm.group("op")
            rb = _shape_bytes(cm.group("type"))
            g = _group_size(line, n_devices)
            wire = _wire_bytes(op, rb, g) * w
            total_wire += wire
            total_raw += rb * w
            count += int(w)
            by_op[op] = by_op.get(op, 0.0) + wire
    return {"wire_bytes": total_wire, "raw_bytes": total_raw,
            "count": count, "by_op": by_op}


def model_flops(cfg, shape, n_active_params: int) -> float:
    """6*N*D (train) / 2*N*D (inference), N = active non-embedding params."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active_params * B * S
    if shape.kind == "prefill":
        return 2.0 * n_active_params * B * S
    return 2.0 * n_active_params * B          # decode: one token per row


def analyze(compiled, cfg, shape, n_devices: int,
            n_active_params: int) -> dict:
    from repro.launch import hlo_stats

    # cost_analysis counts while bodies ONCE (verified) — the loop-aware HLO
    # parser is the source of truth; cost_analysis kept as a cross-check.
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    stats = hlo_stats.module_stats(compiled.as_text(), n_devices)
    flops = stats["flops"]
    bytes_ = stats["bytes"]
    coll = {"wire_bytes": stats["wire_bytes"],
            "raw_bytes": stats["raw_collective_bytes"],
            "count": stats["collective_count"],
            "by_op": stats["collectives_by_op"]}

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    collective_s = coll["wire_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, n_active_params)
    useful = mf / max(flops * n_devices, 1.0)

    mem = compiled.memory_analysis()
    mem_stats = {}
    if mem is not None:
        mem_stats = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        }
        mem_stats["peak_bytes_est"] = (
            mem_stats["argument_bytes"] + mem_stats["temp_bytes"]
            + mem_stats["output_bytes"] - mem_stats["alias_bytes"])

    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "cost_analysis_flops_unrolled_once": float(ca.get("flops", 0.0)),
        "collective": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "step_s_bound": max(terms.values()),
        "model_flops_global": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": compute_s / max(terms.values())
        if max(terms.values()) > 0 else 0.0,
        "memory_analysis": mem_stats,
    }
