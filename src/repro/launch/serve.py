"""Serving launcher: initialize (or restore) a model and run batched
generation — the interactive counterpart of the decode_* dry-run cells.

Two engines (``--engine``):

* ``batch`` (default) — :class:`repro.serve.Engine`: one jitted single-pass
  prefill for the whole (B, S) int32 prompt batch, then one jitted
  ``lax.scan`` for the whole decode loop.  Output: (B, new_tokens) int32.
* ``continuous`` — :class:`repro.serve.ContinuousBatchingEngine`: submits
  ``--requests`` prompts with heterogeneous lengths into ``--slots`` cache
  slots; finished sequences retire at EOS/length and queued requests
  back-fill freed slots, all through one jitted padded-batch step.

The KV/SSM cache is allocated once at ``prompt_len + new_tokens`` (fp32 by
default; see ``Engine(cache_dtype=...)``) and persists across the decode.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --batch 4 --prompt-len 16 --new-tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_0_6b --smoke \
        --engine continuous --requests 12 --slots 4
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp

from repro import configs, faults, obs
from repro.checkpoint import CheckpointManager
from repro.models import model
from repro.serve import ContinuousBatchingEngine, Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--linear", default=None)
    ap.add_argument("--engine", choices=("batch", "continuous"),
                    default="batch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=12,
                    help="continuous engine: number of submitted requests")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous engine: cache slots (padded batch)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="continuous engine: retire sequences at this token")
    ap.add_argument("--page-size", type=int, default=None,
                    help="continuous engine: paged KV cache with this many "
                         "tokens per page (default: dense per-slot rings)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="paged mode: physical pages in the pool incl. "
                         "scratch (default: full-capacity slots)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="paged mode: prefill prompts in chunks of this "
                         "many tokens, interleaved with decode steps")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="paged mode: share full prompt-prefix pages "
                         "between requests (skips re-prefill)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record runtime spans (admission/prefill/decode/"
                         "sync/retire) and export Chrome-trace JSON here — "
                         "open in ui.perfetto.dev, diff two runs with "
                         "python -m repro.perf.timeline")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the final serving metrics snapshot (TTFT/"
                         "ITL percentiles, tok/s, queue depth, page-pool "
                         "occupancy, prefix hits) as JSON")
    ap.add_argument("--report-every", type=float, default=None,
                    metavar="SECONDS",
                    help="continuous engine: periodic one-line stats report")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="fault-injection schedule, e.g. 'page_exhaustion:"
                         "p=0.05;nan_logits:at_step=3;slow_step:ms=50' "
                         "(overrides REPRO_FAULT; see repro.faults)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="continuous engine: per-request wall-clock budget; "
                         "expired requests retire with reason=deadline "
                         "keeping their partial output")
    ap.add_argument("--tp", type=int, default=1,
                    help="shard the model axis over this many devices: "
                         "dispatches the shard_map TP kernels "
                         "(kernels/tp.py) when d_ff / KV heads divide, "
                         "einsum fallback (visible in --metrics-json "
                         "routes) otherwise.  On CPU force devices first: "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel axis size (dp * tp must equal the "
                         "visible device count when either exceeds 1)")
    ap.add_argument("--quant-weights", choices=("int8", "fp8"), default=None,
                    help="quantize the DYAD ff weights offline "
                         "(repro.quant.quantize_params sidecars) and stream "
                         "them through the in-kernel-dequant bodies; "
                         "requires a kernel-routed linear spec.  "
                         "REPRO_KERNEL_QUANT=off restores fp32 routes")
    ap.add_argument("--quant-kv", choices=("int8",), default=None,
                    help="paged mode: int8 KV page pools with per-token-row "
                         "fp32 scale pools, dequantized in-kernel at decode "
                         "(~2-4x more tokens per HBM byte)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="pre-tune Pallas kernel tiles for this model's "
                         "dyad shapes before compiling (repro.perf); only "
                         "meaningful with a kernel-routed linear spec, "
                         "e.g. --linear dyad_it_4_kernel")
    args = ap.parse_args()

    if args.trace:
        obs.enable()
    if args.faults:
        faults.configure(args.faults, seed=args.fault_seed)

    # engines capture the ambient mesh at construction (per-shard autotune
    # keys) and the layer dispatch consults it at trace time, so the whole
    # run sits inside one activation-sharding context
    mesh_ctx = contextlib.nullcontext()
    if args.tp > 1 or args.dp > 1:
        from repro.launch.mesh import make_test_mesh
        from repro.sharding import ctx as shard_ctx
        mesh = make_test_mesh((args.dp, args.tp))
        mesh_ctx = shard_ctx.activation_sharding(mesh, dp=("data",),
                                                 model="model")
        print(f"[serve] mesh: data={args.dp} model={args.tp}")
    with mesh_ctx:
        _run(args)


def _run(args):
    linear = configs.linear_cfg(args.linear) if args.linear else None
    cfg = configs.get(args.arch, smoke=args.smoke, linear=linear)
    if args.quant_weights:
        cfg = cfg.replace(linear=cfg.linear.replace(quant=args.quant_weights))
    if args.quant_kv:
        if args.engine != "continuous" or args.page_size is None:
            raise SystemExit("--quant-kv requires --engine continuous with "
                             "--page-size (the quantized layout is the "
                             "paged pool)")
        cfg = cfg.replace(kv_quant=args.quant_kv)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(cfg, key)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        if mgr.latest_step() is not None:
            step, state = mgr.restore({"params": params})
            params = state["params"]
            print(f"[serve] restored checkpoint step {step}")
    if args.quant_weights:
        from repro import quant
        params = quant.quantize_params(params, args.quant_weights)
        print(f"[serve] quantized DYAD weight sidecars: {args.quant_weights}")

    max_len = args.prompt_len + args.new_tokens

    if args.engine == "continuous":
        engine = ContinuousBatchingEngine(
            cfg, params, n_slots=args.slots, max_len=max_len,
            eos_id=args.eos_id, temperature=args.temperature, seed=args.seed,
            autotune=args.autotune, page_size=args.page_size,
            n_pages=args.n_pages, prefill_chunk=args.prefill_chunk,
            prefix_cache=args.prefix_cache,
            report_every_s=args.report_every)
        lengths = [max(1, args.prompt_len - (i % 4)) for i in range(args.requests)]
        prompts = [
            jax.random.randint(jax.random.fold_in(key, i), (lengths[i],), 0,
                               cfg.vocab_size)
            for i in range(args.requests)]
        t0 = time.perf_counter()
        uids = [engine.submit(p, args.new_tokens,
                              deadline_s=args.deadline_s) for p in prompts]
        results = engine.run()
        dt = time.perf_counter() - t0
        total = sum(len(results[u]) for u in uids)
        print(f"[serve] continuous: {args.requests} requests over "
              f"{args.slots} slots, {total} tokens in {dt:.2f}s "
              f"({total / dt:.1f} tok/s)")
        if engine.paged:
            print(f"[serve] paged: {engine.stats}")
        if faults.active():
            print(f"[serve] faults: {faults.snapshot()} "
                  f"demoted={engine.demoted}")
        print({u: results[u][:8] for u in uids[:4]})
        print(f"[serve] summary: {engine.format_summary()}")
        _finish(args, engine.metrics)
        return

    engine = Engine(cfg, params, max_len=max_len, autotune=args.autotune)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(
            key, (args.batch, cfg.n_frames, cfg.frontend_dim), cfg.cdtype)

    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens,
                          temperature=args.temperature, key=key,
                          frames=frames)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(out[:, :16])
    print(f"[serve] summary: {obs.format_serving_line(engine.metrics)}")
    _finish(args, engine.metrics)


def _finish(args, metrics):
    """Export the trace / metrics snapshot requested on the CLI."""
    if args.metrics_json:
        # route-dispatch counters ride along: ff_tp/attn_tp tp_fused vs
        # tp_fallback make a silently lost kernel route visible here.
        metrics.write_json(args.metrics_json, routes=obs.routes_snapshot(),
                           faults=faults.snapshot())
        print(f"[serve] metrics: {args.metrics_json}")
    if args.trace:
        t = obs.get_tracer()
        n = len(t) if t else 0
        obs.export(args.trace)
        print(f"[serve] trace: {args.trace} ({n} events) — open in "
              f"ui.perfetto.dev")


if __name__ == "__main__":
    main()
