"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (device count is locked at first jax init)."""
from __future__ import annotations

import jax

from repro.sharding.rules import MeshRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_rules(*, multi_pod: bool = False, fsdp: bool = False) -> MeshRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    return MeshRules(model="model", dp=dp, fsdp=("data",) if fsdp else None)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires XLA_FLAGS host device count)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
