"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (device count is locked at first jax init).

``compat_make_mesh`` / ``compat_shard_map`` paper over the JAX API drift
around meshes and shard_map (``jax.sharding.AxisType`` + the ``axis_types=``
kwarg and ``jax.shard_map``/``check_vma`` only exist in newer releases;
older ones have plain ``jax.make_mesh`` and
``jax.experimental.shard_map.shard_map``/``check_rep``) — every mesh the
repo builds, including the SPMD tests', goes through these shims so tier-1
stays green across the supported JAX range.
"""
from __future__ import annotations

import jax

from repro.sharding.rules import MeshRules


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across JAX versions.

    Newer JAX: pass ``axis_types=(AxisType.Auto, ...)`` explicitly (Auto is
    the sharding-in-types default we rely on).  Older JAX: no such kwarg and
    Auto semantics are implicit — call plain ``make_mesh``; if even that is
    missing, fall back to ``Mesh`` over a reshaped device grid.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    make = getattr(jax, "make_mesh", None)
    if make is None:
        import numpy as np

        n = int(np.prod(shape))
        devices = np.asarray(jax.devices()[:n]).reshape(shape)
        return jax.sharding.Mesh(devices, axes)
    if axis_type is None:
        return make(shape, axes)
    return make(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def compat_shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across JAX versions.  The replication-check kwarg
    was renamed ``check_rep`` -> ``check_vma`` independently of shard_map's
    move out of jax.experimental, so detect the spelling from the signature
    rather than from where the function lives."""
    import inspect

    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    try:
        params = inspect.signature(sm).parameters
    except (TypeError, ValueError):
        params = {}
    kw = {"check_vma" if "check_vma" in params else "check_rep": check_vma}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_rules(*, multi_pod: bool = False, fsdp: bool = False) -> MeshRules:
    dp = ("pod", "data") if multi_pod else ("data",)
    return MeshRules(model="model", dp=dp, fsdp=("data",) if fsdp else None)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires XLA_FLAGS host device count)."""
    return compat_make_mesh(shape, axes)
