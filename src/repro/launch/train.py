"""Training launcher.

Single-host (this container) it runs real steps on the local device(s); on a
real cluster the same entrypoint runs under ``jax.distributed.initialize()``
(multi-host: one process per host, the data pipeline shards by process index,
and the mesh comes from ``mesh.make_production_mesh``).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch opt125m --smoke \
        --steps 100 --linear dyad_it_4
    PYTHONPATH=src python -m repro.launch.train --arch qwen3_0_6b --smoke \
        --steps 50 --linear dense --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import signal

import jax

from repro import configs, faults, obs
from repro.data import SyntheticLM
from repro.optim import AdamW, Compressor, schedule
from repro.train import Trainer, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--linear", default=None,
                    help="dense | dyad_<variant>_<n>[_cat]")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record train_step/checkpoint/autotune spans and "
                         "export Chrome-trace JSON here (ui.perfetto.dev)")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the final training metrics snapshot as JSON")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="fault-injection schedule, e.g. "
                         "'nan_loss:at_step=5;ckpt_io:p=0.3;slow_step:ms=20' "
                         "(overrides REPRO_FAULT; see repro.faults)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--nan-strikes", type=int, default=3,
                    help="consecutive non-finite steps before rolling back "
                         "to the last checkpoint")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="pre-tune Pallas kernel tiles (forward AND the "
                         "dgrad/wgrad backward ops) for this model's dyad "
                         "shapes before the train step compiles "
                         "(repro.perf); only meaningful with a "
                         "kernel-routed linear spec, e.g. "
                         "--linear dyad_it_4_kernel")
    args = ap.parse_args()

    if args.trace:
        obs.enable()
    if args.faults:
        faults.configure(args.faults, seed=args.fault_seed)

    linear = configs.linear_cfg(args.linear) if args.linear else None
    cfg = configs.get(args.arch, smoke=args.smoke, linear=linear)
    print(f"[train] arch={cfg.name} family={cfg.family} "
          f"linear={cfg.linear.impl}({cfg.linear.variant},n={cfg.linear.n_dyad})")

    if args.autotune:
        # tune BEFORE the first jit trace: the train step's value_and_grad
        # resolves fwd + dgrad/wgrad tiles at trace time (batch*seq rows).
        from repro.perf.autotune import ensure_tuned_for_model

        # seq_len additionally covers the flash_prefill tiles the training
        # forward resolves for flash_attn configs
        tuned = ensure_tuned_for_model(cfg, tokens=args.batch * args.seq_len,
                                       include_bwd=True,
                                       seq_len=args.seq_len)
        print(f"[train] autotuned {len(tuned)} kernel-shape entries")

    opt = AdamW(lr=schedule.warmup_cosine(args.lr, args.steps // 10 + 1,
                                          args.steps))
    comp = Compressor(codec=args.compress)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                       global_batch=args.batch, seed=args.seed,
                       shard=jax.process_index(),
                       num_shards=jax.process_count())
    state = init_train_state(cfg, opt, jax.random.PRNGKey(args.seed),
                             compressor=comp)
    step = jax.jit(make_train_step(cfg, opt, compressor=comp),
                   donate_argnums=0)

    trainer = Trainer(step, state, data, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every, log_every=10,
                      nan_strikes=args.nan_strikes)
    # SIGTERM (spot reclaim / scheduler) AND SIGINT (operator ctrl-C) both
    # end the run through the same path: finish the in-flight step, write a
    # final blocking checkpoint, exit 0 — the next launch auto-resumes.
    trainer.install_preemption_handler(
        signals=(signal.SIGTERM, signal.SIGINT))
    _, metrics = trainer.run(args.steps)
    if trainer._preempted:
        print(f"[train] preempted at step {trainer.step}: checkpoint saved, "
              "relaunch to resume")
    loss = float(metrics["loss"]) if "loss" in metrics else float("nan")
    print(f"[train] done at step {trainer.step}: loss={loss:.4f} "
          f"stragglers={len(trainer.straggler_events)}")
    snap = trainer.metrics.snapshot()
    h = snap["histograms"].get("step_time_s")
    if h:
        print(f"[train] summary: steps={h['count']} "
              f"step_ms p50={h['p50'] * 1e3:.1f} p99={h['p99'] * 1e3:.1f} "
              f"tok/s={snap['gauges'].get('tokens_per_s', {}).get('value', 0):.0f} "
              f"stragglers={snap['counters'].get('straggler_count', 0)}")
    if args.metrics_json:
        trainer.metrics.write_json(args.metrics_json,
                                   faults=faults.snapshot())
        print(f"[train] metrics: {args.metrics_json}")
    if args.trace:
        obs.export(args.trace)
        print(f"[train] trace: {args.trace} — open in ui.perfetto.dev")


if __name__ == "__main__":
    main()
