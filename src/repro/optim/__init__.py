"""Optimizer substrate: AdamW, schedules, gradient compression."""
from repro.optim.adamw import AdamW, global_norm  # noqa: F401
from repro.optim.compress import Compressor, compressed_psum  # noqa: F401
from repro.optim import schedule  # noqa: F401
