"""Gradient compression for data-parallel reduction, with error feedback.

Two codecs:

* ``int8`` — per-leaf symmetric quantization (scale = max|g| / 127).
* ``topk`` — keep the top-``k`` fraction of entries by magnitude.

Both are wrapped in error feedback (the residual between the true and the
compressed gradient is carried to the next step), which is what makes lossy
reduction converge.  ``compressed_psum`` is the explicit-collective form used
under ``shard_map``: all-gather the int8 payload + per-shard scales, dequantize
and sum locally — 4x fewer collective bytes than an fp32 all-reduce.

In the pure-GSPMD train step the framework's equivalent lever is bf16
gradients (2x), which the roofline's collective term sees directly.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# ONE symmetric-quant codec in the repo: the per-tensor int8 helpers now
# live in repro.quant (which also provides the per-block weight / per-row
# KV variants the serving kernels use) and are re-exported here for the
# gradient compressor's historical import surface.
from repro.quant import dequant_int8 as _dequant_int8  # noqa: F401
from repro.quant import quant_int8 as _quant_int8  # noqa: F401


def _topk_mask(g, frac: float):
    k = max(1, int(g.size * frac))
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


@dataclasses.dataclass(frozen=True)
class Compressor:
    codec: str = "int8"        # "int8" | "topk" | "none"
    topk_frac: float = 0.01

    def init(self, params):
        if self.codec == "none":
            return {}
        return {"err": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def compress_decompress(self, grads, state) -> Tuple[dict, dict]:
        """Simulated lossy reduction: returns (decoded grads, new state)."""
        if self.codec == "none":
            return grads, state

        def one(g, e):
            gc = g.astype(jnp.float32) + e
            if self.codec == "int8":
                q, s = _quant_int8(gc)
                dec = _dequant_int8(q, s)
            else:
                dec = gc * _topk_mask(gc, self.topk_frac)
            return dec.astype(g.dtype), gc - dec

        out = jax.tree.map(one, grads, state["err"])
        dec = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
        return dec, {"err": err}


def compressed_psum(g, axis_name: str):
    """int8 all-gather + local dequant-sum over a shard_map axis."""
    q, scale = _quant_int8(g)
    qs = jax.lax.all_gather(q, axis_name)          # (n_dev, ...) int8
    ss = jax.lax.all_gather(scale, axis_name)      # (n_dev,)
    dec = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * (q.ndim))
    return dec.sum(axis=0)
