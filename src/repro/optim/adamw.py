"""AdamW with decoupled weight decay, decay masking and configurable moment
dtype (bf16 moments = ZeRO-friendly memory for 100B+ params; see DESIGN §5)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def default_decay_mask(path, leaf) -> bool:
    """Decay matrices only (>=2D); skip norms, biases, scalars."""
    name = "/".join(str(getattr(k, "key", k)) for k in path)
    if leaf.ndim < 2:
        return False
    return not any(s in name for s in ("norm", "scale", "A_log", "dt_bias"))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]    # step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"
    # fp32 master copy: params (and their collectives) stay bf16 while the
    # update path accumulates in fp32 — the standard mixed-precision recipe.
    master: bool = False

    def init(self, params):
        md = jnp.bfloat16 if self.moment_dtype == "bfloat16" else jnp.float32
        z = lambda p: jnp.zeros(p.shape, md)
        st = {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.master:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return st

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self.lr(step)

        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        else:
            gnorm = global_norm(grads)

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        base = state.get("master", params)

        def upd(path, p, base_p, g, m, v):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            u = (m_new / c1) / (jnp.sqrt(v_new / c2) + self.eps)
            if self.weight_decay and default_decay_mask(path, p):
                u = u + self.weight_decay * base_p.astype(jnp.float32)
            p_new = base_p.astype(jnp.float32) - lr * u
            return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                    v_new.astype(v.dtype), p_new)

        out = jax.tree_util.tree_map_with_path(upd, params, base, grads,
                                               state["m"], state["v"])
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        new_params = pick(0)
        new_state = {"m": pick(1), "v": pick(2), "step": step}
        if self.master:
            new_state["master"] = pick(3)
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))
