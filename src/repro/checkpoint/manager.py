"""Fault-tolerant checkpointing: atomic, async, keep-N, path-addressed.

Layout per step::

    <dir>/ckpt_<step>/arrays.npz     # flat {key-path: array}
    <dir>/ckpt_<step>/manifest.json  # step, keys, shapes, dtypes

Writes go to ``ckpt_<step>.tmp`` and are renamed atomically, so a crash
mid-save can never corrupt the latest checkpoint; restore always picks the
newest *complete* step.  Async saves run on a worker thread (training is not
blocked by serialization); ``wait()`` joins before exit/next save.

Restore is **template-addressed**: arrays are matched to the target pytree by
key-path, so restoring into a model re-built under a *different mesh* (elastic
scaling) or into a partially-changed pytree (added buffers) is well-defined.

Writes retry with exponential backoff (transient I/O errors — and the
``ckpt_io`` fault site — are absorbed up to ``retries`` times); a write that
exhausts the budget raises :class:`repro.errors.CheckpointIOError`.  Async
save failures are captured on the worker thread and re-raised at the next
``wait()``/``save()`` — they can not vanish silently.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro import faults, obs
from repro.errors import CheckpointIOError


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def flatten_with_paths(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {_path_str(p): v for p, v in flat}


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_save: bool = True, retries: int = 3,
                 backoff_s: float = 0.05):
        self.directory = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self.retries = retries
        self.backoff_s = backoff_s
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False):
        # snapshot to host memory synchronously (cheap), serialize async.
        host = {k: np.asarray(v) for k, v in flatten_with_paths(tree).items()}
        self.wait()
        if self.async_save and not blocking:
            self._thread = threading.Thread(
                target=self._write_async, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write_async(self, step: int, host: dict):
        try:
            self._write(step, host)
        except BaseException as e:        # surfaces at the next wait()
            self._error = e

    def _write(self, step: int, host: dict):
        """Write with retry/backoff; raises :class:`CheckpointIOError` only
        after ``retries`` extra attempts all fail."""
        delay = self.backoff_s
        for attempt in range(self.retries + 1):
            try:
                self._write_once(step, host)
                return
            except (OSError, CheckpointIOError) as e:
                obs.instant("ckpt_retry", cat="train", step=step,
                            attempt=attempt, error=str(e))
                if attempt == self.retries:
                    raise CheckpointIOError(
                        f"checkpoint step {step} failed after "
                        f"{attempt + 1} attempts: {e}") from e
                time.sleep(delay)
                delay *= 2

    def _write_once(self, step: int, host: dict):
        # the fault site sits INSIDE the retry loop, so each attempt
        # re-draws — an injected transient clears exactly like a real one
        if faults.active() and faults.fire("ckpt_io"):
            raise CheckpointIOError(
                f"checkpoint step {step} write failed (injected)")
        final = os.path.join(self.directory, f"ckpt_{step}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.directory, f"ckpt_{s}"),
                          ignore_errors=True)

    def wait(self):
        """Join any in-flight async save; re-raise its failure if it had
        one (an async write error must never be lost)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # -- restore ------------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if (name.startswith("ckpt_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, "manifest.json"))):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None):
        """Returns (step, tree) with arrays matched by key-path into
        ``template``'s structure.  Raises KeyError on missing paths."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        data = np.load(os.path.join(self.directory, f"ckpt_{step}",
                                    "arrays.npz"))

        def pick(path, leaf):
            key = _path_str(path)
            arr = data[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch at {key}: ckpt {arr.shape} vs "
                    f"template {leaf.shape}")
            return arr

        tree = jax.tree_util.tree_map_with_path(pick, template)
        return step, tree
