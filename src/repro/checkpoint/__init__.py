"""Async, atomic, mesh-agnostic checkpointing with elastic resharding."""
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
from repro.checkpoint.reshard import place, reshard_checkpoint  # noqa: F401
