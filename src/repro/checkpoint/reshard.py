"""Elastic resharding: place a restored (host) pytree onto a new mesh.

Checkpoints are mesh-agnostic (full arrays addressed by key-path), so moving
from mesh A to mesh B is: restore on host -> ``place`` with B's shardings.
This is the restart path when the cluster grows/shrinks between jobs.
"""
from __future__ import annotations

import jax


def place(tree, shardings):
    """device_put every leaf with its target sharding (pytree-aligned or a
    single sharding applied to all leaves)."""
    if jax.tree_util.treedef_is_leaf(jax.tree.structure(
            shardings, is_leaf=lambda s: hasattr(s, "spec") or s is None)):
        return jax.tree.map(lambda x: jax.device_put(x, shardings), tree)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


def reshard_checkpoint(manager, template, shardings, step=None):
    step, tree = manager.restore(template, step)
    return step, place(tree, shardings)
