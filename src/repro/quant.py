"""Symmetric quantization: ONE codec for weights, KV pages, and gradients.

Per-block weight quantization for DYAD serving (ROADMAP item 3).  The DYAD
3-D tensors ``(n_dyad, d_out, d_in)`` contract ``d_in`` per block, so a
scale per ``(block, out_row)`` — reduced over the contracted axis only —
makes in-kernel dequant EXACT with a single fp32 accumulator: the scale is
constant along k, so

    sum_k x[k] * (q[o, k] * s[o])  ==  (sum_k x[k] * q[o, k]) * s[o]

and the Pallas bodies (:mod:`repro.kernels.dyad_mm`) multiply ``s`` into
the accumulator epilogue per k-step instead of dequantizing the weight
tile.  int8 payloads stream 4x fewer HBM bytes than fp32 (2x vs bf16);
the fp32 scale sidecar is ``1/d_in`` of the payload — noise.

Layout contract (``quantize_params``): quantized leaves ride SIDECAR next
to the retained fp32 originals — ``w1`` keeps its value and ``w1_q``
(int8/fp8, same shape) + ``w1_s`` (fp32, ``(n, d_out)``) appear beside it.
Dispatch sites check :func:`enabled` + sidecar presence; with
``REPRO_KERNEL_QUANT=off`` the sidecars are ignored and every route is
bit-identical to the unquantized build.

KV pages quantize per token-row (scale over the head dim): a page's rows
are written incrementally (decode appends one token at a time), so a true
per-page scalar would depend on future tokens — per-row scales in
page-shaped ``(n_pages, P, K)`` fp32 pools are the finest granularity
that stays exact under incremental writes.

The per-tensor helpers at the bottom are the single codec implementation
the gradient compressor (:mod:`repro.optim.compress`) re-exports.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12

# dtype name -> (jnp dtype attr name, symmetric max representable value)
_QDTYPES = {
    "int8": ("int8", 127.0),
    "fp8": ("float8_e4m3fn", 448.0),
    "float8_e4m3fn": ("float8_e4m3fn", 448.0),
}


def enabled() -> bool:
    """``REPRO_KERNEL_QUANT=off`` disables every quantized route (the
    sidecar leaves are ignored): bit-identical fp32 behavior."""
    return os.environ.get("REPRO_KERNEL_QUANT", "").lower() != "off"


def supports_fp8() -> bool:
    """Does this jax build ship ``float8_e4m3fn``?  (All pinned versions
    do; guarded so older interpreters degrade to int8 with a clear error
    instead of an AttributeError mid-trace.)"""
    return hasattr(jnp, "float8_e4m3fn")


def resolve_dtype(name: str) -> Tuple[jnp.dtype, float]:
    """``(jnp dtype, qmax)`` for a quantization dtype name."""
    if name not in _QDTYPES:
        raise ValueError(f"unknown quantization dtype {name!r} "
                         f"(know {sorted(_QDTYPES)})")
    attr, qmax = _QDTYPES[name]
    if not hasattr(jnp, attr):
        raise ValueError(f"backend lacks {attr} (jax {jax.__version__}); "
                         f"use dtype='int8'")
    return jnp.dtype(getattr(jnp, attr)), qmax


def quant_symmetric(g, axis=None, dtype: str = "int8"):
    """Symmetric quantization: ``scale = max|g| / qmax + eps`` reduced over
    ``axis`` (None = per-tensor scalar scale), ``q = round(g / scale)``
    clipped to ±qmax and cast.  Returns ``(q, scale)`` with ``scale``
    keeping the reduced axes SQUEEZED (not kept) — a ``(n, d_out, d_in)``
    weight quantized over ``axis=-1`` yields a ``(n, d_out)`` scale."""
    qd, qmax = resolve_dtype(dtype)
    g = jnp.asarray(g)
    scale = (jnp.max(jnp.abs(g), axis=axis).astype(jnp.float32) / qmax
             + _EPS)
    s_full = scale if axis is None else jnp.expand_dims(scale, axis)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / s_full), -qmax, qmax)
    return q.astype(qd), scale


def dequant(q, scale, axis=None):
    """Inverse of :func:`quant_symmetric` (fp32): broadcast the squeezed
    scale back over ``axis`` and multiply."""
    s = scale if axis is None else jnp.expand_dims(scale, axis)
    return q.astype(jnp.float32) * s


# -- DYAD weight sidecars -----------------------------------------------------


def quantize_dyad_weight(w, dtype: str = "int8"):
    """One DYAD component ``(n, d_out, d_in)`` -> ``(q, scales)`` with a
    scale per (block, out_row) — reduced over the CONTRACTED ``d_in`` axis
    so the kernels' epilogue-multiply dequant is exact.  A layer-stacked
    ``(n_layers, n, d_out, d_in)`` tensor quantizes the same way (scales
    ``(n_layers, n, d_out)``) — ``lax.scan`` slices the leading axis off
    both leaves before the kernels see them."""
    if w.ndim not in (3, 4):
        raise ValueError(f"expected a [stacked] (n, d_out, d_in) DYAD "
                         f"tensor, got shape {w.shape}")
    return quant_symmetric(w, axis=-1, dtype=dtype)


def _is_dyad_module(node) -> bool:
    return (isinstance(node, dict) and "w1" in node and "w2" in node
            and getattr(node["w1"], "ndim", 0) in (3, 4))


def quantize_params(params, dtype: str = "int8"):
    """Offline pass: walk the param tree and add sidecar quantized leaves
    (``w1_q``/``w1_s``/``w2_q``/``w2_s``) next to every 3-D DYAD module's
    retained fp32 ``w1``/``w2``.  Existing consumers (``"w1" in params``
    checks, shape reads, the ``REPRO_KERNEL_QUANT=off`` escape hatch) keep
    working untouched; quantized dispatch streams the sidecars instead."""
    resolve_dtype(dtype)   # validate before touching the tree

    def walk(node):
        if _is_dyad_module(node):
            out = dict(node)
            for nm in ("w1", "w2"):
                q, s = quantize_dyad_weight(node[nm], dtype)
                out[nm + "_q"], out[nm + "_s"] = q, s
            # nested submodules (none today) would still be walked:
            for k, v in node.items():
                if isinstance(v, dict):
                    out[k] = walk(v)
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def module_quantized(params) -> bool:
    """Does this DYAD module dict carry the full quantized sidecar set?"""
    return (isinstance(params, dict)
            and all(k in params for k in
                    ("w1_q", "w1_s", "w2_q", "w2_s")))


def ff_quantized(params) -> bool:
    """Does an ff module tree (``up``/``down``[/``gate``] submodules)
    carry quantized sidecars on every projection?"""
    if not isinstance(params, dict):
        return False
    names = [n for n in ("gate", "up", "down") if n in params]
    return (len(names) >= 2
            and all(module_quantized(params[n]) for n in names))


# -- KV page quantization -----------------------------------------------------


def quantize_kv_rows(x, dtype: str = "int8"):
    """Quantize K/V token rows ``(..., K, h)`` with one scale per
    ``(..., K)`` row (reduced over the head dim — the axis the attention
    dot contracts, so in-kernel dequant-by-row is exact).  Returns
    ``(q, scales)`` with ``scales: (..., K)`` fp32."""
    return quant_symmetric(x, axis=-1, dtype=dtype)


# -- per-tensor codec (re-exported by repro.optim.compress) -------------------


def quant_int8(g):
    """Per-tensor symmetric int8: ``scale = max|g| / 127 + eps``."""
    return quant_symmetric(g, axis=None, dtype="int8")


def dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale
