"""Deterministic, resumable, host-sharded synthetic LM data pipeline.

The stream is *stateless*: batch ``i`` for shard ``s`` is a pure function of
``(seed, i, s)`` via ``jax.random.fold_in``, so

* resume-after-restart is exact (no iterator state beyond the step counter),
* elastic re-sharding is exact (shard count is an argument, not baked state),
* every host materializes only its shard.

The token process is learnable but non-trivial: a fixed random permutation
``perm`` over the vocab drives first-order structure — with probability
``p_copy`` the next token is ``perm[prev]``, otherwise uniform noise.  A model
must learn the permutation to beat the entropy floor, which makes the stream
usable for DENSE-vs-DYAD quality-parity experiments (paper Tables 2/3 analog).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    p_copy: float = 0.8
    shard: int = 0
    num_shards: int = 1

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_shards

    def _perm(self):
        return jax.random.permutation(
            jax.random.PRNGKey(self.seed + 7919), self.vocab_size)

    def batch(self, step: int) -> dict:
        """{"tokens": (local_batch, S), "labels": (local_batch, S)} int32."""
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), step), self.shard)
        B, S = self.local_batch, self.seq_len
        perm = self._perm()
        k1, k2, k3 = jax.random.split(key, 3)
        first = jax.random.randint(k1, (B,), 0, self.vocab_size)
        noise = jax.random.randint(k2, (B, S), 0, self.vocab_size)
        use_copy = jax.random.bernoulli(k3, self.p_copy, (B, S))

        def step_fn(prev, inp):
            nz, uc = inp
            nxt = jnp.where(uc, perm[prev], nz)
            return nxt, nxt

        _, toks = jax.lax.scan(
            step_fn, first, (noise.T, use_copy.T))
        toks = toks.T                                   # (B, S)
        seq = jnp.concatenate([first[:, None], toks], axis=1)  # (B, S+1)
        return {"tokens": seq[:, :-1].astype(jnp.int32),
                "labels": seq[:, 1:].astype(jnp.int32)}

    def reshard(self, shard: int, num_shards: int) -> "SyntheticLM":
        return dataclasses.replace(self, shard=shard, num_shards=num_shards)


@dataclasses.dataclass(frozen=True)
class SyntheticClassification:
    """MNIST-analog for the paper's vision probe: random projected clusters."""
    n_classes: int = 10
    dim: int = 784
    batch: int = 128
    seed: int = 0
    noise: float = 0.35

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (self.batch,), 0, self.n_classes)
        centers = jax.random.normal(
            jax.random.PRNGKey(self.seed + 13), (self.n_classes, self.dim))
        x = centers[labels] + self.noise * jax.random.normal(
            k2, (self.batch, self.dim))
        return {"x": x, "labels": labels}
