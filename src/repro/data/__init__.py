"""Data pipeline: deterministic, resumable, host-sharded synthetic streams."""
from repro.data.synthetic import SyntheticClassification, SyntheticLM  # noqa: F401
