"""Training loop substrate."""
from repro.train.loop import Trainer  # noqa: F401
from repro.train.step import init_train_state, make_eval_step, make_train_step  # noqa: F401
