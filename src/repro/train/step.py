"""Train/eval steps: value_and_grad + microbatch accumulation + optimizer.

The returned step function is pure (state, batch) -> (state, metrics) and is
what the launcher jits with in/out shardings — the SAME function serves the
single-host tests and the 512-chip dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.config import ModelCfg
from repro.optim.adamw import AdamW
from repro.optim.compress import Compressor


def init_train_state(cfg: ModelCfg, opt: AdamW, key,
                     compressor: Optional[Compressor] = None) -> dict:
    params = model.init_params(cfg, key)
    state = {"params": params, "opt": opt.init(params)}
    if compressor is not None and compressor.codec != "none":
        state["compress"] = compressor.init(params)
    return state


def make_train_step(cfg: ModelCfg, opt: AdamW,
                    compressor: Optional[Compressor] = None,
                    nan_guard: bool = True):
    """Build the pure (state, batch) -> (state, metrics) step.

    ``nan_guard=True`` (default) adds an IN-JIT skip-step: when the loss or
    gradient norm comes out non-finite, the optimizer update is discarded
    (``state`` passes through unchanged, selected inside the jit — the
    launcher donates ``state``, so a host-side retry of the old state is
    impossible) and ``metrics["nonfinite"]`` is 1.  The trainer counts
    consecutive strikes and rolls back to the last checkpoint.

    A ``"_fault_poison"`` batch key (float scalar, injected by the trainer
    when the ``nan_loss`` fault site is armed) multiplies the gradients and
    the loss metric by NaN when nonzero — it is popped before the batch
    reaches the model, so the loss itself is oblivious."""
    accum = max(cfg.grad_accum, 1)

    def loss_of(params, batch):
        return model.loss_fn(cfg, params, batch)

    def train_step(state, batch):
        poison = None
        if isinstance(batch, dict) and "_fault_poison" in batch:
            batch = dict(batch)
            poison = batch.pop("_fault_poison")
        params = state["params"]
        if accum == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        else:
            def split(x):
                # micro-batch-major layout: each device keeps its LOCAL batch
                # shard across ALL accumulation steps (the naive
                # reshape(accum, B//accum) would partition the scan axis
                # across data-parallel devices).
                return x.reshape(x.shape[0] // accum, accum,
                                 *x.shape[1:]).swapaxes(0, 1)
            micro = jax.tree.map(split, batch)

            def mb(carry, b):
                gsum, gcomp, msum = carry
                (_, m), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                # Kahan-compensated sum: the per-microbatch gradients are the
                # same magnitude, so a plain sequential sum loses ~accum ulps
                # of the mean; the compensation term keeps the accumulated
                # gradient within 1 ulp of the exact sum regardless of accum.
                y = jax.tree.map(jnp.subtract, g, gcomp)
                t = jax.tree.map(jnp.add, gsum, y)
                gcomp = jax.tree.map(lambda t_, s, y_: (t_ - s) - y_,
                                     t, gsum, y)
                msum = jax.tree.map(jnp.add, msum, m)
                return (t, gcomp, msum), None

            zero_g = jax.tree.map(jnp.zeros_like, params)
            zero_c = jax.tree.map(jnp.zeros_like, params)
            zero_m = {"loss": jnp.zeros(()), "aux": jnp.zeros(()),
                      "ppl_proxy": jnp.zeros(())}
            (grads, _, msum), _ = jax.lax.scan(
                mb, (zero_g, zero_c, zero_m), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, msum)

        if poison is not None:
            nanify = jnp.where(jnp.asarray(poison) != 0,
                               jnp.float32(jnp.nan), jnp.float32(1.0))
            grads = jax.tree.map(lambda g: g * nanify.astype(g.dtype), grads)
            metrics = dict(metrics, loss=metrics["loss"] * nanify)

        new_state = dict(state)
        if "compress" in state and compressor is not None:
            grads, new_state["compress"] = compressor.compress_decompress(
                grads, state["compress"])
        new_params, new_opt, om = opt.update(grads, state["opt"], params)
        new_state["params"], new_state["opt"] = new_params, new_opt
        metrics = dict(metrics, **om)
        if nan_guard:
            # skip-step, decided INSIDE the jit: a non-finite loss or grad
            # norm keeps the old state leaf-for-leaf.  grad_norm is the
            # cheap single-scalar witness for "any grad is non-finite"
            # (AdamW already computes it), loss catches forward blowups.
            ok = jnp.isfinite(metrics["loss"]) & jnp.isfinite(om["grad_norm"])
            new_state = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o).astype(n.dtype)
                if hasattr(n, "dtype") else n,
                new_state, state)
            metrics = dict(metrics, nonfinite=(~ok).astype(jnp.float32))
        return new_state, metrics

    return train_step


def make_eval_step(cfg: ModelCfg):
    def eval_step(params, batch):
        _, metrics = model.loss_fn(cfg, params, batch)
        return metrics
    return eval_step
