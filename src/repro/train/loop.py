"""Fault-tolerant training loop.

Production behaviors, all exercised by tests on this container:

* **auto-resume** — restores the newest complete checkpoint on start; the data
  stream is stateless-by-step so data resumes exactly;
* **preemption hook** — SIGTERM/SIGINT triggers a final checkpoint and a clean
  exit (for spot/maintenance events);
* **straggler watchdog** — steps slower than ``straggler_factor`` x the running
  median are recorded; the mitigation policy (re-dispatch to spares, skip) is
  pluggable via ``on_straggler``;
* **async checkpointing** — serialization never blocks the step loop.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager


class Trainer:
    def __init__(
        self,
        train_step: Callable,
        init_state: dict,
        data,
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        log_every: int = 10,
        straggler_factor: float = 3.0,
        on_straggler: Optional[Callable] = None,
        log_fn: Callable = print,
    ):
        self.train_step = train_step
        self.state = init_state
        self.data = data
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.log = log_fn
        self.step = 0
        self.straggler_events = []
        self._preempted = False
        self._step_times = []

    # -- fault tolerance ------------------------------------------------------
    def install_preemption_handler(self, signals=(signal.SIGTERM,)):
        for s in signals:
            signal.signal(s, self._on_preempt)

    def _on_preempt(self, signum, frame):
        self.log(f"[trainer] preemption signal {signum}: checkpoint + exit")
        self._preempted = True

    def maybe_resume(self):
        if self.ckpt and self.ckpt.latest_step() is not None:
            self.step, self.state = self.ckpt.restore(self.state)
            self.log(f"[trainer] resumed from step {self.step}")

    def _watch_straggler(self, dt: float):
        self._step_times.append(dt)
        if len(self._step_times) >= 8:
            med = statistics.median(self._step_times[-64:])
            if dt > self.straggler_factor * med:
                self.straggler_events.append((self.step, dt, med))
                self.log(f"[trainer] straggler at step {self.step}: "
                         f"{dt * 1e3:.1f}ms vs median {med * 1e3:.1f}ms")
                if self.on_straggler:
                    self.on_straggler(self.step, dt, med)

    # -- main loop -------------------------------------------------------------
    def run(self, num_steps: int):
        self.maybe_resume()
        metrics = {}
        while self.step < num_steps and not self._preempted:
            batch = self.data.batch(self.step)
            t0 = time.perf_counter()
            self.state, metrics = self.train_step(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            self._watch_straggler(time.perf_counter() - t0)
            self.step += 1
            if self.step % self.log_every == 0:
                self.log(f"[trainer] step {self.step} "
                         f"loss={float(metrics['loss']):.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f}")
            if self.ckpt and self.step % self.ckpt_every == 0:
                self.ckpt.save(self.step, self.state)
        if self.ckpt:
            self.ckpt.save(self.step, self.state, blocking=True)
            self.ckpt.wait()
        return self.state, metrics
