"""Fault-tolerant training loop.

Production behaviors, all exercised by tests on this container:

* **auto-resume** — restores the newest complete checkpoint on start; the data
  stream is stateless-by-step so data resumes exactly;
* **preemption hook** — SIGTERM/SIGINT triggers a final checkpoint and a clean
  exit (for spot/maintenance events);
* **straggler watchdog** — steps slower than ``straggler_factor`` x the running
  median are recorded; the mitigation policy (re-dispatch to spares, skip) is
  pluggable via ``on_straggler``;
* **NaN backoff** — when the step function reports a non-finite loss/grad
  (``metrics["nonfinite"]``, see :func:`repro.train.step.make_train_step`'s
  in-jit skip-step), the trainer counts consecutive strikes; at
  ``nan_strikes`` it rolls back to the last checkpoint (the skip-step means
  the weights are still clean — rollback re-reads data from an earlier
  step, which is what shakes off a poisoned batch window).  With no
  checkpoint to roll back to, or after ``max_rollbacks`` rollbacks,
  :class:`repro.errors.NumericalFault` is raised;
* **async checkpointing** — serialization never blocks the step loop;
* **telemetry** — every step runs under an ``obs.span`` (``--trace`` on the
  launcher exports the timeline) and feeds a :class:`repro.obs.MetricsRegistry`
  (``step_time_s`` histogram, ``tokens_per_s`` / ``loss`` gauges,
  ``straggler_count``); the periodic log line carries loss, tokens/s and the
  running-median step time the watchdog already maintains.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro import faults, obs
from repro.checkpoint.manager import CheckpointManager
from repro.errors import NumericalFault


def _batch_tokens(batch) -> int:
    """Tokens in one batch: the ``tokens`` leaf when present (the synthetic
    LM pipeline contract), else the largest leaf's element count."""
    if isinstance(batch, dict) and "tokens" in batch:
        t = batch["tokens"]
        return int(t.size) if hasattr(t, "size") else 0
    sizes = [int(x.size) for x in jax.tree.leaves(batch)
             if hasattr(x, "size")]
    return max(sizes) if sizes else 0


class Trainer:
    def __init__(
        self,
        train_step: Callable,
        init_state: dict,
        data,
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        log_every: int = 10,
        straggler_factor: float = 3.0,
        on_straggler: Optional[Callable] = None,
        nan_strikes: int = 3,
        max_rollbacks: int = 3,
        log_fn: Callable = print,
    ):
        self.train_step = train_step
        self.state = init_state
        self.data = data
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.log = log_fn
        self.step = 0
        self.straggler_events = []
        self._preempted = False
        self._step_times = []
        self._median = 0.0            # running median the watchdog computes
        self.nan_strikes = nan_strikes
        self.max_rollbacks = max_rollbacks
        self._strikes = 0             # consecutive non-finite steps
        self._rollbacks = 0
        self.metrics = obs.MetricsRegistry()

    # -- fault tolerance ------------------------------------------------------
    def install_preemption_handler(self, signals=(signal.SIGTERM,)):
        for s in signals:
            signal.signal(s, self._on_preempt)

    def _on_preempt(self, signum, frame):
        self.log(f"[trainer] preemption signal {signum}: checkpoint + exit")
        self._preempted = True

    def maybe_resume(self):
        if self.ckpt and self.ckpt.latest_step() is not None:
            with obs.span("resume", cat="train"):
                self.step, self.state = self.ckpt.restore(self.state)
            self.log(f"[trainer] resumed from step {self.step}")

    def _after_step(self, metrics) -> None:
        """Consecutive-NaN accounting.  The step function already skipped
        the bad update in-jit, so a strike costs one wasted batch; at
        ``nan_strikes`` strikes the trainer rolls back to the last
        checkpoint (bounded by ``max_rollbacks``)."""
        bad = metrics.get("nonfinite")
        if bad is None or not float(bad):
            self._strikes = 0
            return
        self._strikes += 1
        self.metrics.counter("nonfinite_steps").inc()
        obs.instant("nonfinite_step", cat="train", step=self.step,
                    strikes=self._strikes)
        self.log(f"[trainer] non-finite loss/grad at step {self.step} "
                 f"(skipped; strike {self._strikes}/{self.nan_strikes})")
        if self._strikes < self.nan_strikes:
            return
        if not self.ckpt or self.ckpt.latest_step() is None:
            raise NumericalFault(
                f"{self._strikes} consecutive non-finite steps and no "
                "checkpoint to roll back to")
        self._rollbacks += 1
        if self._rollbacks > self.max_rollbacks:
            raise NumericalFault(
                f"still non-finite after {self.max_rollbacks} rollbacks "
                "— the fault is not transient")
        with obs.span("rollback", cat="train", step=self.step,
                      strikes=self._strikes, rollback=self._rollbacks):
            self.ckpt.wait()
            self.step, self.state = self.ckpt.restore(self.state)
        self.metrics.counter("rollbacks").inc()
        self._strikes = 0
        self.log(f"[trainer] rolled back to checkpoint step {self.step} "
                 f"(rollback {self._rollbacks}/{self.max_rollbacks})")

    def _watch_straggler(self, dt: float):
        self._step_times.append(dt)
        if len(self._step_times) >= 8:
            med = statistics.median(self._step_times[-64:])
            self._median = med
            if dt > self.straggler_factor * med:
                self.straggler_events.append((self.step, dt, med))
                self.metrics.counter("straggler_count").inc()
                obs.instant("straggler", cat="train", step=self.step,
                            dt_ms=round(dt * 1e3, 2),
                            median_ms=round(med * 1e3, 2))
                self.log(f"[trainer] straggler at step {self.step}: "
                         f"{dt * 1e3:.1f}ms vs median {med * 1e3:.1f}ms")
                if self.on_straggler:
                    self.on_straggler(self.step, dt, med)

    # -- main loop -------------------------------------------------------------
    def run(self, num_steps: int):
        self.maybe_resume()
        metrics = {}
        m = self.metrics
        while self.step < num_steps and not self._preempted:
            batch = self.data.batch(self.step)
            if faults.active():
                sp = faults.fire("slow_step")
                if sp is not None and sp.ms:
                    with obs.span("slow_step_fault", cat="fault", ms=sp.ms):
                        time.sleep(sp.ms / 1000.0)
                reg = faults.registry()
                if (reg is not None and "nan_loss" in reg.specs
                        and isinstance(batch, dict)):
                    # keep the batch pytree structure stable across steps:
                    # the key is always present while the site is armed
                    batch = dict(batch)
                    batch["_fault_poison"] = np.float32(
                        1.0 if faults.fire("nan_loss") else 0.0)
            n_tok = _batch_tokens(batch)
            t0 = time.perf_counter()
            with obs.span("train_step", cat="train", step=self.step,
                          tokens=n_tok):
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watch_straggler(dt)
            m.histogram("step_time_s").observe(dt)
            m.counter("tokens_trained").inc(n_tok)
            m.gauge("tokens_per_s").set(n_tok / max(dt, 1e-9))
            self.step += 1
            self._after_step(metrics)
            if self.step % self.log_every == 0:
                loss = float(metrics["loss"])
                m.gauge("loss").set(loss)
                # median from the watchdog window (not the histogram): both
                # log line and straggler verdicts quote the SAME number
                med = self._median or statistics.median(self._step_times)
                self.log(f"[trainer] step {self.step} loss={loss:.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f} "
                         f"tok/s={n_tok / max(dt, 1e-9):.0f} "
                         f"step_ms_med={med * 1e3:.1f}")
            if self.ckpt and self.step % self.ckpt_every == 0:
                with obs.span("checkpoint", cat="train", step=self.step):
                    self.ckpt.save(self.step, self.state)
        if self.ckpt:
            with obs.span("checkpoint", cat="train", step=self.step,
                          final=True):
                self.ckpt.save(self.step, self.state, blocking=True)
                self.ckpt.wait()
        return self.state, metrics
