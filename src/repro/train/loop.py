"""Fault-tolerant training loop.

Production behaviors, all exercised by tests on this container:

* **auto-resume** — restores the newest complete checkpoint on start; the data
  stream is stateless-by-step so data resumes exactly;
* **preemption hook** — SIGTERM/SIGINT triggers a final checkpoint and a clean
  exit (for spot/maintenance events);
* **straggler watchdog** — steps slower than ``straggler_factor`` x the running
  median are recorded; the mitigation policy (re-dispatch to spares, skip) is
  pluggable via ``on_straggler``;
* **async checkpointing** — serialization never blocks the step loop;
* **telemetry** — every step runs under an ``obs.span`` (``--trace`` on the
  launcher exports the timeline) and feeds a :class:`repro.obs.MetricsRegistry`
  (``step_time_s`` histogram, ``tokens_per_s`` / ``loss`` gauges,
  ``straggler_count``); the periodic log line carries loss, tokens/s and the
  running-median step time the watchdog already maintains.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable, Optional

import jax

from repro import obs
from repro.checkpoint.manager import CheckpointManager


def _batch_tokens(batch) -> int:
    """Tokens in one batch: the ``tokens`` leaf when present (the synthetic
    LM pipeline contract), else the largest leaf's element count."""
    if isinstance(batch, dict) and "tokens" in batch:
        t = batch["tokens"]
        return int(t.size) if hasattr(t, "size") else 0
    sizes = [int(x.size) for x in jax.tree.leaves(batch)
             if hasattr(x, "size")]
    return max(sizes) if sizes else 0


class Trainer:
    def __init__(
        self,
        train_step: Callable,
        init_state: dict,
        data,
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        log_every: int = 10,
        straggler_factor: float = 3.0,
        on_straggler: Optional[Callable] = None,
        log_fn: Callable = print,
    ):
        self.train_step = train_step
        self.state = init_state
        self.data = data
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.straggler_factor = straggler_factor
        self.on_straggler = on_straggler
        self.log = log_fn
        self.step = 0
        self.straggler_events = []
        self._preempted = False
        self._step_times = []
        self._median = 0.0            # running median the watchdog computes
        self.metrics = obs.MetricsRegistry()

    # -- fault tolerance ------------------------------------------------------
    def install_preemption_handler(self, signals=(signal.SIGTERM,)):
        for s in signals:
            signal.signal(s, self._on_preempt)

    def _on_preempt(self, signum, frame):
        self.log(f"[trainer] preemption signal {signum}: checkpoint + exit")
        self._preempted = True

    def maybe_resume(self):
        if self.ckpt and self.ckpt.latest_step() is not None:
            with obs.span("resume", cat="train"):
                self.step, self.state = self.ckpt.restore(self.state)
            self.log(f"[trainer] resumed from step {self.step}")

    def _watch_straggler(self, dt: float):
        self._step_times.append(dt)
        if len(self._step_times) >= 8:
            med = statistics.median(self._step_times[-64:])
            self._median = med
            if dt > self.straggler_factor * med:
                self.straggler_events.append((self.step, dt, med))
                self.metrics.counter("straggler_count").inc()
                obs.instant("straggler", cat="train", step=self.step,
                            dt_ms=round(dt * 1e3, 2),
                            median_ms=round(med * 1e3, 2))
                self.log(f"[trainer] straggler at step {self.step}: "
                         f"{dt * 1e3:.1f}ms vs median {med * 1e3:.1f}ms")
                if self.on_straggler:
                    self.on_straggler(self.step, dt, med)

    # -- main loop -------------------------------------------------------------
    def run(self, num_steps: int):
        self.maybe_resume()
        metrics = {}
        m = self.metrics
        while self.step < num_steps and not self._preempted:
            batch = self.data.batch(self.step)
            n_tok = _batch_tokens(batch)
            t0 = time.perf_counter()
            with obs.span("train_step", cat="train", step=self.step,
                          tokens=n_tok):
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watch_straggler(dt)
            m.histogram("step_time_s").observe(dt)
            m.counter("tokens_trained").inc(n_tok)
            m.gauge("tokens_per_s").set(n_tok / max(dt, 1e-9))
            self.step += 1
            if self.step % self.log_every == 0:
                loss = float(metrics["loss"])
                m.gauge("loss").set(loss)
                # median from the watchdog window (not the histogram): both
                # log line and straggler verdicts quote the SAME number
                med = self._median or statistics.median(self._step_times)
                self.log(f"[trainer] step {self.step} loss={loss:.4f} "
                         f"gnorm={float(metrics['grad_norm']):.3f} "
                         f"tok/s={n_tok / max(dt, 1e-9):.0f} "
                         f"step_ms_med={med * 1e3:.1f}")
            if self.ckpt and self.step % self.ckpt_every == 0:
                with obs.span("checkpoint", cat="train", step=self.step):
                    self.ckpt.save(self.step, self.state)
        if self.ckpt:
            with obs.span("checkpoint", cat="train", step=self.step,
                          final=True):
                self.ckpt.save(self.step, self.state, blocking=True)
                self.ckpt.wait()
        return self.state, metrics
