"""Normalization layers.

Mixed-precision discipline: REDUCTIONS accumulate in fp32 (the (B,S,1)
statistics), but the big (B,S,D) elementwise math stays in the activation
dtype — fp32-internal norms would push fp32 cotangents through the whole
backward pass, doubling HBM traffic and collective bytes (EXPERIMENTS §Perf
iteration A2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    # fp32 for the row statistics only; (B,S,D) math in activation dtype.
    # (A hand-written VJP was tried and REFUTED: it blocked XLA fusion and
    # INCREASED modeled HBM traffic — EXPERIMENTS §Perf A3.)
    var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    y = (x - mu.astype(x.dtype)) * inv
    return y * params["scale"].astype(x.dtype) + params["bias"].astype(x.dtype)
