"""Mamba2 (SSD — state-space duality) mixer.

Training/prefill uses the chunked dual form: quadratic attention-like compute
inside chunks of length ``chunk`` plus a cheap sequential inter-chunk state
recurrence (``lax.scan`` over ``S/chunk`` steps, state ``(B,H,P,N)``).
Decode is the O(1) recurrent update.  The large in/out projections go through
the linear factory with ``site="ssm"`` — the DYAD substitution point for
attention-free architectures (see DESIGN §Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import factory, linear
from repro.layers import norms


def init_ssm(
    key,
    d_model: int,
    lin_cfg: factory.LinearCfg,
    *,
    d_state: int = 128,
    head_dim: int = 64,
    expand: int = 2,
    n_groups: int = 1,
    conv_width: int = 4,
    dtype=jnp.float32,
):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_ch = d_inner + 2 * n_groups * d_state
    ks = jax.random.split(key, 7)
    p = {
        "wz": factory.init(ks[0], d_model, d_inner, lin_cfg, site="ssm",
                           bias=False, dtype=dtype),
        "wx": factory.init(ks[1], d_model, d_inner, lin_cfg, site="ssm",
                           bias=False, dtype=dtype),
        "wbc": linear.init(ks[2], d_model, 2 * n_groups * d_state, bias=False,
                           dtype=dtype),
        "wdt": linear.init(ks[3], d_model, n_heads, bias=False, dtype=dtype),
        "wo": factory.init(ks[4], d_inner, d_model, lin_cfg, site="ssm",
                           bias=False, dtype=dtype),
        "conv": jax.random.normal(ks[5], (conv_width, conv_ch), dtype) * 0.1,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(dtype)),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[6], (n_heads,), jnp.float32) *
                    (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)))).astype(dtype),
        "norm": norms.init_rmsnorm(d_inner, dtype),
    }
    return p


def _segsum(x):
    """x: (..., L) -> (..., L, L): T[i,j] = sum_{k=j+1..i} x[k] (i>=j), -inf else."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, diff, -jnp.inf)


def _causal_conv(u, kernel, hist=None):
    """Depthwise causal conv: u (B,S,Ch), kernel (W,Ch).

    ``hist`` is an optional (B, W-1, Ch) left context — the conv tail carried
    in the decode cache.  ``hist=None`` zero-pads (a fresh stream; identical
    to a zero-initialized cache)."""
    W = kernel.shape[0]
    if hist is None:
        up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([hist.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u)
    for w in range(W):
        out = out + up[:, w:w + u.shape[1], :] * kernel[w]
    return out


def _project(params, x, lin_cfg, n_groups, d_state, n_heads, head_dim):
    """Shared projection math for both forms."""
    z = factory.apply(params["wz"], x, lin_cfg, site="ssm")
    xs = factory.apply(params["wx"], x, lin_cfg, site="ssm")
    bc = linear.apply(params["wbc"], x)
    dt = linear.apply(params["wdt"], x)
    return z, xs, bc, dt


def _ssd_forward(params, x, lin_cfg, *, d_state, head_dim, n_groups, chunk,
                 hist=None, s0=None):
    """Chunked SSD forward that ALSO yields the recurrent decode cache.

    x: (B, S, D) in the activation dtype.  ``hist`` is the (B, W-1, Ch) conv
    history and ``s0`` the (B, H, P, N) fp32 initial state — both optional
    (None == fresh stream, identical to a zero-initialized cache).

    Sequences whose length does not divide the SSD chunk are right-padded
    internally; padded positions get dt == 0 (decay exp(0)=1, input term 0),
    so they update neither the state nor any real position's output.

    Returns (y (B,S,D), final_state (B,H,P,N) fp32, conv_tail (B,W-1,Ch)) —
    final_state/conv_tail are exactly what ``ssm_decode_step`` expects next.
    """
    B, S, D = x.shape
    n_heads = params["A_log"].shape[0]
    d_inner = n_heads * head_dim
    L = min(chunk, S)
    Sp = -(-S // L) * L
    if Sp != S:
        x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0)))
    z, xs, bc, dt = _project(params, x, lin_cfg, n_groups, d_state, n_heads,
                             head_dim)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    W = params["conv"].shape[0]
    conv_tail = jnp.concatenate(
        [hist.astype(conv_in.dtype) if hist is not None
         else jnp.zeros((B, W - 1, conv_in.shape[-1]), conv_in.dtype),
         conv_in[:, :S]], axis=1)[:, -(W - 1):]
    conv_out = jax.nn.silu(
        _causal_conv(conv_in, params["conv"].astype(x.dtype), hist=hist))
    xs, bmat, cmat = jnp.split(
        conv_out, [d_inner, d_inner + n_groups * d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))     # (B,Sp,H)
    if Sp != S:
        dt = jnp.where(jnp.arange(Sp)[None, :, None] < S, dt, 0.0)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))               # (H,)
    xh = xs.reshape(B, Sp, n_heads, head_dim).astype(jnp.float32)
    bmat = bmat.reshape(B, Sp, n_groups, d_state).astype(jnp.float32)
    cmat = cmat.reshape(B, Sp, n_groups, d_state).astype(jnp.float32)
    # broadcast groups over heads
    rep = n_heads // n_groups
    bh = jnp.repeat(bmat, rep, axis=2)                              # (B,Sp,H,N)
    ch = jnp.repeat(cmat, rep, axis=2)

    nc = Sp // L
    r = lambda t: t.reshape(B, nc, L, *t.shape[2:])
    xh, bh, ch, dt = r(xh), r(bh), r(ch), r(dt)

    dA = dt * A                                                     # (B,nc,L,H)
    dA_cs = jnp.cumsum(dA, axis=2)

    # 1) intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))               # (B,nc,H,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcsh,bcshp->bclhp",
                        ch, bh, Lmat, dt, xh)

    # 2) per-chunk final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)             # (B,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", bh, decay_states * dt, xh)

    # 3) inter-chunk recurrence (sequential over nc; state (B,H,P,N))
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                       # (B,nc,H)

    def step(s_prev, inp):
        dec, st = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    if s0 is None:
        s0 = jnp.zeros((B, n_heads, head_dim, d_state), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (chunk_decay.swapaxes(0, 1), states.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                        # (B,nc,H,P,N)

    # 4) state contribution to outputs
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", ch, prev_states,
                       jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(B, Sp, n_heads, head_dim)
    y = y + params["D"].astype(jnp.float32)[:, None] * xh.reshape(
        B, Sp, n_heads, head_dim)
    y = y.reshape(B, Sp, d_inner).astype(x.dtype)
    y = norms.rmsnorm(params["norm"], y * jax.nn.silu(z))
    y = factory.apply(params["wo"], y, lin_cfg, site="ssm")
    return y[:, :S], final_state, conv_tail


def apply_ssm(params, x, lin_cfg, *, d_state=128, head_dim=64, n_groups=1,
              chunk=256):
    """Chunked SSD forward (no cache).  x: (B, S, D) -> (B, S, D)."""
    y, _, _ = _ssd_forward(params, x, lin_cfg, d_state=d_state,
                           head_dim=head_dim, n_groups=n_groups, chunk=chunk)
    return y


def ssm_prefill(params, x, cache, lin_cfg, *, d_state=128, head_dim=64,
                n_groups=1, chunk=256):
    """Single-pass multi-token prefill: chunked SSD forward + cache handoff.

    x: (B, S, D); cache: {"conv" (B,W-1,Ch), "state" (B,H,P,N) fp32} — the
    layout made by :func:`init_ssm_cache`.  One call replaces S sequential
    :func:`ssm_decode_step` calls; the returned cache continues decode at
    position S.  Returns (y (B,S,D), new_cache).
    """
    y, state, tail = _ssd_forward(
        params, x, lin_cfg, d_state=d_state, head_dim=head_dim,
        n_groups=n_groups, chunk=chunk, hist=cache["conv"], s0=cache["state"])
    return y, {"conv": tail.astype(cache["conv"].dtype), "state": state}


def init_ssm_cache(batch, d_model, *, d_state=128, head_dim=64, expand=2,
                   n_groups=1, conv_width=4, n_heads=None, dtype=jnp.float32):
    d_inner = expand * d_model
    h = n_heads or d_inner // head_dim
    conv_ch = d_inner + 2 * n_groups * d_state
    return {
        "conv": jnp.zeros((batch, conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, h, head_dim, d_state), jnp.float32),
    }


def ssm_decode_step(params, x, cache, lin_cfg, *, d_state=128, head_dim=64,
                    n_groups=1):
    """One-token recurrent update.  x: (B, 1, D) -> (y (B,1,D), new cache)."""
    B = x.shape[0]
    n_heads = params["A_log"].shape[0]
    d_inner = n_heads * head_dim
    z, xs, bc, dt = _project(params, x, lin_cfg, n_groups, d_state, n_heads,
                             head_dim)
    conv_in = jnp.concatenate([xs, bc], axis=-1)[:, 0]              # (B,Ch)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)
    kernel = params["conv"].astype(x.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, kernel))
    new_conv = hist[:, 1:]
    xs, bmat, cmat = jnp.split(
        conv_out, [d_inner, d_inner + n_groups * d_state], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))     # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, n_heads, head_dim).astype(jnp.float32)
    rep = n_heads // n_groups
    bh = jnp.repeat(bmat.reshape(B, n_groups, d_state), rep, 1)
    chh = jnp.repeat(cmat.reshape(B, n_groups, d_state), rep, 1)

    decay = jnp.exp(dt * A)                                         # (B,H)
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, chh)
    y = y + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = norms.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = factory.apply(params["wo"], y, lin_cfg, site="ssm")
    return out, {"conv": new_conv, "state": state}
