"""Token embedding / unembedding (kept dense — see DESIGN §Arch-applicability)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_model, jnp.float32))
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * scale}


def embed(params, tokens, *, iota: bool = False):
    if iota:
        # one-hot matmul: vocab stays contracted => fwd is a psum-able dot and
        # bwd (d_table) is a plain matmul — no scatter onto the sharded table.
        table = params["table"]
        onehot = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
        return jnp.einsum("...v,vd->...d", onehot, table)
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, *, tied_table=None):
    """Logits in fp32 (loss stability)."""
    table = tied_table if tied_table is not None else params["table"]
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      table.astype(jnp.float32))
