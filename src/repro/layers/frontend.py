"""Modality frontend STUBS (per the assignment: ``[audio]``/``[vlm]`` archs
specify the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

The stubs are honest about their interface: they take precomputed embeddings,
apply a small trainable projector + positional signal, and hand off to the
backbone.  Swapping in a real conv/CLIP frontend touches only this file.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import linear


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d // 2)]))
    return pe


def init_frontend(key, d_in: int, d_model: int, dtype=jnp.float32):
    """Projector from precomputed modality embeddings into the backbone width."""
    return {"proj": linear.init(key, d_in, d_model, bias=True, dtype=dtype)}


def apply_frontend(params, feats, *, add_positions: bool = True):
    """feats: (B, T, d_in) precomputed frame/patch embeddings -> (B, T, d_model)."""
    x = linear.apply(params["proj"], feats)
    if add_positions:
        x = x + sinusoidal_positions(x.shape[1], x.shape[2]).astype(x.dtype)
    return x
