"""Transformer ff module — THE site the paper targets with DYAD.

Supports SwiGLU (gate/up/down) and single-activation (GELU/ReLU) variants; all
projections go through the linear factory with ``site="ff"``.

Three DYAD execution tiers, picked per config:

* plain        — each projection through ``factory.apply`` (two/three ops);
* ``fuse_mlp`` — mixed-variant einsum fusion (up=IT, down=OT, 3-D
  block-layout hidden) for sharded runs;
* ``fuse_ff_kernel`` — the same dataflow as ONE Pallas megakernel
  (``kernels.ops.dyad_ff``): activation epilogue in-register, hidden never
  leaves VMEM.  Requires ``use_kernel`` and bias-free ff params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dyad as dyad_lib
from repro.core import factory
from repro.kernels import ops as kops
from repro.kernels.ref import ACTS as _ACTS
from repro.sharding import ctx as shard_ctx

# activations the ff megakernel can run as an in-register epilogue
# (_ACTS is the shared kernel-epilogue/oracle table in kernels.ref)
_FF_KERNEL_ACTS = frozenset({"swiglu", *_ACTS})


def init_mlp(key, d_model: int, d_ff: int, lin_cfg: factory.LinearCfg, *,
             act: str = "swiglu", bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "gate": factory.init(ks[0], d_model, d_ff, lin_cfg, site="ff",
                                 bias=bias, dtype=dtype),
            "up": factory.init(ks[1], d_model, d_ff, lin_cfg, site="ff",
                               bias=bias, dtype=dtype),
            "down": factory.init(ks[2], d_ff, d_model, lin_cfg, site="ff",
                                 bias=bias, dtype=dtype),
        }
    return {
        "up": factory.init(ks[0], d_model, d_ff, lin_cfg, site="ff",
                           bias=bias, dtype=dtype),
        "down": factory.init(ks[1], d_ff, d_model, lin_cfg, site="ff",
                             bias=bias, dtype=dtype),
    }


def _ff_kernel_ready(params, lin_cfg: factory.LinearCfg, act: str) -> bool:
    """Route this ff module through the one-grid Pallas megakernel?  Needs
    the config opt-in, a supported epilogue activation, bias-free DYAD
    params on every projection (the kernel has no bias epilogue; the
    default transformer ff is bias-free), and NO active tensor-parallel
    sharding context — the megakernel is a single-device dataflow, and a
    TP hidden needs the ``fuse_mlp`` path's block-layout sharding
    constraint (skipping it silently costs an all-gather per layer)."""
    if not (lin_cfg.fuse_ff_kernel and lin_cfg.use_kernel):
        return False
    if act not in _FF_KERNEL_ACTS:
        return False
    if shard_ctx.current() is not None:
        return False
    need = ("gate", "up", "down") if act == "swiglu" else ("up", "down")
    return all("w1" in params.get(k, {}) and "b" not in params[k]
               for k in need)


def _fused_dyad_mlp(params, x, lin_cfg: factory.LinearCfg, act: str):
    """Mixed-variant fused ff: up=IT (strided view on the replicated input),
    down=OT (strided view on the reduced output) — the hidden stays in the
    DYAD block layout (..., n, d_out) end-to-end, so its TP sharding on
    d_out never hits an inexpressible flat reshape (no all-gather)."""
    n = params["up"]["w1"].shape[0]
    spec = dyad_lib.DyadSpec(n_dyad=n, variant="it")
    if act == "swiglu":
        g = dyad_lib.apply_blocks(params["gate"], x, spec)
        u = dyad_lib.apply_blocks(params["up"], x, spec)
        h = jax.nn.silu(g) * u
    else:
        h = _ACTS[act](dyad_lib.apply_blocks(params["up"], x, spec))
    h = shard_ctx.constrain_ff_hidden(h)     # (..., n, d_out): last dim TP
    return dyad_lib.apply_ot_from_blocks(params["down"], h)


def apply_mlp(params, x, lin_cfg: factory.LinearCfg, *, act: str = "swiglu"):
    if _ff_kernel_ready(params, lin_cfg, act):
        # whole ff module in one Pallas grid; hidden never leaves VMEM.
        # Single-device dataflow — under tensor parallelism use fuse_mlp,
        # whose block-layout hidden carries the sharding constraint.
        return kops.dyad_ff(params, x, act=act,
                            use_kernel_bwd=lin_cfg.use_kernel_bwd)
    if lin_cfg.fuse_mlp and "w1" in params.get("down", {}):
        return _fused_dyad_mlp(params, x, lin_cfg, act)
    if act == "swiglu":
        g = factory.apply(params["gate"], x, lin_cfg, site="ff")
        u = factory.apply(params["up"], x, lin_cfg, site="ff")
        h = jax.nn.silu(g) * u
    else:
        h = _ACTS[act](factory.apply(params["up"], x, lin_cfg, site="ff"))
    h = shard_ctx.constrain_ff_hidden(h)
    return factory.apply(params["down"], h, lin_cfg, site="ff")
