"""Transformer ff module — THE site the paper targets with DYAD.

Supports SwiGLU (gate/up/down) and single-activation (GELU/ReLU) variants; all
projections go through the linear factory with ``site="ff"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import factory
from repro.sharding import ctx as shard_ctx

_ACTS = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
}


def init_mlp(key, d_model: int, d_ff: int, lin_cfg: factory.LinearCfg, *,
             act: str = "swiglu", bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "gate": factory.init(ks[0], d_model, d_ff, lin_cfg, site="ff",
                                 bias=bias, dtype=dtype),
            "up": factory.init(ks[1], d_model, d_ff, lin_cfg, site="ff",
                               bias=bias, dtype=dtype),
            "down": factory.init(ks[2], d_ff, d_model, lin_cfg, site="ff",
                                 bias=bias, dtype=dtype),
        }
    return {
        "up": factory.init(ks[0], d_model, d_ff, lin_cfg, site="ff",
                           bias=bias, dtype=dtype),
        "down": factory.init(ks[1], d_ff, d_model, lin_cfg, site="ff",
                             bias=bias, dtype=dtype),
    }


def _fused_dyad_mlp(params, x, lin_cfg: factory.LinearCfg, act: str):
    """Mixed-variant fused ff: up=IT (strided view on the replicated input),
    down=OT (strided view on the reduced output) — the hidden stays in the
    DYAD block layout (..., n, d_out) end-to-end, so its TP sharding on
    d_out never hits an inexpressible flat reshape (no all-gather)."""
    from repro.core import dyad as dyad_lib

    n = params["up"]["w1"].shape[0]
    spec = dyad_lib.DyadSpec(n_dyad=n, variant="it")
    if act == "swiglu":
        g = dyad_lib.apply_blocks(params["gate"], x, spec)
        u = dyad_lib.apply_blocks(params["up"], x, spec)
        h = jax.nn.silu(g) * u
    else:
        h = _ACTS[act](dyad_lib.apply_blocks(params["up"], x, spec))
    h = shard_ctx.constrain_ff_hidden(h)     # (..., n, d_out): last dim TP
    return dyad_lib.apply_ot_from_blocks(params["down"], h)


def apply_mlp(params, x, lin_cfg: factory.LinearCfg, *, act: str = "swiglu"):
    if lin_cfg.fuse_mlp and "w1" in params.get("down", {}):
        return _fused_dyad_mlp(params, x, lin_cfg, act)
    if act == "swiglu":
        g = factory.apply(params["gate"], x, lin_cfg, site="ff")
        u = factory.apply(params["up"], x, lin_cfg, site="ff")
        h = jax.nn.silu(g) * u
    else:
        h = _ACTS[act](factory.apply(params["up"], x, lin_cfg, site="ff"))
    h = shard_ctx.constrain_ff_hidden(h)
    return factory.apply(params["down"], h, lin_cfg, site="ff")
