"""Transformer ff module — THE site the paper targets with DYAD.

Supports SwiGLU (gate/up/down) and single-activation (GELU/ReLU) variants; all
projections go through the linear factory with ``site="ff"``.

Three DYAD execution tiers, picked per config:

* plain        — each projection through ``factory.apply`` (two/three ops);
* ``fuse_mlp`` — mixed-variant einsum fusion (up=IT, down=OT, 3-D
  block-layout hidden) for sharded runs;
* ``fuse_ff_kernel`` — the same dataflow as ONE Pallas megakernel
  (``kernels.ops.dyad_ff``): activation epilogue in-register, hidden never
  leaves VMEM.  Requires ``use_kernel`` and bias-free ff params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import faults, obs
from repro import quant as quant_lib
from repro.core import dyad as dyad_lib
from repro.core import factory
from repro.kernels import ops as kops
from repro.kernels import tp as ktp
from repro.kernels.ref import ACTS as _ACTS
from repro.sharding import ctx as shard_ctx

# activations the ff megakernel can run as an in-register epilogue
# (_ACTS is the shared kernel-epilogue/oracle table in kernels.ref)
_FF_KERNEL_ACTS = frozenset({"swiglu", *_ACTS})


def init_mlp(key, d_model: int, d_ff: int, lin_cfg: factory.LinearCfg, *,
             act: str = "swiglu", bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "gate": factory.init(ks[0], d_model, d_ff, lin_cfg, site="ff",
                                 bias=bias, dtype=dtype),
            "up": factory.init(ks[1], d_model, d_ff, lin_cfg, site="ff",
                               bias=bias, dtype=dtype),
            "down": factory.init(ks[2], d_ff, d_model, lin_cfg, site="ff",
                                 bias=bias, dtype=dtype),
        }
    return {
        "up": factory.init(ks[0], d_model, d_ff, lin_cfg, site="ff",
                           bias=bias, dtype=dtype),
        "down": factory.init(ks[1], d_ff, d_model, lin_cfg, site="ff",
                             bias=bias, dtype=dtype),
    }


def _ff_module_ok(params, act: str) -> bool:
    """Bias-free DYAD ff params with a supported epilogue activation — the
    shape of module the megakernel (and its einsum twin
    ``_fused_dyad_mlp``) computes."""
    if act not in _FF_KERNEL_ACTS:
        return False
    need = ("gate", "up", "down") if act == "swiglu" else ("up", "down")
    return all("w1" in params.get(k, {}) and "b" not in params[k]
               for k in need)


def _ff_kernel_ready(params, lin_cfg: factory.LinearCfg, act: str) -> bool:
    """Route this ff module through the one-grid Pallas megakernel?  Needs
    the config opt-in, a supported epilogue activation, and bias-free DYAD
    params on every projection (the kernel has no bias epilogue; the
    default transformer ff is bias-free).  Under an active sharding
    context the megakernel runs PER-SHARD via ``kernels.tp.dyad_ff_tp``
    (shard_map over the model axis, hidden split like
    ``constrain_ff_hidden``) when the hidden divides the axis; otherwise —
    or with ``REPRO_KERNEL_TP=off`` — the ``fuse_mlp`` einsum path keeps
    the block-layout sharding constraint.  Both TP outcomes are counted
    (``ff_tp``: ``tp_fused`` vs ``tp_fallback``) so a config that silently
    loses the kernel is visible in ``--metrics-json``."""
    if not (lin_cfg.fuse_ff_kernel and lin_cfg.use_kernel):
        return False
    if not _ff_module_ok(params, act):
        return False
    ctx = shard_ctx.current()
    if ctx is None:
        return True
    ready = ktp.ff_tp_ready(params, ctx)
    obs.route_event("ff_tp", "tp_fused" if ready else "tp_fallback",
                    tp=ctx.axis_size(ctx.model))
    return ready


def _ff_quant_ready(params, lin_cfg: factory.LinearCfg, act: str) -> bool:
    """Route this ff module through the quantized-weight-stream kernels?
    Needs the ``quant`` config opt-in ON TOP of the megakernel conditions,
    plus the offline sidecar leaves on every projection
    (``repro.quant.quantize_params``) — a param tree without them (training
    params, fp checkpoints) silently keeps the fp routes.  Every decision
    is counted under ``ff_quant`` (payload dtype vs ``off`` vs
    ``fp_fallback``) so a config that silently loses the quantized stream
    shows up in ``--metrics-json``."""
    if not (lin_cfg.quant and lin_cfg.use_kernel and lin_cfg.fuse_ff_kernel):
        return False
    if not _ff_module_ok(params, act):
        return False
    if not quant_lib.enabled():
        obs.route_event("ff_quant", "off", forced=True)
        return False
    if not quant_lib.ff_quantized(params):
        obs.route_event("ff_quant", "fp_fallback")
        return False
    ctx = shard_ctx.current()
    if ctx is not None and ctx.axis_size(ctx.model) > 1:
        if not ktp.ff_tp_ready(params, ctx):
            obs.route_event("ff_quant", "fp_fallback",
                            tp=ctx.axis_size(ctx.model))
            return False
    obs.route_event("ff_quant", lin_cfg.quant)
    return True


def _fused_dyad_mlp(params, x, lin_cfg: factory.LinearCfg, act: str):
    """Mixed-variant fused ff: up=IT (strided view on the replicated input),
    down=OT (strided view on the reduced output) — the hidden stays in the
    DYAD block layout (..., n, d_out) end-to-end, so its TP sharding on
    d_out never hits an inexpressible flat reshape (no all-gather)."""
    n = params["up"]["w1"].shape[0]
    spec = dyad_lib.DyadSpec(n_dyad=n, variant="it")
    if act == "swiglu":
        g = dyad_lib.apply_blocks(params["gate"], x, spec)
        u = dyad_lib.apply_blocks(params["up"], x, spec)
        h = jax.nn.silu(g) * u
    else:
        h = _ACTS[act](dyad_lib.apply_blocks(params["up"], x, spec))
    h = shard_ctx.constrain_ff_hidden(h)     # (..., n, d_out): last dim TP
    return dyad_lib.apply_ot_from_blocks(params["down"], h)


def apply_mlp(params, x, lin_cfg: factory.LinearCfg, *, act: str = "swiglu"):
    if _ff_quant_ready(params, lin_cfg, act):
        # quantized weight streams through the megakernel (or, under an
        # active TP context, per-shard inside shard_map).  Forward-only:
        # the quantized snapshot is frozen, nothing differentiates it.
        ctx = shard_ctx.current()
        if ctx is not None and ctx.axis_size(ctx.model) > 1:
            y = ktp.dyad_ff_quant_tp(params, x, act=act, ctx=ctx)
        else:
            y = kops.dyad_ff_quant(params, x, act=act)
        # chaos hook: kernel_nan route=ff_quant models corrupt quantized
        # blocks — the serving demotion ladder's first rung (quant -> fp)
        return faults.poison(y, "kernel_nan", route="ff_quant")
    if _ff_kernel_ready(params, lin_cfg, act):
        # whole ff module in one Pallas grid; hidden never leaves VMEM.
        # Under tensor parallelism the same grid runs per-shard inside
        # shard_map with an overlapped psum_scatter reduce (kernels.tp).
        ctx = shard_ctx.current()
        if ctx is not None and ctx.axis_size(ctx.model) > 1:
            return ktp.dyad_ff_tp(params, x, act=act,
                                  use_kernel_bwd=lin_cfg.use_kernel_bwd,
                                  ctx=ctx)
        return kops.dyad_ff(params, x, act=act,
                            use_kernel_bwd=lin_cfg.use_kernel_bwd)
    # fuse_ff_kernel modules that can't run the kernel here (TP fallback,
    # REPRO_KERNEL_TP=off) drop to the SAME up=IT/act/down=OT dataflow as
    # einsums — the megakernel's function, not the plain all-IT chain.
    use_blocks = (lin_cfg.fuse_mlp
                  or (lin_cfg.fuse_ff_kernel and _ff_module_ok(params, act)))
    if use_blocks and "w1" in params.get("down", {}):
        return _fused_dyad_mlp(params, x, lin_cfg, act)
    if act == "swiglu":
        g = factory.apply(params["gate"], x, lin_cfg, site="ff")
        u = factory.apply(params["up"], x, lin_cfg, site="ff")
        h = jax.nn.silu(g) * u
    else:
        h = _ACTS[act](factory.apply(params["up"], x, lin_cfg, site="ff"))
    h = shard_ctx.constrain_ff_hidden(h)
    return factory.apply(params["down"], h, lin_cfg, site="ff")
