"""Mixture-of-Experts ff module (top-k routed + shared experts).

Dispatch is GShard-style with **batch rows as capacity groups** and an optional
``lax.scan`` over sequence chunks, chosen so the layer composes with GSPMD
without shard_map:

* tokens stay on their data shard (groups = batch rows, sharded over
  ``data``/``pod``);
* expert weights are sharded over ``model`` on the leading expert axis (EP);
* expert compute is fully local — each (data, model) device processes its
  batch rows against its expert shard;
* the only collective is ONE all-reduce of the combined output over ``model``
  per layer (inserted by GSPMD at the combine einsum) — identical comm to a
  dense TP MLP.

Capacity: ``C = ceil(Sc * top_k * capacity_factor / n_experts)`` per batch row
per chunk; overflow tokens are dropped (standard dropped-token MoE).  Experts
are padded up to a multiple of the mesh ``model`` size; padded experts are
masked to -inf in the router and receive no tokens.

Each expert's FFN goes through the linear factory (``site="ff"``) — DYAD
applies *inside* experts, composing the paper's technique with EP.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import factory, linear
from repro.layers import mlp as mlp_lib
from repro.sharding import ctx as shard_ctx


def init_moe(
    key,
    d_model: int,
    expert_d_ff: int,
    n_experts: int,
    top_k: int,
    lin_cfg: factory.LinearCfg,
    *,
    n_shared: int = 0,
    shared_d_ff: Optional[int] = None,
    act: str = "swiglu",
    n_experts_padded: Optional[int] = None,
    dtype=jnp.float32,
):
    e_pad = n_experts_padded or n_experts
    ks = jax.random.split(key, 3)
    expert_keys = jax.random.split(ks[0], e_pad)
    experts = jax.vmap(
        lambda k: mlp_lib.init_mlp(k, d_model, expert_d_ff, lin_cfg, act=act,
                                   dtype=dtype)
    )(expert_keys)
    p = {
        "router": linear.init(ks[1], d_model, e_pad, bias=False, dtype=dtype),
        "experts": experts,
    }
    if n_shared:
        sk1, sk2 = jax.random.split(ks[2])
        p["shared"] = mlp_lib.init_mlp(
            sk1, d_model, shared_d_ff or n_shared * expert_d_ff, lin_cfg,
            act=act, dtype=dtype)
        p["shared_gate"] = linear.init(sk2, d_model, 1, bias=False, dtype=dtype)
    return p


def _route(params, x, n_experts: int, top_k: int):
    """x: (..., D) -> (weights, idx, probs): top-k renormalized weights."""
    e_pad = params["router"]["w"].shape[0]
    logits = linear.apply(params["router"], x.astype(jnp.float32))
    if e_pad > n_experts:  # mask padded experts
        pad_mask = jnp.arange(e_pad) >= n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, top_k)
    weights = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return weights, idx, probs


def _dispatch_combine(xc, weights, idx, e_pad: int, top_k: int, capacity: int):
    """One chunk: xc (B, Sc, D); returns (expert_in (B,E,C,D), combine (B,Sc,E,C))."""
    # position of each (token, slot) within its expert, per batch row.
    oh = jax.nn.one_hot(idx, e_pad, dtype=jnp.float32)       # (B,Sc,k,E)
    # sequentialize the k slots: slot j sees counts from slots < j.
    pos = jnp.cumsum(oh.reshape(oh.shape[0], -1, e_pad), axis=1).reshape(oh.shape) - oh
    pos = jnp.einsum("bske->bsk", pos * oh)                   # (B,Sc,k) position
    keep = pos < capacity
    w = weights * keep                                        # dropped -> 0
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    # combine[b,s,e,c] = sum_j w[b,s,j] * oh[b,s,j,e] * cap_oh[b,s,j,c]
    combine = jnp.einsum("bsk,bske,bskc->bsec", w, oh, cap_oh)
    dispatch = (combine > 0).astype(xc.dtype)
    expert_in = jnp.einsum("bsec,bsd->becd", dispatch, xc)
    return expert_in, combine.astype(xc.dtype)


def _expert_ffn(experts, x, act: str):
    """Expert FFN with an EXPLICIT expert axis (no vmap): every intermediate
    carries E so sharding constraints can anchor EP end-to-end (a vmapped
    body hides the E axis from with_sharding_constraint — §Perf B2).

    x: (B, E, C, D).  DYAD experts use the mixed-variant fused form
    (up=IT, down=OT, block-layout hidden) — see DESIGN §7."""
    up = experts["up"]
    if "w1" in up:                                   # dyad experts
        n, d_out, d_in = up["w1"].shape[1:]

        def dyad_up(p):
            lead = x.shape[:-1]
            x1 = x.reshape(*lead, n, d_in)
            x2 = jnp.swapaxes(x.reshape(*lead, d_in, n), -1, -2)
            return (jnp.einsum("becgi,egoi->becgo", x1, p["w1"].astype(x.dtype))
                    + jnp.einsum("becgi,egoi->becgo", x2,
                                 p["w2"].astype(x.dtype)))

        if act == "swiglu":
            h = jax.nn.silu(dyad_up(experts["gate"])) * dyad_up(up)
        else:
            h = getattr(jax.nn, act if act != "gelu" else "gelu")(dyad_up(up))
        h = shard_ctx.constrain_expert_batch(h)       # (B,E,C,n,d_out)
        dn = experts["down"]
        z1 = jnp.einsum("becgi,egoi->becgo", h, dn["w1"].astype(x.dtype))
        z2 = jnp.einsum("becgi,egoi->becgo", h, dn["w2"].astype(x.dtype))
        nd, d2 = z1.shape[-2], z1.shape[-1]
        y = (z1.reshape(*z1.shape[:-2], nd * d2)
             + jnp.swapaxes(z2, -1, -2).reshape(*z2.shape[:-2], nd * d2))
        return y

    # dense experts: (E, f_out, f_in) weights
    if act == "swiglu":
        g = jnp.einsum("becd,efd->becf", x, experts["gate"]["w"].astype(x.dtype))
        u = jnp.einsum("becd,efd->becf", x, up["w"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        fn = {"gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
        h = fn(jnp.einsum("becd,efd->becf", x, up["w"].astype(x.dtype)))
    h = shard_ctx.constrain_expert_batch(h)
    return jnp.einsum("becf,edf->becd", h, experts["down"]["w"].astype(x.dtype))


def apply_moe(
    params,
    x,
    lin_cfg: factory.LinearCfg,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "swiglu",
    chunk: Optional[int] = None,
):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    e_pad = params["router"]["w"].shape[0]
    weights, idx, probs = _route(params, x, n_experts, top_k)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    fe = jnp.mean(
        jax.nn.one_hot(idx, e_pad, dtype=jnp.float32).sum(-2), axis=(0, 1))
    aux = n_experts * jnp.sum(me * fe) / top_k

    Sc = min(chunk or S, S)
    assert S % Sc == 0, f"seq {S} must divide moe chunk {Sc}"
    capacity = max(1, int(Sc * top_k * capacity_factor / n_experts))

    def run_chunk(xc, wc, ic):
        expert_in, combine = _dispatch_combine(xc, wc, ic, e_pad, top_k, capacity)
        expert_in = shard_ctx.constrain_expert_batch(expert_in)
        eo = _expert_ffn(params["experts"], expert_in, act)
        eo = shard_ctx.constrain_expert_batch(eo)
        return jnp.einsum("bsec,becd->bsd", combine, eo)

    if Sc == S:
        y = run_chunk(x, weights, idx)
    else:
        ns = S // Sc
        xs = (
            x.reshape(B, ns, Sc, D).swapaxes(0, 1),
            weights.reshape(B, ns, Sc, -1).swapaxes(0, 1),
            idx.reshape(B, ns, Sc, -1).swapaxes(0, 1),
        )
        _, ys = jax.lax.scan(lambda c, t: (c, run_chunk(*t)), None, xs)
        y = ys.swapaxes(0, 1).reshape(B, S, D)

    if "shared" in params:
        g = jax.nn.sigmoid(
            linear.apply(params["shared_gate"], x.astype(jnp.float32)))
        y = y + g.astype(x.dtype) * mlp_lib.apply_mlp(
            params["shared"], x, lin_cfg, act=act)
    return y, aux
