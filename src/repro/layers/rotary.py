"""Rotary position embeddings (RoPE), supporting arbitrary position offsets
(required for single-token decode against a long KV cache)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies, fp32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S).

    Angles are computed in fp32 (positions can exceed bf16 range); the big
    (..., S, H, hd) rotation math stays in the activation dtype."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv     # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)         # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)
