"""Grouped-query attention with RoPE, qk-norm, QKV-bias, sliding windows,
cross-attention, KV-cache decode, and an online-softmax chunked path for long
sequences (bounded memory; the production path for the 32k shapes).

The Q/K/V/O projections are created through the linear factory with
``site="attn"`` — DYAD substitutes them when the config scope says so.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro import faults, obs
from repro.core import factory
from repro.kernels import ops as kops
from repro.kernels import tp as ktp
from repro.layers import norms
from repro.layers.rotary import apply_rope
from repro.sharding import ctx as shard_ctx

NEG_INF = -1e30


def init_attention(
    key,
    d_model: int,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    lin_cfg: factory.LinearCfg,
    *,
    qkv_bias: bool = False,
    qk_norm: bool = False,
    out_bias: bool = False,
    dtype=jnp.float32,
):
    ks = jax.random.split(key, 4)
    p = {
        "wq": factory.init(ks[0], d_model, n_heads * head_dim, lin_cfg,
                           site="attn", bias=qkv_bias, dtype=dtype),
        "wk": factory.init(ks[1], d_model, n_kv * head_dim, lin_cfg,
                           site="attn", bias=qkv_bias, dtype=dtype),
        "wv": factory.init(ks[2], d_model, n_kv * head_dim, lin_cfg,
                           site="attn", bias=qkv_bias, dtype=dtype),
        "wo": factory.init(ks[3], n_heads * head_dim, d_model, lin_cfg,
                           site="attn", bias=out_bias, dtype=dtype),
    }
    if qk_norm:
        p["q_norm"] = norms.init_rmsnorm(head_dim, dtype)
        p["k_norm"] = norms.init_rmsnorm(head_dim, dtype)
    return p


def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    """Boolean (..., S, T) validity mask from absolute positions."""
    m = jnp.broadcast_to(kpos[..., None, :] >= 0,
                         jnp.broadcast_shapes(qpos[..., :, None].shape,
                                              kpos[..., None, :].shape))
    if causal:
        m &= kpos[..., None, :] <= qpos[..., :, None]
    if window is not None:
        m &= qpos[..., :, None] - kpos[..., None, :] < window
    return m


def _sdpa_mask(qpos, kpos, causal: bool, window: Optional[int]):
    """Validity mask rank-expanded to broadcast against (B,S,K,G,T) scores
    — THE shared broadcast/rank-fixup for every sdpa path (``kpos`` may be
    (T,) or per-batch (B, T); the head axes are always size-1)."""
    m = _mask(qpos, kpos, causal, window)            # (S, T) or (B,S,T)
    return (m[:, :, None, None, :] if m.ndim == 3
            else m[None, :, None, None, :])


def _naive_sdpa(q, k, v, qpos, kpos, causal, window):
    """q: (B,S,K,G,h); k,v: (B,T,K,h) -> (B,S,K,G,h).

    Inputs stay in the activation dtype; score ACCUMULATION and softmax run
    in fp32 (preferred_element_type), probabilities are cast back for the AV
    matmul.  Scores are laid out (B,S,K,G,T) — q's natural layout — so the
    einsum chain needs no score-sized transposes (§Perf A4).  Masked
    probabilities are explicitly zeroed and the denominator guarded
    (``max(l, 1e-30)``, parity with ``_chunked_sdpa``): a fully-masked row
    yields output 0, not the softmax-of-NEG_INF uniform average."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bskgh,btkh->bskgt", q, k,
                   preferred_element_type=jnp.float32) * scale
    m = _sdpa_mask(qpos, kpos, causal, window)
    s = jnp.where(m, s, NEG_INF)
    e = jnp.where(m, jnp.exp(s - jnp.max(s, axis=-1, keepdims=True)), 0.0)
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bskgt,btkh->bskgh", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def _online_step(carry, q, kb, vb, qpos, pb, causal, window, scale):
    """One online-softmax update over a key chunk — THE shared step body
    for `_chunked_sdpa` and `_q_block_sdpa` (and the contract the flash
    kernels implement in VMEM).  Masked probabilities are explicitly
    zeroed so a fully-masked row accumulates l == 0 (-> output 0 after
    the ``max(l, 1e-30)`` guard) on every route."""
    m_prev, l_prev, acc = carry
    s = jnp.einsum("bskgh,btkh->bskgt", q, kb,
                   preferred_element_type=jnp.float32) * scale
    valid = _sdpa_mask(qpos, pb, causal, window)
    s = jnp.where(valid, s, NEG_INF)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum(
        "bskgt,btkh->bskgh", p.astype(vb.dtype), vb,
        preferred_element_type=jnp.float32)
    return (m_new, l_new, acc)


def _chunked_sdpa(q, k, v, qpos, kpos, causal, window, chunk: int):
    """Online-softmax over key chunks: memory O(S * chunk) instead of O(S*T).

    ``kpos`` may be (T,) or per-batch (B, T) — the latter from per-slot
    continuous-batching caches."""
    B, T = k.shape[0], k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    nchunks = -(-T // chunk)
    pad = nchunks * chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, [(0, 0)] * (kpos.ndim - 1) + [(0, pad)],
                       constant_values=-(10 ** 9))
    kc = k.reshape(B, nchunks, chunk, *k.shape[2:]).swapaxes(0, 1)
    vc = v.reshape(B, nchunks, chunk, *v.shape[2:]).swapaxes(0, 1)
    pc = (kpos.reshape(B, nchunks, chunk).swapaxes(0, 1) if kpos.ndim == 2
          else kpos.reshape(nchunks, chunk))

    def step(carry, xs):
        kb, vb, pb = xs
        return _online_step(carry, q, kb, vb, qpos, pb, causal, window,
                            scale), None

    S, K, G, h = q.shape[1], q.shape[2], q.shape[3], q.shape[4]
    init = (
        jnp.full((B, S, K, G), NEG_INF, jnp.float32),
        jnp.zeros((B, S, K, G), jnp.float32),
        jnp.zeros((B, S, K, G, h), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)                           # (B,S,K,G,h)


def _q_block_sdpa(q, k, v, qpos, kpos, causal, window, chunk: int):
    """Block BOTH q and k: a ``lax.scan`` over q-blocks (O(1) trace size —
    the seed's Python unroll retraced the whole band per block and blew up
    compile time at 32k) with an inner online-softmax scan over key
    chunks.  Key chunks wholly outside a q-block's causal/window band are
    skipped at runtime via ``lax.cond`` on position bounds, so the banded
    FLOP savings of the old unroll survive the scan.  Memory per step:
    O(chunk^2) scores instead of O(S*T).  This is the non-Pallas oracle
    route for long sequences; the production path is the flash kernel."""
    B, S, K, G, h = q.shape
    T = k.shape[1]
    nq = S // chunk
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    nk = -(-T // chunk)
    pad = nk * chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, pad),), constant_values=-(10 ** 9))
    kc = k.reshape(B, nk, chunk, K, h).swapaxes(0, 1)
    vc = v.reshape(B, nk, chunk, K, h).swapaxes(0, 1)
    pc = kpos.reshape(nk, chunk)

    def qblock(_, i):
        qb = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(qpos, i * chunk, chunk, axis=0)

        def kstep(carry, xs):
            kb, vb, pb = xs

            def update(c):
                return _online_step(c, qb, kb, vb, qp, pb, causal, window,
                                    scale)

            # runtime band skip from position bounds (padding = -1e9 is
            # excluded from the min/max so it can't widen the band)
            pvalid = pb >= 0
            pmax = jnp.max(jnp.where(pvalid, pb, -(10 ** 9)))
            active = pmax >= 0
            if causal:
                pmin = jnp.min(jnp.where(pvalid, pb, 10 ** 9))
                active &= pmin <= jnp.max(qp)
            if window is not None:
                active &= pmax > jnp.min(qp) - window
            return jax.lax.cond(active, update, lambda c: c, carry), None

        init = (jnp.full((B, chunk, K, G), NEG_INF, jnp.float32),
                jnp.zeros((B, chunk, K, G), jnp.float32),
                jnp.zeros((B, chunk, K, G, h), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kstep, init, (kc, vc, pc))
        ob = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, ob.astype(q.dtype)

    _, outs = jax.lax.scan(qblock, None, jnp.arange(nq))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, K, G, h)


def attention(
    params,
    x,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    lin_cfg: factory.LinearCfg,
    rope_theta: Optional[float] = 10000.0,
    positions=None,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: Optional[int] = None,
    flash: bool = False,    # route sdpa through the Pallas flash kernels
    kv_input=None,          # cross-attention source (B, T, D)
    cache=None,             # {"k","v","idx"} (dense ring) or
                            # {"pages_k","pages_v","block_table","idx"}
                            # (paged pool) for decode
):
    """Returns (out, new_cache).

    ``flash=True`` (``cfg.flash_attn``) routes the sdpa through the Pallas
    flash kernels (:mod:`repro.kernels.flash_attn`) whenever the kernel
    route is active (TPU, or ``REPRO_KERNEL_ATTN=flash``) and the call
    shape supports it: the no-cache forward and the cache prefill hit the
    fused prefill grid (the S < L case attends the post-write cache, so
    warm-cache continuation prefill is exact), the S=1 decode step hits
    the ring-cache decode kernel.  Under a tensor-parallel sharding
    context the same kernels run per-shard over the KV-head axis via
    shard_map (:mod:`repro.kernels.tp`) when the heads divide the model
    axis.  Cross-attention, non-divisible TP head counts (or
    ``REPRO_KERNEL_TP=off``), and per-batch (2-D) position vectors fall
    back to the chunked/naive einsum paths below (which also remain the
    off-TPU route and the correctness oracles).  CONTRACT: the no-cache flash path
    assumes 1-D ``positions`` are contiguous (``positions[0] + arange(S)``
    — true for every model dispatch site; contiguity of a traced vector
    cannot be checked at trace time); the S >= L windowed-ring prefill
    keeps that branch's documented fresh-stream assumption."""
    B, S, _ = x.shape
    K, G = n_kv, n_heads // n_kv
    q = factory.apply(params["wq"], x, lin_cfg, site="attn").reshape(B, S, n_heads, head_dim)
    src = kv_input if kv_input is not None else x
    Tsrc = src.shape[1]
    k = factory.apply(params["wk"], src, lin_cfg, site="attn").reshape(B, Tsrc, K, head_dim)
    v = factory.apply(params["wv"], src, lin_cfg, site="attn").reshape(B, Tsrc, K, head_dim)

    if "q_norm" in params:
        q = norms.rmsnorm(params["q_norm"], q)
        k = norms.rmsnorm(params["k_norm"], k)

    # anchor GSPMD: heads over model (or seq-parallel attention as fallback)
    q = shard_ctx.constrain_heads(q)
    k = shard_ctx.constrain_kv(k)
    v = shard_ctx.constrain_kv(v)

    if positions is None:
        offset = cache["idx"] if cache is not None else 0
        positions = (offset[..., None] + jnp.arange(S)
                     if getattr(offset, "ndim", 0) == 1
                     else offset + jnp.arange(S))
    qpos = positions
    if rope_theta is not None and kv_input is None:
        # cache path: Tsrc == S (k/v are the NEW tokens, roped before the
        # cache write so cached entries never need re-rotation).
        rp = qpos if qpos.ndim > 1 else jnp.broadcast_to(qpos, (S,))
        q = apply_rope(q, rp, rope_theta)
        k = apply_rope(k, rp, rope_theta)

    # flash routing decision (trace time).  Under an active sharding
    # context the flash kernels run PER-SHARD over the KV-head axis via
    # shard_map (kernels.tp) when the heads divide the model axis — GQA
    # groups stay whole per shard, the scalar-prefetched index/block-table
    # machinery rides along per device.  Non-divisible heads (or
    # REPRO_KERNEL_TP=off) keep the einsum paths, whose score layout
    # carries the GSPMD constraints; both outcomes are counted under the
    # ``attn_tp`` route so silent kernel loss shows up in --metrics-json.
    route = kops.attn_route() if flash and kv_input is None else None
    actx = shard_ctx.current()
    tp_ok = True
    if route == "flash" and actx is not None:
        tp_ok = ktp.attn_tp_ready(K, actx)
        obs.route_event("attn_tp", "tp_fused" if tp_ok else "tp_fallback",
                        tp=actx.axis_size(actx.model))
    use_flash = route == "flash" and tp_ok
    if actx is not None and actx.axis_size(actx.model) > 1:
        fa = functools.partial(ktp.flash_attention_tp, ctx=actx)
        fd = functools.partial(ktp.flash_decode_tp, ctx=actx)
        fdp = functools.partial(ktp.flash_decode_paged_tp, ctx=actx)
    else:
        fa, fd, fdp = (kops.flash_attention, kops.flash_decode,
                       kops.flash_decode_paged)
    k_inflight = v_inflight = None

    new_cache = None
    paged = cache is not None and "block_table" in cache
    if paged and kv_input is None:
        # -- paged pool: write through the block table, gather per slot --
        # The cache is a shared page pool (n_pages, P, K, h) + a per-slot
        # block table (B, NB): slot b's logical position j lives in page
        # ``bt[b, j // P]`` at offset ``j % P``.  Positions never wrap
        # (ordered tables — the engine hands out fresh pages instead), so
        # kpos is simply j bounded by the write index, exactly the
        # unwrapped dense-ring layout.  Dead/free lanes must have their
        # table rows pointed at the reserved scratch page 0 by the engine,
        # so their writes land harmlessly off the live pages.
        idx = cache["idx"]                       # (B,) per-slot
        bt = cache["block_table"]                # (B, NB) page ids
        P = cache["pages_k"].shape[1]
        NB = bt.shape[1]
        Lcap = NB * P
        kd, vd = cache["pages_k"].dtype, cache["pages_v"].dtype
        j = idx[:, None] + jnp.arange(S)         # (B, S) absolute positions
        pid = jnp.take_along_axis(bt, jnp.clip(j // P, 0, NB - 1), axis=1)
        quant_kv = "scales_k" in cache
        if quant_kv:
            # int8 pool: quantize the new rows per (token, kv-head) —
            # scale over the contracted head dim — and write payload +
            # scale through the SAME block-table indices.  Chunked prefill
            # (S > 1) takes this path too, so prefill pages are quantized.
            from repro import quant as quant_lib
            obs.route_event("kv_quant", "int8")
            kq, ksc = quant_lib.quantize_kv_rows(k)
            vq, vsc = quant_lib.quantize_kv_rows(v)
            ck = cache["pages_k"].at[pid, j % P].set(kq.astype(kd))
            cv = cache["pages_v"].at[pid, j % P].set(vq.astype(vd))
            csk = cache["scales_k"].at[pid, j % P].set(ksc)
            csv = cache["scales_v"].at[pid, j % P].set(vsc)
            new_cache = {"pages_k": ck, "pages_v": cv, "scales_k": csk,
                         "scales_v": csv, "block_table": bt, "idx": idx + S}
        else:
            ck = cache["pages_k"].at[pid, j % P].set(k.astype(kd))
            cv = cache["pages_v"].at[pid, j % P].set(v.astype(vd))
            new_cache = {"pages_k": ck, "pages_v": cv, "block_table": bt,
                         "idx": idx + S}
        k_inflight, v_inflight = k, v
        attend_cache = True
        jl = jnp.arange(Lcap)[None, :]
        kpos = jnp.where(jl < (idx + S)[:, None], jl, -(10 ** 9))
        if not (use_flash and S == 1):
            # einsum / flash-prefill paths attend a per-slot DENSE view
            # gathered from the pool (the S=1 flash decode path instead
            # gathers in-kernel through the prefetched block table).
            gpid = bt[:, jnp.arange(Lcap) // P]  # (B, Lcap)
            gj = jnp.arange(Lcap) % P
            k = ck[gpid, gj]                     # (B, Lcap, K, h)
            v = cv[gpid, gj]
            if quant_kv:
                # XLA-side dequant of the dense view (oracle/off-TPU path)
                k = (k.astype(jnp.float32)
                     * csk[gpid, gj][..., None]).astype(k_inflight.dtype)
                v = (v.astype(jnp.float32)
                     * csv[gpid, gj][..., None]).astype(v_inflight.dtype)
    elif cache is not None and kv_input is None:
        idx = cache["idx"]
        L = cache["k"].shape[1]
        kd, vd = cache["k"].dtype, cache["v"].dtype
        attend_cache = True      # False: attend the in-flight K/V (S >= L)
        if idx.ndim == 0:
            # shared write offset: every batch row is at the same position
            # (the homogeneous-batch Engine path).
            if S == 1:
                # ring-buffer write: supports caches bounded to the attention
                # window (slot = idx % L).  For full-length caches idx < L and
                # this reduces to a plain indexed write.
                slot = idx % L
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(kd), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(vd), (0, slot, 0, 0))
                j = jnp.arange(L)
                kpos = idx - ((idx - j) % L)      # position held by each slot
                kpos = jnp.where(kpos >= 0, kpos, -(10 ** 9))
            elif S < L:
                # multi-token (prefill) write requires idx + S <= cache length.
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(kd), (0, idx, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(vd), (0, idx, 0, 0))
                kpos = jnp.arange(L)
                kpos = jnp.where(kpos < idx + S, kpos, -(10 ** 9))
            else:
                # prompt at least fills the window-bounded ring (S >= L):
                # attend over the full in-flight K/V (the window mask bounds
                # the reach) and persist only the last L tokens, laid out at
                # their ring slots (slot = position % L) so decode continues
                # seamlessly.  Assumes a fresh-stream prefill (queries do not
                # reach keys written before ``idx``).
                kpos = idx + jnp.arange(S)
                sel = S - L + ((jnp.arange(L) - idx - S) % L)
                ck, cv = k.astype(kd)[:, sel], v.astype(vd)[:, sel]
                attend_cache = False
        else:
            # per-slot write offsets, idx: (B,) — the continuous-batching
            # path where heterogeneous requests share one padded step.
            # kpos becomes (B, L) so masking stays per-slot exact.
            j = jnp.arange(L)[None, :]
            if S == 1 or S < L:
                start = idx % L if S == 1 else idx   # ring wrap in decode
                write = lambda buf, new, i: jax.lax.dynamic_update_slice(
                    buf, new, (i, 0, 0))
                ck = jax.vmap(write)(cache["k"], k.astype(kd), start)
                cv = jax.vmap(write)(cache["v"], v.astype(vd), start)
                if S == 1:
                    kpos = idx[:, None] - ((idx[:, None] - j) % L)
                    kpos = jnp.where(kpos >= 0, kpos, -(10 ** 9))
                else:
                    kpos = jnp.where(j < idx[:, None] + S, j, -(10 ** 9))
            else:
                # per-slot variant of the S >= L windowed-ring prefill
                kpos = idx[:, None] + jnp.arange(S)
                sel = S - L + ((j - idx[:, None] - S) % L)
                ck = jnp.take_along_axis(k.astype(kd), sel[..., None, None],
                                         axis=1)
                cv = jnp.take_along_axis(v.astype(vd), sel[..., None, None],
                                         axis=1)
                attend_cache = False
        k_inflight, v_inflight = k, v      # roped new tokens (flash prefill)
        if attend_cache:
            k, v = ck, cv
        new_cache = {"k": ck, "v": cv, "idx": idx + S}
    else:
        kpos = jnp.arange(k.shape[1])

    qg = q.reshape(B, S, K, G, head_dim)
    if use_flash and paged and kv_input is None and S == 1:
        # paged decode: K/V tiles are gathered through the scalar-prefetched
        # block table in-kernel — the dense per-slot view is never built.
        # Quantized pools ship their scale pools through the same gather;
        # the kernel dequantizes per token-row in VMEM.
        o = fdp(qg, new_cache["pages_k"], new_cache["pages_v"], bt, idx,
                window=window, scales_k=new_cache.get("scales_k"),
                scales_v=new_cache.get("scales_v"))
    elif use_flash and cache is not None and kv_input is None and S == 1:
        # ring-cache decode: per-slot key positions derive from the
        # scalar-prefetched write index inside the kernel.
        o = fd(qg, k, v, idx, window=window)
    elif use_flash and cache is None and qpos.ndim == 1:
        # plain forward (training / encoder): contiguous positions
        # qpos[0] + arange(S) against keys at arange(T).
        o = fa(qg, k, v, qpos[0], 0, causal=causal, window=window,
               use_kernel_bwd=getattr(lin_cfg, "use_kernel_bwd", True))
    elif use_flash and cache is not None and S > 1 and causal:
        if attend_cache:
            # S < L linear cache prefill: attend the POST-WRITE cache.
            # Slot j holds position j, queries sit at idx + arange(S), so
            # q_off=idx / k_off=0 reproduces the einsum branch EXACTLY —
            # keys cached before ``idx`` included (warm-cache continuation
            # prefill), tail slots j > idx+s excluded by the causal mask,
            # out-of-band key tiles band-skipped from the prefetched idx.
            o = fa(qg, k, v, idx, 0, causal=True, window=window)
        else:
            # S >= L windowed-ring prefill: the cache cannot hold the
            # prompt; attend the in-flight roped K/V at idx + arange(S) —
            # the same fresh-stream contract the einsum branch documents.
            o = fa(qg, k_inflight, v_inflight, idx, idx, causal=True,
                   window=window)
    elif (chunk is not None and cache is None and kv_input is None
            and S > chunk and S % chunk == 0 and qpos.ndim == 1):
        o = _q_block_sdpa(qg, k, v, qpos, kpos, causal, window, chunk)
    elif chunk is not None and k.shape[1] > chunk:
        o = _chunked_sdpa(qg, k, v, qpos, kpos, causal, window, chunk)
    else:
        o = _naive_sdpa(qg, k, v, qpos, kpos, causal, window)
    if use_flash:
        # chaos hook: kernel_nan route=attn_flash simulates a broken flash
        # kernel; demotion to REPRO_KERNEL_ATTN=xla re-traces off it
        o = faults.poison(o, "kernel_nan", route="attn_flash")
    o = o.reshape(B, S, n_heads * head_dim)
    out = factory.apply(params["wo"], o, lin_cfg, site="attn")
    return out, new_cache


def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16, *, per_slot: bool = False):
    """KV cache pytree.  ``per_slot=True`` makes ``idx`` a (batch,) vector so
    each batch row (continuous-batching slot) advances independently."""
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "idx": jnp.zeros((batch,) if per_slot else (), jnp.int32),
    }


def init_paged_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int,
                        dtype=jnp.bfloat16, *, page_size: int,
                        n_pages: int, quant: Optional[str] = None):
    """Paged KV cache pytree: one shared ``(n_pages, page_size, K, h)``
    pool per K/V, a per-slot ``(batch, ceil(max_len / page_size))`` block
    table, and per-slot write indices.  Page 0 is RESERVED as the scratch
    page: block tables init to it, so unallocated entries (and the decode
    writes of free/prefilling lanes the engine points at it) land
    harmlessly off the live pages.  The engine's ``PageAllocator`` owns
    pages ``1 .. n_pages - 1``.

    ``quant="int8"`` stores the pools as int8 payloads plus per-token-row
    fp32 scale pools ``scales_k``/``scales_v`` ``(n_pages, page_size, K)``
    (~2-4x more tokens per HBM byte vs bf16/fp32 pools); the write path
    quantizes rows as they land and the paged decode kernel dequantizes
    in-VMEM after the block-table gather."""
    n_blocks = -(-max_len // page_size)
    cache = {
        "block_table": jnp.zeros((batch, n_blocks), jnp.int32),
        "idx": jnp.zeros((batch,), jnp.int32),
    }
    if quant is not None:
        if quant != "int8":
            raise ValueError(f"kv_quant supports 'int8' only, got {quant!r}")
        cache["pages_k"] = jnp.zeros(
            (n_pages, page_size, n_kv, head_dim), jnp.int8)
        cache["pages_v"] = jnp.zeros(
            (n_pages, page_size, n_kv, head_dim), jnp.int8)
        cache["scales_k"] = jnp.zeros(
            (n_pages, page_size, n_kv), jnp.float32)
        cache["scales_v"] = jnp.zeros(
            (n_pages, page_size, n_kv), jnp.float32)
    else:
        cache["pages_k"] = jnp.zeros(
            (n_pages, page_size, n_kv, head_dim), dtype)
        cache["pages_v"] = jnp.zeros(
            (n_pages, page_size, n_kv, head_dim), dtype)
    return cache
