"""Neural-network substrate layers; every linear goes through repro.core.factory."""
from repro.layers import (  # noqa: F401
    attention,
    embed,
    frontend,
    mlp,
    moe,
    norms,
    rotary,
    ssm,
)
