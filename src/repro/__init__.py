"""repro: DYAD structured-sparse linear layers in a multi-pod JAX framework.

The paper's contribution (DYAD-IT/OT/DT and -CAT) lives in :mod:`repro.core`.
Everything else is the substrate a production framework needs: model families,
sharding, optimizer, data, checkpointing, launch/dry-run tooling.
"""

__version__ = "0.1.0"
