"""Unified, hashable model configuration for every supported family."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core import factory

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}

FAMILIES = ("lm", "moe", "encdec", "ssm", "vlm", "hybrid")


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: Optional[float] = 10000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    window: Optional[int] = None          # sliding-window attention
    attn_chunk: Optional[int] = None      # online-softmax key chunking
    # route sdpa through the Pallas flash kernels (prefill grid + ring-cache
    # decode) when the kernel route is active; off-TPU the chunked/naive
    # einsum paths remain the hot path (REPRO_KERNEL_ATTN forces either)
    flash_attn: bool = False
    # ff
    d_ff: int = 0
    act: str = "swiglu"
    mlp_bias: bool = False
    # norm / embeddings
    norm: str = "rmsnorm"                 # "rmsnorm" | "layernorm"
    pos_embed: str = "rope"               # "rope" | "learned" | "none"
    max_position: int = 1 << 20
    tie_embeddings: bool = False
    # one-hot (iota) embedding lookup: keeps the vocab-sharded table's
    # gradient a plain matmul (no giant scatter under GSPMD) — the
    # Megatron/MaxText trick.  On for production configs.
    iota_embed: bool = False
    # moe
    n_experts: int = 0
    n_experts_padded: int = 0
    top_k: int = 0
    n_shared: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_chunk: Optional[int] = None
    router_aux_coef: float = 0.01
    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssd_chunk: int = 256
    # encoder-decoder
    n_enc_layers: int = 0
    n_frames: int = 0
    frontend_dim: int = 0
    # vlm
    n_patches: int = 0
    # the paper's knob
    linear: factory.LinearCfg = factory.DENSE
    # serving-only KV-cache quantization: "int8" stores paged K/V pools as
    # int8 payloads with per-token-row fp32 scale pools; the paged decode
    # kernel dequantizes tiles in-kernel after the block-table gather.
    # None keeps the cache dtype the engine asks for.  Engines plumb this
    # to init_paged_kv_cache; REPRO_KERNEL_QUANT=off disables it.
    kv_quant: Optional[str] = None
    # precision & memory
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    # training-shape hints consumed by the launcher
    grad_accum: int = 1

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def e_pad(self) -> int:
        return self.n_experts_padded or self.n_experts

    def replace(self, **kw) -> "ModelCfg":
        return dataclasses.replace(self, **kw)
