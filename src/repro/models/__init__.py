"""Model families (lm / moe / encdec / ssm / vlm / hybrid), scan-over-layers."""
from repro.models.config import ModelCfg  # noqa: F401
from repro.models import blocks, model  # noqa: F401
