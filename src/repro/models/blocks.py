"""Per-layer blocks for every model family.

Each family uses ONE homogeneous block kind so the whole stack can be
``jax.lax.scan``-ed over stacked layer params (keeps compiled HLO size O(1) in
depth — required to compile 126-layer models, and the idiomatic TPU pattern).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_lib
from repro.layers import mlp as mlp_lib
from repro.layers import moe as moe_lib
from repro.layers import norms
from repro.layers import ssm as ssm_lib
from repro.models.config import ModelCfg
from repro.sharding import ctx as shard_ctx


def _init_norm(cfg: ModelCfg, dtype):
    if cfg.norm == "layernorm":
        return norms.init_layernorm(cfg.d_model, dtype)
    return norms.init_rmsnorm(cfg.d_model, dtype)


def _apply_norm(cfg: ModelCfg, p, x):
    if cfg.norm == "layernorm":
        return norms.layernorm(p, x)
    return norms.rmsnorm(p, x)


def init_block(key, cfg: ModelCfg, kind: str):
    """kind: lm | moe | ssm | hybrid | enc | dec_cross."""
    dtype = cfg.pdtype
    ks = jax.random.split(key, 6)
    p = {"norm1": _init_norm(cfg, dtype)}
    if kind in ("lm", "moe", "hybrid", "enc", "dec_cross"):
        p["attn"] = attn_lib.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.linear,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, dtype=dtype)
    if kind in ("ssm", "hybrid"):
        skey = ks[1] if kind == "hybrid" else ks[0]
        p["ssm"] = ssm_lib.init_ssm(
            skey, cfg.d_model, cfg.linear, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            n_groups=cfg.ssm_groups, conv_width=cfg.conv_width, dtype=dtype)
    if kind == "hybrid":
        p["bnorm_a"] = norms.init_rmsnorm(cfg.d_model, dtype)
        p["bnorm_s"] = norms.init_rmsnorm(cfg.d_model, dtype)
    if kind == "dec_cross":
        p["xnorm"] = _init_norm(cfg, dtype)
        p["xattn"] = attn_lib.init_attention(
            ks[2], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.linear,
            qkv_bias=cfg.qkv_bias, qk_norm=False, dtype=dtype)
    if kind != "ssm":
        p["norm2"] = _init_norm(cfg, dtype)
        if kind == "moe":
            p["moe"] = moe_lib.init_moe(
                ks[3], cfg.d_model, cfg.expert_d_ff, cfg.n_experts, cfg.top_k,
                cfg.linear, n_shared=cfg.n_shared, act=cfg.act,
                n_experts_padded=cfg.e_pad, dtype=dtype)
        else:
            p["mlp"] = mlp_lib.init_mlp(
                ks[3], cfg.d_model, cfg.d_ff, cfg.linear, act=cfg.act,
                bias=cfg.mlp_bias, dtype=dtype)
    return p


def _self_attn(p, cfg: ModelCfg, x, *, causal, cache, positions):
    return attn_lib.attention(
        p, x, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
        lin_cfg=cfg.linear,
        rope_theta=cfg.rope_theta if cfg.pos_embed == "rope" else None,
        positions=positions, causal=causal, window=cfg.window,
        chunk=cfg.attn_chunk, flash=cfg.flash_attn, cache=cache)


def _ssm_with_cache(params, cfg: ModelCfg, h, cache, prefill: bool):
    """Cached SSM mixer: one-token recurrent step, or the single-pass
    multi-token prefill (full chunked SSD forward + cache handoff)."""
    if prefill:
        return ssm_lib.ssm_prefill(
            params, h, cache, cfg.linear, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
            chunk=cfg.ssd_chunk)
    return ssm_lib.ssm_decode_step(
        params, h, cache, cfg.linear, d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups)


def apply_block(
    params,
    x,
    cfg: ModelCfg,
    kind: str,
    *,
    cache=None,
    enc_out=None,
    positions=None,
    prefill: bool = False,
):
    """Returns (x, new_cache, aux).

    ``cache`` selects the cached (serving) path; ``prefill=True`` marks a
    multi-token teacher-forced pass THROUGH the cache (single-pass prefill) —
    attention writes S tokens of K/V at once and the SSM mixer runs the
    chunked SSD forward instead of S recurrent steps.
    """
    new_cache = {} if cache is not None else None
    aux = jnp.zeros((), jnp.float32)
    causal = kind != "enc"

    x = shard_ctx.constrain_residual(x)
    h = _apply_norm(cfg, params["norm1"], x)
    if kind == "hybrid":
        a, kv = _self_attn(params["attn"], cfg, h, causal=True,
                           cache=cache.get("kv") if cache else None,
                           positions=positions)
        if cache is not None:
            s, sc = _ssm_with_cache(params["ssm"], cfg, h, cache["ssm"],
                                    prefill)
            new_cache = {"kv": kv, "ssm": sc}
        else:
            s = ssm_lib.apply_ssm(
                params["ssm"], h, cfg.linear, d_state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
                chunk=cfg.ssd_chunk)
        # parallel heads, per-branch output norm, averaged (hymba-style)
        x = x + 0.5 * (norms.rmsnorm(params["bnorm_a"], a) +
                       norms.rmsnorm(params["bnorm_s"], s))
    elif kind == "ssm":
        if cache is not None:
            s, sc = _ssm_with_cache(params["ssm"], cfg, h, cache["ssm"],
                                    prefill)
            new_cache = {"ssm": sc}
        else:
            s = ssm_lib.apply_ssm(
                params["ssm"], h, cfg.linear, d_state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups,
                chunk=cfg.ssd_chunk)
        return x + s, new_cache, aux
    else:
        a, kv = _self_attn(params["attn"], cfg, h, causal=causal,
                           cache=cache.get("kv") if cache else None,
                           positions=positions)
        if cache is not None:
            new_cache["kv"] = kv
        x = x + a

    if kind == "dec_cross":
        h = _apply_norm(cfg, params["xnorm"], x)
        if cache is not None and "xk" in cache:
            # cross K/V precomputed at prefill; attend directly.
            xa = _cross_from_cache(params["xattn"], cfg, h, cache)
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
        else:
            xa, _ = attn_lib.attention(
                params["xattn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.hd, lin_cfg=cfg.linear, rope_theta=None,
                positions=jnp.arange(h.shape[1]), causal=False,
                kv_input=enc_out)
        x = x + xa

    h = _apply_norm(cfg, params["norm2"], x)
    if kind == "moe":
        m, aux = moe_lib.apply_moe(
            params["moe"], h, cfg.linear, n_experts=cfg.n_experts,
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, act=cfg.act,
            chunk=cfg.moe_chunk)
    else:
        m = mlp_lib.apply_mlp(params["mlp"], h, cfg.linear, act=cfg.act)
    return x + m, new_cache, aux


def _cross_from_cache(p, cfg: ModelCfg, q_in, cache):
    """Cross-attention against precomputed encoder K/V (decode path)."""
    from repro.core import factory
    B, S, _ = q_in.shape
    K, G = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q = factory.apply(p["wq"], q_in, cfg.linear, site="attn").reshape(
        B, S, cfg.n_heads, cfg.hd)
    if "q_norm" in p:
        q = norms.rmsnorm(p["q_norm"], q)
    qg = q.reshape(B, S, K, G, cfg.hd)
    T = cache["xk"].shape[1]
    o = attn_lib._naive_sdpa(qg, cache["xk"], cache["xv"],
                             jnp.zeros((S,), jnp.int32),
                             jnp.arange(T), False, None)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return factory.apply(p["wo"], o, cfg.linear, site="attn")


def init_block_cache(cfg: ModelCfg, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16, *, per_slot: bool = False,
                     page_size: int = None, n_pages: int = None):
    """Cache pytree for ONE block (stacked over layers by the model).

    ``per_slot=True`` gives the KV cache a per-batch-row write index so each
    row (continuous-batching slot) can sit at a different sequence position.
    ``page_size``/``n_pages`` swap the dense KV ring for a paged pool +
    block table (see :func:`repro.layers.attention.init_paged_kv_cache`);
    the write index is per-slot by construction there.
    """
    c = {}
    if kind in ("lm", "moe", "hybrid", "dec_cross"):
        if page_size is not None:
            from repro import quant as quant_lib
            kvq = cfg.kv_quant if quant_lib.enabled() else None
            c["kv"] = attn_lib.init_paged_kv_cache(
                batch, max_len, cfg.n_kv_heads, cfg.hd, dtype,
                page_size=page_size, n_pages=n_pages, quant=kvq)
        else:
            # ring buffer when sliding-window attention bounds the reach
            L = min(max_len, cfg.window) if cfg.window else max_len
            c["kv"] = attn_lib.init_kv_cache(batch, L, cfg.n_kv_heads,
                                             cfg.hd, dtype,
                                             per_slot=per_slot)
    if kind in ("ssm", "hybrid"):
        c["ssm"] = ssm_lib.init_ssm_cache(
            batch, cfg.d_model, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
            n_groups=cfg.ssm_groups, conv_width=cfg.conv_width,
            dtype=cfg.cdtype)
    if kind == "dec_cross":
        c["xk"] = jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd), dtype)
        c["xv"] = jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, cfg.hd), dtype)
    return c
