"""Family-dispatched model: init / forward / loss / cache / decode_step.

Layer params are stacked on a leading ``n_layers`` axis and the stack is
``jax.lax.scan``-ed (with optional remat) — HLO size stays O(1) in depth.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers import embed as embed_lib
from repro.layers import frontend as frontend_lib
from repro.layers import norms
from repro.models import blocks
from repro.models.config import ModelCfg

_KIND = {
    "lm": "lm",
    "moe": "moe",
    "ssm": "ssm",
    "vlm": "lm",
    "hybrid": "hybrid",
    "encdec": "dec_cross",
}


def block_kind(cfg: ModelCfg) -> str:
    return _KIND[cfg.family]


def _stacked_init(key, n: int, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


def init_params(cfg: ModelCfg, key) -> dict:
    ks = jax.random.split(key, 8)
    dtype = cfg.pdtype
    p = {
        "embed": embed_lib.init_embedding(ks[0], cfg.vocab_size, cfg.d_model,
                                          dtype),
        "layers": _stacked_init(
            ks[1], cfg.n_layers, lambda k: blocks.init_block(k, cfg,
                                                             block_kind(cfg))),
        "final_norm": (norms.init_layernorm(cfg.d_model, dtype)
                       if cfg.norm == "layernorm"
                       else norms.init_rmsnorm(cfg.d_model, dtype)),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_lib.init_embedding(ks[2], cfg.vocab_size,
                                             cfg.d_model, dtype)
    if cfg.pos_embed == "learned":
        p["pos"] = embed_lib.init_embedding(ks[3], cfg.max_position,
                                            cfg.d_model, dtype)
    if cfg.family == "encdec":
        p["enc_layers"] = _stacked_init(
            ks[4], cfg.n_enc_layers, lambda k: blocks.init_block(k, cfg, "enc"))
        p["enc_norm"] = (norms.init_layernorm(cfg.d_model, dtype)
                         if cfg.norm == "layernorm"
                         else norms.init_rmsnorm(cfg.d_model, dtype))
    if cfg.family in ("encdec", "vlm"):
        p["frontend"] = frontend_lib.init_frontend(
            ks[5], cfg.frontend_dim, cfg.d_model, dtype)
    return p


def _final_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return norms.layernorm(p, x)
    return norms.rmsnorm(p, x)


def _run_stack(cfg: ModelCfg, stacked, x, kind: str, *, enc_out=None,
               positions=None, caches=None, prefill: bool = False):
    """scan over stacked layer params (and caches).  Returns (x, caches, aux)."""

    def body(carry, scanned):
        h, aux = carry
        lp = scanned[0] if caches is not None else scanned
        lc = scanned[1] if caches is not None else None
        h, nc, a = blocks.apply_block(lp, h, cfg, kind, cache=lc,
                                      enc_out=enc_out, positions=positions,
                                      prefill=prefill)
        return (h, aux + a), nc

    if cfg.remat and caches is None:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (stacked, caches) if caches is not None else stacked
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        xs)
    return x, new_caches, aux


def encode(cfg: ModelCfg, params, frames):
    """Encoder pass (encdec family).  frames: (B, n_frames, frontend_dim)."""
    x = frontend_lib.apply_frontend(params["frontend"], frames)
    x = x.astype(cfg.cdtype)
    x, _, _ = _run_stack(cfg, params["enc_layers"], x, "enc",
                         positions=jnp.arange(x.shape[1]))
    return _final_norm(cfg, params["enc_norm"], x)


def _embed_inputs(cfg: ModelCfg, params, batch, offset=0):
    tokens = batch["tokens"]
    x = embed_lib.embed(params["embed"], tokens, iota=cfg.iota_embed)
    if cfg.family == "vlm" and "patches" in batch:
        pe = frontend_lib.apply_frontend(params["frontend"], batch["patches"],
                                         add_positions=False)
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    # offset may be a scalar (homogeneous batch) or a (B,) vector of per-slot
    # positions (continuous batching) -> positions (S,) or (B, S).
    if getattr(offset, "ndim", 0) == 1:
        positions = offset[:, None] + jnp.arange(S)
    else:
        positions = offset + jnp.arange(S)
    if cfg.pos_embed == "learned":
        x = x + embed_lib.embed(params["pos"], positions)  # pos table stays gathered
    return x.astype(cfg.cdtype), positions


def forward(cfg: ModelCfg, params, batch, *, last_only: bool = False):
    """Full-sequence forward.  Returns (logits_f32, aux).

    ``last_only`` slices to the final position BEFORE the unembedding —
    the production prefill path (a full (B,S,V) fp32 logit tensor at 32k
    sequence x 150k vocab is tens of GB per device)."""
    x, positions = _embed_inputs(cfg, params, batch)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, batch["frames"])
    x, _, aux = _run_stack(cfg, params["layers"], x, block_kind(cfg),
                           enc_out=enc_out, positions=positions)
    x = _final_norm(cfg, params["final_norm"], x)
    if cfg.family == "vlm" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]     # logits over text positions
    if last_only:
        x = x[:, -1:]
    head = params.get("head", params["embed"])
    return embed_lib.unembed(head, x), aux


def loss_fn(cfg: ModelCfg, params, batch):
    """Next-token cross-entropy (+ router aux).  labels < 0 are masked."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    # logsumexp - gold_logit form: partitions cleanly over a vocab-sharded
    # logits axis (no full log_softmax materialization on the bwd pass).
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux,
                   "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


def init_cache(cfg: ModelCfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               *, per_slot: bool = False, page_size: int = None,
               n_pages: int = None):
    """Stacked (n_layers-leading) decode cache for ``batch`` sequences.

    ``per_slot=True`` gives every leaf a batch axis at position 1 — including
    the KV write index, which becomes (n_layers, batch) so each slot advances
    independently (the continuous-batching layout).  ``page_size``/``n_pages``
    swap the dense KV rings for per-layer page pools + block tables (the
    paged serving layout; every layer gets its own pool slice, so one page id
    addresses the same logical page in all of them)."""
    one = blocks.init_block_cache(cfg, block_kind(cfg), batch, max_len, dtype,
                                  per_slot=per_slot, page_size=page_size,
                                  n_pages=n_pages)
    stacked = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (cfg.n_layers,) + leaf.shape).copy()
        if leaf.ndim > 0 else jnp.zeros((cfg.n_layers,), leaf.dtype), one)
    return stacked


def prefill_cross(cfg: ModelCfg, params, cache, frames):
    """encdec: run the encoder and fill per-layer cross K/V into the cache."""
    from repro.core import factory
    enc_out = encode(cfg, params, frames)
    B, T, _ = enc_out.shape

    def per_layer(lp):
        k = factory.apply(lp["xattn"]["wk"], enc_out, cfg.linear, site="attn")
        v = factory.apply(lp["xattn"]["wv"], enc_out, cfg.linear, site="attn")
        return (k.reshape(B, T, cfg.n_kv_heads, cfg.hd),
                v.reshape(B, T, cfg.n_kv_heads, cfg.hd))

    xk, xv = jax.vmap(per_layer)(params["layers"])
    cache = dict(cache)
    cache["xk"] = xk.astype(cache["xk"].dtype)
    cache["xv"] = xv.astype(cache["xv"].dtype)
    return cache


def prefill(cfg: ModelCfg, params, cache, tokens, *, frames=None,
            last_only: bool = True):
    """Single-pass prefill: ONE full-sequence forward with cache writes.

    tokens: (B, S) int32 prompts; cache: a fresh (or position-consistent)
    pytree from :func:`init_cache`.  Attention layers write all S tokens of
    K/V in one ``dynamic_update_slice``; SSM layers run the chunked SSD dual
    form and hand off the final recurrent state — no per-token Python loop,
    one jitted call per request batch.

    Requires ``pos + S <= cache length`` for full-length KV caches (windowed
    ring caches additionally need ``S <= window`` at prefill).

    Returns ``(logits, new_cache)`` with logits fp32 ``(B, 1, vocab)`` for the
    last position (``last_only=True``, the production path — a full (B,S,V)
    tensor at 32k x 150k is tens of GB) or ``(B, S, vocab)`` otherwise.  The
    returned cache is positioned at S, ready for :func:`decode_step`.
    """
    if cfg.family == "encdec" and frames is not None:
        cache = prefill_cross(cfg, params, cache, frames)
    offset = _cache_pos(cfg, cache)
    x, positions = _embed_inputs(cfg, params, {"tokens": tokens},
                                 offset=offset)
    x, new_cache, _ = _run_stack(cfg, params["layers"], x, block_kind(cfg),
                                 positions=positions, caches=cache,
                                 prefill=True)
    x = _final_norm(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    head = params.get("head", params["embed"])
    return embed_lib.unembed(head, x), new_cache


def decode_step(cfg: ModelCfg, params, cache, tokens):
    """One-token decode.  tokens: (B, 1).  Returns (logits, new_cache)."""
    offset = _cache_pos(cfg, cache)
    x, positions = _embed_inputs(cfg, params, {"tokens": tokens}, offset=offset)
    x, new_cache, _ = _run_stack(cfg, params["layers"], x, block_kind(cfg),
                                 positions=positions, caches=cache)
    x = _final_norm(cfg, params["final_norm"], x)
    head = params.get("head", params["embed"])
    return embed_lib.unembed(head, x), new_cache


def _cache_pos(cfg: ModelCfg, cache):
    kind = block_kind(cfg)
    if kind in ("lm", "moe", "hybrid", "dec_cross"):
        return cache["kv"]["idx"][0]
    return cache.get("pos", jnp.zeros((), jnp.int32))


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def non_embedding_param_count(params) -> int:
    total = param_count(params)
    emb = int(params["embed"]["table"].size)
    if "head" in params:
        emb += int(params["head"]["table"].size)
    if "pos" in params:
        emb += int(params["pos"]["table"].size)
    return total - emb
