"""Deterministic, seedable fault injection for chaos-testing the stack.

None of the resilience machinery (victim preemption, NaN demotion ladder,
checkpoint retry, straggler handling) is testable without a way to make the
rare failure happen on demand, deterministically.  This module is that way:
a process-global registry of **fault sites** threaded through the hot
paths —

========================  ===================================================
site                      where it fires
========================  ===================================================
``page_exhaustion``       ``PageAllocator.alloc`` raises :class:`PageExhausted`
                          even though pages are free (pool-pressure chaos)
``nan_logits``            the continuous engine's decode step poisons the
                          batch logits with NaN (in-jit, via a host flag)
``nan_loss``              the trainer poisons the step's gradients + loss
                          metric with NaN (via the ``_fault_poison`` batch key)
``kernel_nan``            kernel route dispatch (``layers/mlp.py``,
                          ``kernels/ops.py``, ``layers/attention.py``)
                          multiplies the routed output by NaN at trace time
                          when the active route matches ``route=`` —
                          simulates a numerically-broken kernel so the
                          demotion ladder has something to demote away from
``slow_step``             engine decode / trainer step sleeps ``ms=`` —
                          straggler and stall-localization chaos
``ckpt_io``               ``CheckpointManager`` writes raise
                          :class:`CheckpointIOError` (exercises retry/backoff)
========================  ===================================================

Schedules come from ``REPRO_FAULT`` (``site:k=v[,k=v];site2:...``) plus
``REPRO_FAULT_SEED``, or programmatically via :func:`configure`::

    REPRO_FAULT="page_exhaustion:p=0.05;nan_logits:at_step=3;ckpt_io:p=0.1"

Per-spec knobs:

* ``p=0.05``      — fire on each check with probability p (seeded RNG);
* ``at_step=3``   — fire exactly on the site's 3rd check (0-based), once;
* ``times=2``     — cap total fires (default: 1 for ``at_step``, unlimited
  for ``p``/unconditional);
* ``ms=50``       — payload for ``slow_step`` (milliseconds);
* ``route=x``     — only fire when the call site reports this route
  (``kernel_nan`` route labels: ``ff_quant``, ``ff_fused``, ``ff_split``,
  ``attn_flash``).

Determinism: each site draws from its OWN ``numpy`` generator seeded by
``(seed, site)``, so interleaving checks of different sites never perturbs a
site's firing sequence — the same schedule + seed fires at the same checks
regardless of what else runs.  The disabled fast path is one module-global
``bool`` load (:func:`active`), so production code pays nothing.
"""
from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Dict, Optional, Union

import numpy as np

from repro import obs

ENV_VAR = "REPRO_FAULT"
ENV_SEED = "REPRO_FAULT_SEED"

_FLOAT_KEYS = ("p", "ms")
_INT_KEYS = ("at_step", "times")


@dataclasses.dataclass
class FaultSpec:
    """One site's schedule (see the module docstring for the knobs)."""
    site: str
    p: float = 0.0
    at_step: Optional[int] = None
    times: Optional[int] = None
    ms: float = 0.0
    route: Optional[str] = None

    def __post_init__(self):
        if self.p and self.at_step is not None:
            raise ValueError(
                f"fault {self.site!r}: p= and at_step= are exclusive")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault {self.site!r}: p={self.p} not in [0,1]")
        if self.times is None and self.at_step is not None:
            self.times = 1          # a step trigger fires once by default


def parse(spec: str) -> Dict[str, FaultSpec]:
    """``"site:k=v[,k=v];site2:..."`` -> {site: FaultSpec}.  An entry with
    no knobs (``"kernel_nan"``) fires unconditionally while configured."""
    out: Dict[str, FaultSpec] = {}
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        site, _, kvs = part.partition(":")
        site = site.strip()
        kwargs: dict = {}
        for kv in kvs.split(","):
            kv = kv.strip()
            if not kv:
                continue
            k, _, v = kv.partition("=")
            k = k.strip()
            if k in _FLOAT_KEYS:
                kwargs[k] = float(v)
            elif k in _INT_KEYS:
                kwargs[k] = int(v)
            elif k == "route":
                kwargs[k] = v.strip()
            else:
                raise ValueError(f"unknown fault knob {k!r} in {part!r}")
        if site in out:
            raise ValueError(f"duplicate fault site {site!r}")
        out[site] = FaultSpec(site=site, **kwargs)
    return out


class FaultRegistry:
    """Seeded firing engine over a parsed schedule.  Owns per-site check /
    fire counters (the chaos tests and ``--metrics-json`` read them) and
    per-site RNG streams."""

    def __init__(self, specs: Dict[str, FaultSpec], seed: int = 0):
        self.specs = dict(specs)
        self.seed = int(seed)
        self._rng: Dict[str, np.random.Generator] = {
            site: np.random.default_rng(
                [self.seed, zlib.crc32(site.encode())])
            for site in specs
        }
        self.checks: Dict[str, int] = {site: 0 for site in specs}
        self.fired: Dict[str, int] = {site: 0 for site in specs}

    def check(self, site: str, route: Optional[str] = None
              ) -> Optional[FaultSpec]:
        """One firing decision for ``site``; returns the spec when the
        fault fires, else None.  Route-mismatched checks do not consume a
        draw or advance the site's check counter, so the same schedule
        fires identically whatever other routes run."""
        spec = self.specs.get(site)
        if spec is None:
            return None
        if spec.route is not None and route != spec.route:
            return None
        n = self.checks[site]
        self.checks[site] = n + 1
        if spec.times is not None and self.fired[site] >= spec.times:
            return None
        if spec.at_step is not None:
            fire = n == spec.at_step
        elif spec.p:
            fire = bool(self._rng[site].random() < spec.p)
        else:
            fire = True
        if not fire:
            return None
        self.fired[site] += 1
        obs.instant("fault", cat="fault", site=site, check=n,
                    route=route or "", fired=self.fired[site])
        return spec

    def snapshot(self) -> dict:
        """JSON-ready per-site tallies (rides in ``--metrics-json``)."""
        return {site: {"checks": self.checks[site],
                       "fired": self.fired[site]}
                for site in sorted(self.specs)}


# -- process-global registry -------------------------------------------------
# _ACTIVE is the one-load fast path: every hot-path check is
# ``if faults.active(): ...`` and production runs never go further.
_REGISTRY: Optional[FaultRegistry] = None
_ACTIVE = False
_ENV_LOADED = False


def configure(spec: Union[str, Dict[str, FaultSpec], None],
              seed: int = 0) -> Optional[FaultRegistry]:
    """Install a fault schedule (string syntax or pre-parsed specs);
    ``configure(None)`` clears it.  Returns the live registry."""
    global _REGISTRY, _ACTIVE, _ENV_LOADED
    _ENV_LOADED = True          # explicit config wins over the env var
    if spec is None:
        _REGISTRY, _ACTIVE = None, False
        return None
    specs = parse(spec) if isinstance(spec, str) else dict(spec)
    _REGISTRY = FaultRegistry(specs, seed=seed)
    _ACTIVE = bool(specs)
    return _REGISTRY


def reset() -> None:
    """Clear the schedule AND re-arm env loading (test isolation)."""
    global _REGISTRY, _ACTIVE, _ENV_LOADED
    _REGISTRY, _ACTIVE, _ENV_LOADED = None, False, False


def _load_env() -> None:
    global _ENV_LOADED
    _ENV_LOADED = True
    spec = os.environ.get(ENV_VAR, "").strip()
    if spec:
        configure(spec, seed=int(os.environ.get(ENV_SEED, "0") or 0))


def active() -> bool:
    """Is any fault schedule configured?  One global load on the hot path
    (after the first call has resolved ``REPRO_FAULT``)."""
    if not _ENV_LOADED:
        _load_env()
    return _ACTIVE


def registry() -> Optional[FaultRegistry]:
    if not _ENV_LOADED:
        _load_env()
    return _REGISTRY


def fire(site: str, route: Optional[str] = None) -> Optional[FaultSpec]:
    """One firing decision at ``site`` (None = keep going).  The per-site
    check counter advances on every call, so retries re-draw — a transient
    injected fault clears on the retry exactly like a real one."""
    if not active():
        return None
    return _REGISTRY.check(site, route=route)


def poison(x, site: str, route: Optional[str] = None):
    """Trace-time array poisoning for kernel-route sites: returns ``x``
    untouched unless ``site`` fires for ``route``, in which case the route's
    output is multiplied by NaN — the cheapest honest model of a
    numerically-broken kernel (detection sees NaN, the demotion ladder
    re-traces onto a different route whose label no longer matches)."""
    if not active():
        return x
    if _REGISTRY.check(site, route=route) is None:
        return x
    import jax.numpy as jnp
    return x * jnp.float32(jnp.nan)


def snapshot() -> dict:
    """Per-site check/fire tallies of the live registry ({} when off)."""
    reg = registry()
    return reg.snapshot() if reg else {}
