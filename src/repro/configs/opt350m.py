"""OPT-350m — the paper's larger-scale arch (§3.2, Tables 7/10):
24L d1024 16H d_ff=4096 v=50272.  (Published OPT-350m adds in/out projections
around a d=512 embedding; we use the uniform-width replica, matching how the
paper reports ff-module timings.)  [arXiv:2205.01068]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="opt-350m", family="lm",
        n_layers=24, d_model=1024, vocab_size=50272,
        n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, act="relu", mlp_bias=True,
        norm="layernorm", pos_embed="learned", max_position=2048,
        flash_attn=True,
        rope_theta=None, tie_embeddings=True,
        iota_embed=True,
        linear=DYAD_DEFAULT,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="opt-350m-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, max_position=128)
