"""hymba-1.5b [hybrid] — 32L d1600 25H (GQA kv=5) d_ff=5504 v=32001,
ssm_state=16; parallel attention + mamba heads per layer, sliding-window
attention (window=1024) => sub-quadratic, long_500k runnable.
[arXiv:2411.13676; hf]

Published Hymba keeps 3 global-attention layers + meta tokens; we model the
homogeneous SWA stack (scan-able; noted in DESIGN §4)."""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, vocab_size=32001,
        n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, act="swiglu",
        window=1024, attn_chunk=1024,
        ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
        conv_width=4, ssd_chunk=256,
        iota_embed=True,
        linear=DYAD_DEFAULT.replace(scope="ff+ssm"),
        compute_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="hymba-1.5b-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, window=8,
        attn_chunk=None, ssm_state=16, ssm_head_dim=16, ssd_chunk=8,
        compute_dtype="float32", remat=False)
