"""qwen3-0.6b [dense] — 28L d1024 16H (GQA kv=8) d_ff=3072 v=151936;
qk_norm, GQA, head_dim=128 explicit.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen3-0.6b", family="lm",
        n_layers=28, d_model=1024, vocab_size=151936,
        n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, act="swiglu",
        qk_norm=True, rope_theta=1e6,
        tie_embeddings=True,
        attn_chunk=2048,
        flash_attn=True,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        compute_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="qwen3-0.6b-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, attn_chunk=None,
        compute_dtype="float32", remat=False)
