"""phi3-medium-14b [dense] — 40L d5120 40H (GQA kv=10) d_ff=17920 v=100352;
RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="phi3-medium-14b", family="lm",
        n_layers=40, d_model=5120, vocab_size=100352,
        n_heads=40, n_kv_heads=10, head_dim=128,
        d_ff=17920, act="swiglu",
        attn_chunk=2048,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        compute_dtype="bfloat16", remat=True, grad_accum=2,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="phi3-medium-14b-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, attn_chunk=None,
        compute_dtype="float32", remat=False, grad_accum=1)
