"""whisper-medium [audio] — enc-dec, 24+24L d1024 16H (kv=16) d_ff=4096
v=51865; conv frontend is a STUB (input_specs provides precomputed frame
embeddings, 1500 frames x 1024).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="whisper-medium", family="encdec",
        n_layers=24, n_enc_layers=24,
        d_model=1024, vocab_size=51865,
        n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, act="gelu", mlp_bias=True,
        norm="layernorm", pos_embed="learned", max_position=1 << 16,
        rope_theta=None,
        n_frames=1500, frontend_dim=1024,
        attn_chunk=2048,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        compute_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="whisper-medium-smoke", n_layers=2, n_enc_layers=2, d_model=64,
        vocab_size=256, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        n_frames=8, frontend_dim=16, max_position=128, attn_chunk=None,
        compute_dtype="float32", remat=False)
