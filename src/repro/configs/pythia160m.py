"""Pythia-160m — the paper's generalization arch (§3.4.2, Tables 3/4/5):
12L d768 12H d_ff=3072 v=50304, GELU, LayerNorm, RoPE, untied embeddings.
(Published Pythia computes attention+mlp in parallel; we use the sequential
pre-norm form — noted in DESIGN §7.)  [arXiv:2304.01373 family]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="pythia-160m", family="lm",
        n_layers=12, d_model=768, vocab_size=50304,
        n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, act="gelu", mlp_bias=True,
        norm="layernorm", pos_embed="rope", rope_theta=10000.0,
        iota_embed=True,
        linear=DYAD_DEFAULT,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="pythia-160m-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128)
