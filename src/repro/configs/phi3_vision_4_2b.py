"""phi-3-vision-4.2b [vlm] — 32L d3072 32H (kv=32) d_ff=8192 v=32064;
phi3-mini backbone + CLIP frontend STUB (input_specs provides precomputed
patch embeddings, 576 patches x 1024).  [hf:microsoft/Phi-3-vision; hf]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="phi3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, vocab_size=32064,
        n_heads=32, n_kv_heads=32, head_dim=96,
        d_ff=8192, act="swiglu",
        n_patches=576, frontend_dim=1024,
        attn_chunk=2048,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        compute_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="phi3-vision-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, n_patches=6,
        frontend_dim=16, attn_chunk=None,
        compute_dtype="float32", remat=False)
