"""llama4-maverick-400b-a17b [moe] — 48L d5120 40H (GQA kv=8) expert
d_ff=8192 v=202048, 128 routed experts top-1 + 1 shared; early fusion.
[hf:meta-llama/Llama-4 family; unverified]

Published Maverick interleaves dense/MoE layers; we model the all-MoE stack
(homogeneous layers => scan-able; noted in DESIGN §4)."""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, vocab_size=202048,
        n_heads=40, n_kv_heads=8, head_dim=128,
        n_experts=128, top_k=1,
        expert_d_ff=8192, n_shared=1,
        capacity_factor=1.25, moe_chunk=4096,
        act="swiglu", attn_chunk=2048,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat=True, grad_accum=4,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="llama4-maverick-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, head_dim=16, n_experts=8, top_k=1,
        expert_d_ff=32, n_shared=1, moe_chunk=None, attn_chunk=None,
        param_dtype="float32", compute_dtype="float32", remat=False,
        grad_accum=1)
