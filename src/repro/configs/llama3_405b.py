"""llama3-405b [dense] — 126L d16384 128H (GQA kv=8) d_ff=53248 v=128256;
GQA, 128k vocab.  [arXiv:2407.21783; unverified]

Memory plan (v5e 16GB): bf16 params + bf16 Adam moments, FSDP(data) x TP(model)
sharded; activations remat'd; grad_accum=8 bounds the microbatch.  See
EXPERIMENTS §Dry-run for the compiled per-device bytes."""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="llama3-405b", family="lm",
        n_layers=126, d_model=16384, vocab_size=128256,
        n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, act="swiglu",
        rope_theta=5e5,
        attn_chunk=2048,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        param_dtype="bfloat16", compute_dtype="bfloat16",
        remat=True, grad_accum=8,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="llama3-405b-smoke", n_layers=2, d_model=128, vocab_size=256,
        n_heads=8, n_kv_heads=2, head_dim=16, d_ff=256, attn_chunk=None,
        param_dtype="float32", compute_dtype="float32", remat=False,
        grad_accum=1)
