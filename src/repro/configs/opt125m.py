"""OPT-125m — the paper's primary experimental architecture (§3.2):
12L d768 12H d_ff=3072 v=50272, ReLU, LayerNorm, learned positions, tied
embeddings.  [arXiv:2205.01068]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="opt-125m", family="lm",
        n_layers=12, d_model=768, vocab_size=50272,
        n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, act="relu", mlp_bias=True,
        norm="layernorm", pos_embed="learned", max_position=2048,
        flash_attn=True,
        rope_theta=None, tie_embeddings=True,
        iota_embed=True,
        linear=DYAD_DEFAULT,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="opt-125m-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, max_position=128)
