"""Config registry, dry-run shapes, and ShapeDtypeStruct input specs.

Every assigned architecture registers ``full()`` (the exact published config)
and ``smoke()`` (a reduced same-family config for CPU tests).  The DYAD knob
defaults to the paper's technique (IT, n_dyad=4, ff scope) and is overridable
per instantiation (``--linear dense`` in the launchers gives the baseline).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import factory
from repro.models import model
from repro.models.config import ModelCfg

DYAD_DEFAULT = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it", scope="ff")
DENSE = factory.DENSE


def linear_cfg(spec: str) -> factory.LinearCfg:
    """Parse "dense" | "dyad_it" | "dyad_ot_8" | "dyad_dt_4_cat" |
    "dyad_it_4_fused" (mixed-variant fused ff; EXPERIMENTS §Perf) |
    "dyad_it_4_kernel" (route through the fused Pallas kernels — forward
    AND backward — with autotuned tiles; interpret-mode on CPU) |
    "dyad_it_4_kernel_einsumbwd" (kernel forward, einsum-VJP oracle
    backward — the use_kernel_bwd=False escape hatch) |
    "dyad_it_4_kernel_ffused" (whole ff module as ONE Pallas megakernel —
    up [+ gate], in-register activation, down; hidden never leaves VMEM) |
    "dyad_it_4_kernel_ffused_w8" (serving-only: stream per-block int8
    weight sidecars with in-kernel dequant; "wfp8" for float8_e4m3fn;
    requires params through ``repro.quant.quantize_params``)."""
    if spec == "dense":
        return DENSE
    parts = spec.split("_")
    assert parts[0] == "dyad", spec
    variant = parts[1] if len(parts) > 1 else "it"
    n = int(parts[2]) if len(parts) > 2 and parts[2].isdigit() else 4
    quant = ("int8" if "w8" in parts
             else "fp8" if "wfp8" in parts else None)
    return factory.LinearCfg(impl="dyad", n_dyad=n, variant=variant,
                             cat="cat" in parts, fuse_mlp="fused" in parts,
                             use_kernel="kernel" in parts,
                             use_kernel_bwd="einsumbwd" not in parts,
                             fuse_ff_kernel="ffused" in parts,
                             quant=quant, scope="ff")


# ---------------------------------------------------------------------------
# shapes (the assignment's 4 cells; every arch pairs with all of them)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def sub_quadratic(cfg: ModelCfg) -> bool:
    """long_500k runs only for archs with bounded attention reach."""
    return cfg.family in ("ssm",) or (
        cfg.family == "hybrid" and cfg.window is not None)


def cell_runnable(cfg: ModelCfg, shape: Shape) -> tuple:
    """(runnable, reason-if-skipped)."""
    if shape.name == "long_500k" and not sub_quadratic(cfg):
        return False, "full-attention arch: O(S^2) at 500k (DESIGN §4)"
    return True, ""


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelCfg, shape: Shape, cache_dtype=jnp.bfloat16) -> dict:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = cfg.cdtype
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.frontend_dim), f)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frames, cfg.frontend_dim), f)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.frontend_dim), f)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frames, cfg.frontend_dim), f)
        return specs
    # decode: one new token against a cache of length seq_len
    cache = jax.eval_shape(
        lambda: model.init_cache(cfg, B, S, dtype=cache_dtype))
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32), "cache": cache}


def params_specs(cfg: ModelCfg) -> dict:
    return jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
ARCHS = [
    "qwen3_0_6b", "phi3_medium_14b", "qwen2_5_32b", "llama3_405b",
    "qwen2_moe_a2_7b", "llama4_maverick_400b_a17b", "whisper_medium",
    "mamba2_780m", "phi3_vision_4_2b", "hymba_1_5b",
]
PAPER_ARCHS = ["opt125m", "opt350m", "pythia160m"]


def get(arch: str, *, smoke: bool = False,
        linear: Optional[factory.LinearCfg] = None, **overrides) -> ModelCfg:
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = (mod.smoke if smoke else mod.full)()
    if linear is not None:
        cfg = cfg.replace(linear=linear)
    if overrides:
        cfg = cfg.replace(**overrides)
    return cfg


def all_archs():
    return list(ARCHS)
