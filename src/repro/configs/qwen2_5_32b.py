"""qwen2.5-32b [dense] — 64L d5120 40H (GQA kv=8) d_ff=27648 v=152064;
GQA, QKV bias.  [hf:Qwen/Qwen2.5 family; hf]"""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen2.5-32b", family="lm",
        n_layers=64, d_model=5120, vocab_size=152064,
        n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=27648, act="swiglu",
        qkv_bias=True, rope_theta=1e6,
        attn_chunk=2048,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        compute_dtype="bfloat16", remat=True, grad_accum=2,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="qwen2.5-32b-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, attn_chunk=None,
        compute_dtype="float32", remat=False, grad_accum=1)
