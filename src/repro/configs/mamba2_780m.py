"""mamba2-780m [ssm] — 48L d1536 (attention-free) v=50280, ssm_state=128;
SSD (state-space duality).  [arXiv:2405.21060; unverified]

DYAD applies to the in/out projections (the ff module does not exist in this
family — DESIGN §4 Arch-applicability)."""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="mamba2-780m", family="ssm",
        n_layers=48, d_model=1536, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
        conv_width=4, ssd_chunk=256,
        pos_embed="none", rope_theta=None,
        tie_embeddings=True,
        iota_embed=True,
        linear=DYAD_DEFAULT.replace(scope="ff+ssm"),
        compute_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="mamba2-780m-smoke", n_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssd_chunk=8,
        compute_dtype="float32", remat=False)
