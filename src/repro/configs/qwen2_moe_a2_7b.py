"""qwen2-moe-a2.7b [moe] — 24L d2048 16H (GQA kv=16) expert d_ff=1408
v=151936, 60 routed experts top-4 + 4 shared (shared d_ff = 4*1408 = 5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Experts are padded 60 -> 64 so the expert axis divides the mesh ``model``
size; padded experts are router-masked (DESIGN §5)."""
from repro.configs.base import DYAD_DEFAULT
from repro.models.config import ModelCfg


def full() -> ModelCfg:
    return ModelCfg(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, vocab_size=151936,
        n_heads=16, n_kv_heads=16, head_dim=128,
        qkv_bias=True,
        n_experts=60, n_experts_padded=64, top_k=4,
        expert_d_ff=1408, n_shared=4,
        capacity_factor=1.25, moe_chunk=4096,
        act="swiglu", attn_chunk=2048,
        iota_embed=True,
        linear=DYAD_DEFAULT,
        compute_dtype="bfloat16", remat=True,
    )


def smoke() -> ModelCfg:
    return full().replace(
        name="qwen2-moe-a2.7b-smoke", n_layers=2, d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=4, head_dim=16, n_experts=6, n_experts_padded=8,
        top_k=4, expert_d_ff=32, n_shared=2, moe_chunk=None, attn_chunk=None,
        compute_dtype="float32", remat=False)
