"""Architecture configs: 10 assigned archs + the paper's own (OPT, Pythia)."""
from repro.configs.base import (  # noqa: F401
    ARCHS,
    DENSE,
    DYAD_DEFAULT,
    PAPER_ARCHS,
    SHAPES,
    Shape,
    cell_runnable,
    get,
    input_specs,
    linear_cfg,
    params_specs,
    sub_quadratic,
)
