"""Benchmark suite registry.

Suites live in ``benchmarks/bench_*.py`` and register themselves:

    from repro import perf

    @perf.register("ff_timing")
    def run(): ...

``run_suite`` wraps the suite in a :class:`repro.perf.record.recording`
context (so every ``benchmarks.common.emit`` lands in a typed record) and
writes ``BENCH_<suite>.json``.  The registry itself is import-order
agnostic: ``benchmarks/run.py`` imports the suite modules, then asks the
registry to run them.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.perf.record import Recorder, recording

_SUITES: Dict[str, Callable[[], None]] = {}


def register(name: str) -> Callable:
    """Decorator: register ``fn`` as benchmark suite ``name``."""
    def deco(fn: Callable[[], None]) -> Callable[[], None]:
        _SUITES[name] = fn
        return fn
    return deco


def available_suites() -> List[str]:
    return sorted(_SUITES)


def get(name: str) -> Callable[[], None]:
    if name not in _SUITES:
        raise KeyError(
            f"unknown suite {name!r}; available: {available_suites()}")
    return _SUITES[name]


def run_suite(name: str, out_dir: str = ".",
              write: bool = True) -> Recorder:
    """Run one registered suite under a fresh recorder; optionally write
    ``BENCH_<name>.json`` into ``out_dir``.  Returns the recorder."""
    fn = get(name)
    with recording(name, out_dir) as rec:
        fn()
    if write:
        rec.write()
    return rec
