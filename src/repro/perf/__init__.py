"""Performance subsystem: autotuning, benchmark records, regression gating.

Three cooperating parts (see docs/ARCHITECTURE.md §Performance subsystem):

* :mod:`repro.perf.autotune` — per-(shape, dtype, backend) Pallas block-size
  sweeps with a persistent JSON cache.  ``get_tuned_blocks`` is the lookup
  the kernels call at trace time.
* :mod:`repro.perf.record` / :mod:`repro.perf.registry` — typed
  :class:`BenchResult` records and the suite registry behind
  ``python benchmarks/run.py --suite <name>``, which writes
  ``BENCH_<suite>.json`` at the repo root.
* :mod:`repro.perf.compare` / ``python -m repro.perf.check`` — diff a fresh
  run against the last committed ``BENCH_*.json`` and fail on regression.
* :mod:`repro.perf.timeline` — replay-diff of two ``--trace`` exports (or a
  trace vs a BENCH document): attributes a wall-time regression to the
  specific spans that got slower (``python -m repro.perf.timeline a b``).
"""
from repro.perf.autotune import (autotune_dyad, candidate_blocks,
                                 candidate_blocks_ff, get_tuned_blocks,
                                 memo_counts, tune_key, vmem_estimate_ff)
from repro.perf.record import (BenchResult, Recorder, current_recorder,
                               hlo_metrics, recording)
from repro.perf.registry import available_suites, register, run_suite

# NOTE: repro.perf.timeline is intentionally NOT imported here — it is a
# ``python -m`` entry point, and importing it from the package __init__
# makes runpy warn about the module already being in sys.modules.

__all__ = [
    "BenchResult", "Recorder", "current_recorder", "recording", "hlo_metrics",
    "register", "run_suite", "available_suites",
    "autotune_dyad", "candidate_blocks", "candidate_blocks_ff",
    "get_tuned_blocks", "memo_counts", "tune_key", "vmem_estimate_ff",
]
