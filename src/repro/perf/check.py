"""Regression gate: ``python -m repro.perf.check``.

Diffs every working-tree ``BENCH_*.json`` against the version last
committed to git (``--baseline-rev``, default ``HEAD``) and exits nonzero
if any record regressed beyond tolerance.  A suite with no committed
baseline passes (first run establishes the trajectory); a baseline
recorded on a different machine or backend is compared and printed but
never gated — raw wall-times are only comparable on the recording host,
so cross-machine runs (fresh clones, CI runners) need ``--cross-backend``
plus a generous ``--tol`` to opt into gating.

    python benchmarks/run.py --suite ff_timing     # writes BENCH_ff_timing.json
    python -m repro.perf.check                     # gate vs committed baseline
    python -m repro.perf.check --suite smoke --tol 3.0
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
from typing import List, Optional

from repro.perf import compare
from repro.perf.record import BenchResult, load_bench


def repo_root(start: Optional[str] = None) -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--show-toplevel"],
                             capture_output=True, text=True, timeout=10,
                             cwd=start or os.getcwd())
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return start or os.getcwd()


def committed_bench(rev: str, relpath: str, root: str) -> Optional[dict]:
    """``git show <rev>:<relpath>`` parsed as a BENCH document, or None if
    the file doesn't exist at that revision (or we're not in a git repo)."""
    try:
        out = subprocess.run(["git", "show", f"{rev}:{relpath}"],
                             capture_output=True, text=True, timeout=10,
                             cwd=root)
    except OSError:
        return None
    if out.returncode != 0:
        return None
    try:
        doc = json.loads(out.stdout)
        doc["results"] = [BenchResult.from_dict(d) for d in doc["results"]]
        return doc
    except (json.JSONDecodeError, KeyError, ValueError, TypeError) as e:
        print(f"warning: baseline {rev}:{relpath} unreadable ({e}); "
              f"treating as absent", file=sys.stderr)
        return None


def check_file(path: str, *, rev: str, tol: float, min_us: float,
               root: str, cross_backend: bool) -> int:
    rel = os.path.relpath(path, root)
    current = load_bench(path)
    baseline = committed_bench(rev, rel, root)
    print(f"\n== {rel} (suite={current.get('suite', '?')}, "
          f"backend={current.get('backend', '?')}, "
          f"sha={current.get('git_sha', '?')})")
    if baseline is None:
        print(f"   no baseline at {rev}: PASS (new trajectory)")
        return 0

    same_machine = (baseline.get("backend") == current.get("backend")
                    and baseline.get("host") == current.get("host"))
    rows = compare.compare_runs(baseline["results"], current["results"],
                                tol=tol, min_us=min_us)
    print(compare.format_table(rows))
    s = compare.summarize(rows)
    print(f"   {s['compared']} compared, {s['new']} new, "
          f"{s['removed']} removed, {s['regressed']} regressed "
          f"(tol={tol:.0%}, baseline backend="
          f"{baseline.get('backend', '?')} host="
          f"{baseline.get('host', '?')})")
    if not same_machine and not cross_backend:
        print("   baseline is from a different machine/backend — wall-time "
              "gate skipped (pass --cross-backend to enforce)")
        return 0
    return 1 if s["regressed"] else 0


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.perf.check", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--baseline-rev", default="HEAD",
                   help="git revision holding the baseline (default HEAD)")
    p.add_argument("--tol", type=float, default=compare.DEFAULT_TOL,
                   help="relative slowdown tolerance (0.25 = 25%% slower)")
    p.add_argument("--min-us", type=float, default=compare.DEFAULT_MIN_US,
                   help="ignore cells faster than this (timer noise floor)")
    p.add_argument("--suite", action="append", default=None,
                   help="only gate these suites (repeatable)")
    p.add_argument("--cross-backend", action="store_true",
                   help="gate wall-times even when the baseline was "
                        "recorded on a different machine or backend")
    p.add_argument("paths", nargs="*",
                   help="explicit BENCH_*.json paths (default: repo root)")
    args = p.parse_args(argv)

    root = repo_root()
    paths = args.paths or sorted(glob.glob(os.path.join(root,
                                                        "BENCH_*.json")))
    if args.suite:
        wanted = set(args.suite)
        paths = [q for q in paths
                 if os.path.basename(q)[len("BENCH_"):-len(".json")]
                 in wanted]
    if not paths:
        print("no BENCH_*.json found — run "
              "`python benchmarks/run.py --suite <name>` first")
        return 0

    rc = 0
    for path in paths:
        rc |= check_file(path, rev=args.baseline_rev, tol=args.tol,
                         min_us=args.min_us, root=root,
                         cross_backend=args.cross_backend)
    print("\nPERF GATE:", "FAIL" if rc else "PASS")
    return rc


if __name__ == "__main__":
    sys.exit(main())
