"""Regression gate: ``python -m repro.perf.check``.

Diffs every working-tree ``BENCH_*.json`` against the version last
committed to git (``--baseline-rev``, default ``HEAD``) and exits nonzero
if any record regressed beyond tolerance.  A suite with no committed
baseline passes (first run establishes the trajectory); a baseline
recorded on a different machine or backend is compared and printed but
never gated — raw wall-times are only comparable on the recording host,
so cross-machine runs (fresh clones, CI runners) need ``--cross-backend``
plus a generous ``--tol`` to opt into gating.

    python benchmarks/run.py --suite ff_timing     # writes BENCH_ff_timing.json
    python -m repro.perf.check                     # gate vs committed baseline
    python -m repro.perf.check --suite smoke --tol 3.0
    python -m repro.perf.check --json report.json  # machine-readable verdict
                                                   # (per-cell rows +
                                                   # regressed_cells for CI
                                                   # annotations; '-'=stdout)
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
from typing import List, Optional

from repro.perf import compare
from repro.perf.record import BenchResult, load_bench


def repo_root(start: Optional[str] = None) -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--show-toplevel"],
                             capture_output=True, text=True, timeout=10,
                             cwd=start or os.getcwd())
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return start or os.getcwd()


def committed_bench(rev: str, relpath: str, root: str) -> Optional[dict]:
    """``git show <rev>:<relpath>`` parsed as a BENCH document, or None if
    the file doesn't exist at that revision (or we're not in a git repo)."""
    try:
        out = subprocess.run(["git", "show", f"{rev}:{relpath}"],
                             capture_output=True, text=True, timeout=10,
                             cwd=root)
    except OSError:
        return None
    if out.returncode != 0:
        return None
    try:
        doc = json.loads(out.stdout)
        doc["results"] = [BenchResult.from_dict(d) for d in doc["results"]]
        return doc
    except (json.JSONDecodeError, KeyError, ValueError, TypeError) as e:
        print(f"warning: baseline {rev}:{relpath} unreadable ({e}); "
              f"treating as absent", file=sys.stderr)
        return None


def check_file(path: str, *, rev: str, tol: float, min_us: float,
               root: str, cross_backend: bool) -> dict:
    """Gate one BENCH file; returns a JSON-ready report dict whose
    ``"failed"`` key is the gate verdict for this file."""
    rel = os.path.relpath(path, root)
    current = load_bench(path)
    baseline = committed_bench(rev, rel, root)
    report = {
        "path": rel,
        "suite": current.get("suite"),
        "backend": current.get("backend"),
        "git_sha": current.get("git_sha"),
        "baseline_rev": rev,
        "gated": False,
        "failed": False,
        "rows": [],
    }
    print(f"\n== {rel} (suite={current.get('suite', '?')}, "
          f"backend={current.get('backend', '?')}, "
          f"sha={current.get('git_sha', '?')})")
    if baseline is None:
        print(f"   no baseline at {rev}: PASS (new trajectory)")
        report["baseline"] = None
        return report

    same_machine = (baseline.get("backend") == current.get("backend")
                    and baseline.get("host") == current.get("host"))
    rows = compare.compare_runs(baseline["results"], current["results"],
                                tol=tol, min_us=min_us)
    print(compare.format_table(rows))
    s = compare.summarize(rows)
    print(f"   {s['compared']} compared, {s['new']} new, "
          f"{s['removed']} removed, {s['regressed']} regressed "
          f"(tol={tol:.0%}, baseline backend="
          f"{baseline.get('backend', '?')} host="
          f"{baseline.get('host', '?')})")
    report["baseline"] = {"backend": baseline.get("backend"),
                          "host": baseline.get("host"),
                          "git_sha": baseline.get("git_sha")}
    report["summary"] = s
    report["rows"] = [{
        "name": r.name,
        "base_us": r.base_us,
        "cur_us": r.cur_us,
        "ratio": r.ratio,
        "status": r.status,
        "regressed": r.regressed,
    } for r in rows]
    gated = same_machine or cross_backend
    report["gated"] = gated
    if not gated:
        print("   baseline is from a different machine/backend — wall-time "
              "gate skipped (pass --cross-backend to enforce)")
        return report
    report["failed"] = bool(s["regressed"])
    return report


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.perf.check", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--baseline-rev", default="HEAD",
                   help="git revision holding the baseline (default HEAD)")
    p.add_argument("--tol", type=float, default=compare.DEFAULT_TOL,
                   help="relative slowdown tolerance (0.25 = 25%% slower)")
    p.add_argument("--min-us", type=float, default=compare.DEFAULT_MIN_US,
                   help="ignore cells faster than this (timer noise floor)")
    p.add_argument("--suite", action="append", default=None,
                   help="only gate these suites (repeatable)")
    p.add_argument("--cross-backend", action="store_true",
                   help="gate wall-times even when the baseline was "
                        "recorded on a different machine or backend")
    p.add_argument("--json", default=None, metavar="PATH", dest="json_out",
                   help="write a machine-readable report ('-' = stdout): "
                        "per-cell base/cur/ratio/status rows so CI can "
                        "annotate WHICH cell regressed without parsing "
                        "the table")
    p.add_argument("paths", nargs="*",
                   help="explicit BENCH_*.json paths (default: repo root)")
    args = p.parse_args(argv)

    root = repo_root()
    paths = args.paths or sorted(glob.glob(os.path.join(root,
                                                        "BENCH_*.json")))
    if args.suite:
        wanted = set(args.suite)
        paths = [q for q in paths
                 if os.path.basename(q)[len("BENCH_"):-len(".json")]
                 in wanted]
    if not paths:
        print("no BENCH_*.json found — run "
              "`python benchmarks/run.py --suite <name>` first")
        return 0

    reports = []
    for path in paths:
        reports.append(check_file(path, rev=args.baseline_rev, tol=args.tol,
                                  min_us=args.min_us, root=root,
                                  cross_backend=args.cross_backend))
    rc = 1 if any(r["failed"] for r in reports) else 0
    print("\nPERF GATE:", "FAIL" if rc else "PASS")
    if args.json_out:
        doc = {
            "pass": not rc,
            "tol": args.tol,
            "min_us": args.min_us,
            "cross_backend": args.cross_backend,
            "files": reports,
            "regressed_cells": [
                {"suite": r["suite"], "name": row["name"],
                 "base_us": row["base_us"], "cur_us": row["cur_us"],
                 "ratio": row["ratio"]}
                for r in reports for row in r["rows"] if row["regressed"]],
        }
        if args.json_out == "-":
            json.dump(doc, sys.stdout, indent=1, sort_keys=True)
            sys.stdout.write("\n")
        else:
            with open(args.json_out, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
                f.write("\n")
            print(f"json report: {args.json_out}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
