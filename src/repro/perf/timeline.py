"""Trace replay-diff: ``python -m repro.perf.timeline a.json b.json``.

Loads two runs and attributes the wall-time difference between them to
specific spans/ops — the profiler half of ROADMAP item 4 (byteprofile-style
trace replay): instead of "the run got 18% slower", the diff says "the
``decode_step`` spans account for +41 ms of the +47 ms, mean +0.8 ms/step".

Inputs may be either artifact the repo already produces:

* a Chrome-trace JSON exported by the tracer (``launch/serve.py --trace``,
  ``launch/train.py --trace``, ``benchmarks/run.py --trace``) — spans are
  aggregated by name (count, total, mean);
* a ``BENCH_<suite>.json`` benchmark document — each record becomes one
  "span" with its ``us_per_call`` (so a trace can be diffed against a
  committed baseline suite).

Rows are ranked by absolute total-time delta, so the top row *is* the
localization.  ``--fail-on-regress`` turns the diff into a gate (used by
the CI self-diff smoke, which must find nothing when a == b).

    python -m repro.perf.timeline base_trace.json new_trace.json
    python -m repro.perf.timeline trace.json BENCH_smoke.json --top 5
    python -m repro.perf.timeline t.json t.json --fail-on-regress  # == ok
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional

DEFAULT_TOL = 0.20        # mean-time growth beyond 20% marks a row regressed
DEFAULT_MIN_US = 50.0     # ignore sub-noise-floor total deltas


@dataclasses.dataclass
class SpanStats:
    """Aggregated timing of one span name within a run."""

    name: str
    count: int = 0
    total_us: float = 0.0

    @property
    def mean_us(self) -> float:
        return self.total_us / self.count if self.count else 0.0


@dataclasses.dataclass
class DiffRow:
    name: str
    base: Optional[SpanStats]
    cur: Optional[SpanStats]

    @property
    def delta_total_us(self) -> float:
        b = self.base.total_us if self.base else 0.0
        c = self.cur.total_us if self.cur else 0.0
        return c - b

    @property
    def mean_ratio(self) -> Optional[float]:
        if not (self.base and self.cur and self.base.count
                and self.cur.count):
            return None
        return self.cur.mean_us / max(self.base.mean_us, 1e-9)

    def regressed(self, tol: float, min_us: float) -> bool:
        r = self.mean_ratio
        return (r is not None and r > 1.0 + tol
                and self.delta_total_us >= min_us)

    @property
    def status(self) -> str:
        if self.base is None:
            return "NEW"
        if self.cur is None:
            return "REMOVED"
        return "ok"


def load_timeline(path: str) -> Dict[str, SpanStats]:
    """Per-span-name aggregate of one run.  Accepts a Chrome-trace document
    (``traceEvents``) or a ``BENCH_*.json`` (``results``)."""
    with open(path) as f:
        doc = json.load(f)
    stats: Dict[str, SpanStats] = {}

    def add(name: str, us: float) -> None:
        s = stats.get(name)
        if s is None:
            s = stats[name] = SpanStats(name)
        s.count += 1
        s.total_us += us

    if isinstance(doc, dict) and "traceEvents" in doc:
        for ev in doc["traceEvents"]:
            if ev.get("ph") == "X":
                add(ev.get("name", "?"), float(ev.get("dur", 0.0)))
        return stats
    if isinstance(doc, dict) and "results" in doc:
        for r in doc["results"]:
            if isinstance(r, dict) and "us_per_call" in r:
                add(r.get("name", "?"), float(r["us_per_call"]))
        return stats
    raise ValueError(f"{path}: neither a Chrome trace (traceEvents) nor a "
                     f"BENCH document (results)")


def diff_timelines(base: Dict[str, SpanStats], cur: Dict[str, SpanStats]
                   ) -> List[DiffRow]:
    """Rows for every span name in either run, ranked by |total delta| —
    the first row is where the wall time went."""
    rows = [DiffRow(name, base.get(name), cur.get(name))
            for name in set(base) | set(cur)]
    rows.sort(key=lambda r: -abs(r.delta_total_us))
    return rows


def _fmt_us(v: Optional[float]) -> str:
    if v is None:
        return "-"
    if abs(v) >= 1e6:
        return f"{v / 1e6:.2f}s"
    if abs(v) >= 1e3:
        return f"{v / 1e3:.1f}ms"
    return f"{v:.0f}us"


def format_diff(rows: List[DiffRow], *, top: int = 15,
                tol: float = DEFAULT_TOL,
                min_us: float = DEFAULT_MIN_US) -> str:
    hdr = (f"{'span':40s} {'n(base/cur)':>12s} {'base_total':>10s} "
           f"{'cur_total':>10s} {'d_total':>9s} {'base_mean':>10s} "
           f"{'cur_mean':>10s} {'ratio':>6s}  status")
    lines = [hdr, "-" * len(hdr)]
    for r in rows[:top]:
        nb = r.base.count if r.base else 0
        nc = r.cur.count if r.cur else 0
        ratio = r.mean_ratio
        status = ("REGRESSED" if r.regressed(tol, min_us)
                  else "faster" if (ratio is not None and ratio < 1.0 - tol
                                    and -r.delta_total_us >= min_us)
                  else r.status)
        lines.append(
            f"{r.name[:40]:40s} {f'{nb}/{nc}':>12s} "
            f"{_fmt_us(r.base.total_us if r.base else None):>10s} "
            f"{_fmt_us(r.cur.total_us if r.cur else None):>10s} "
            f"{_fmt_us(r.delta_total_us):>9s} "
            f"{_fmt_us(r.base.mean_us if r.base else None):>10s} "
            f"{_fmt_us(r.cur.mean_us if r.cur else None):>10s} "
            f"{'-' if ratio is None else f'{ratio:.2f}':>6s}  {status}")
    if len(rows) > top:
        lines.append(f"... {len(rows) - top} more spans (use --top)")
    return "\n".join(lines)


def attribute(rows: List[DiffRow], *, tol: float = DEFAULT_TOL,
              min_us: float = DEFAULT_MIN_US) -> List[DiffRow]:
    """The regression verdict: rows that got slower, worst first."""
    return [r for r in rows if r.regressed(tol, min_us)]


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.perf.timeline", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("base", help="baseline trace.json or BENCH_*.json")
    p.add_argument("current", help="current trace.json or BENCH_*.json")
    p.add_argument("--top", type=int, default=15,
                   help="rows to print (ranked by |total delta|)")
    p.add_argument("--tol", type=float, default=DEFAULT_TOL,
                   help="mean-time growth marking a span regressed")
    p.add_argument("--min-us", type=float, default=DEFAULT_MIN_US,
                   help="ignore spans whose total delta is below this")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also write the full diff as JSON ('-' = stdout)")
    p.add_argument("--fail-on-regress", action="store_true",
                   help="exit 1 when any span regressed beyond --tol")
    args = p.parse_args(argv)

    base = load_timeline(args.base)
    cur = load_timeline(args.current)
    rows = diff_timelines(base, cur)
    total_b = sum(s.total_us for s in base.values())
    total_c = sum(s.total_us for s in cur.values())
    print(f"base: {args.base} ({len(base)} spans, {_fmt_us(total_b)} total)")
    print(f"cur:  {args.current} ({len(cur)} spans, {_fmt_us(total_c)} "
          f"total, delta {_fmt_us(total_c - total_b)})")
    print()
    print(format_diff(rows, top=args.top, tol=args.tol, min_us=args.min_us))

    bad = attribute(rows, tol=args.tol, min_us=args.min_us)
    print()
    if bad:
        worst = bad[0]
        print(f"REGRESSION localized to span '{worst.name}': "
              f"{_fmt_us(worst.delta_total_us)} of the "
              f"{_fmt_us(total_c - total_b)} total delta "
              f"(mean {_fmt_us(worst.base.mean_us)} -> "
              f"{_fmt_us(worst.cur.mean_us)}, x{worst.mean_ratio:.2f}, "
              f"{worst.cur.count} calls)")
        for r in bad[1:4]:
            print(f"  also regressed: '{r.name}' "
                  f"{_fmt_us(r.delta_total_us)} (x{r.mean_ratio:.2f})")
    else:
        print("no span regressed beyond tolerance "
              f"(tol={args.tol:.0%}, min_us={args.min_us:g})")

    if args.json:
        doc = {
            "base": args.base, "current": args.current,
            "tol": args.tol, "min_us": args.min_us,
            "total_base_us": total_b, "total_cur_us": total_c,
            "rows": [{
                "name": r.name,
                "base": dataclasses.asdict(r.base) if r.base else None,
                "cur": dataclasses.asdict(r.cur) if r.cur else None,
                "delta_total_us": r.delta_total_us,
                "mean_ratio": r.mean_ratio,
                "regressed": r.regressed(args.tol, args.min_us),
            } for r in rows],
        }
        if args.json == "-":
            json.dump(doc, sys.stdout, indent=1, sort_keys=True)
            sys.stdout.write("\n")
        else:
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True)
                f.write("\n")
    return 1 if (bad and args.fail_on_regress) else 0


if __name__ == "__main__":
    sys.exit(main())
