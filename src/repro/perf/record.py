"""Typed benchmark records and the ``BENCH_<suite>.json`` writer.

Every benchmark suite appends :class:`BenchResult` records to the active
:class:`Recorder`; ``Recorder.write`` serializes the whole run as one JSON
document keyed by suite.  The on-disk format is the repo's performance
trajectory: committed at the root as ``BENCH_<suite>.json`` and diffed by
``python -m repro.perf.check`` on every subsequent run.

Records carry enough context to compare across commits and machines:
git sha, backend, jax version, shape, dtype — plus free-form numeric
``metrics`` (ratios, tokens/sec, and the hlo_stats-derived ``flops`` /
``bytes`` used for roofline annotation in :mod:`repro.perf.compare`).
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import platform
import subprocess
from typing import Dict, List, Optional, Sequence, Union

SCHEMA_VERSION = 1

Metric = Union[int, float, str]


def time_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on the result).
    The one timer shared by the benchmark suites and the autotuner, so both
    always measure the same way."""
    import time

    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def git_sha(short: bool = True) -> str:
    """HEAD sha, with a ``-dirty`` suffix when the working tree has
    uncommitted changes — a baseline's numbers must be attributable to the
    code that produced them, not the last clean commit."""
    here = os.path.dirname(os.path.abspath(__file__))
    cmd = ["git", "rev-parse"] + (["--short"] if short else []) + ["HEAD"]
    try:
        out = subprocess.run(cmd, capture_output=True, text=True, timeout=10,
                             cwd=here)
        if out.returncode != 0:
            return "unknown"
        sha = out.stdout.strip()
        st = subprocess.run(["git", "status", "--porcelain"],
                            capture_output=True, text=True, timeout=10,
                            cwd=here)
        if st.returncode == 0 and st.stdout.strip():
            sha += "-dirty"
        return sha
    except OSError:
        return "unknown"


def backend_name() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:
        return "unknown"


@dataclasses.dataclass(frozen=True)
class BenchResult:
    """One benchmark measurement: a named cell of a paper table / suite."""

    name: str
    us_per_call: float
    suite: str = ""
    shape: Optional[Sequence[int]] = None
    dtype: str = "float32"
    metrics: Dict[str, Metric] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = {
            "name": self.name,
            "us_per_call": round(float(self.us_per_call), 3),
            "suite": self.suite,
            "dtype": self.dtype,
            "metrics": dict(self.metrics),
        }
        if self.shape is not None:
            d["shape"] = [int(s) for s in self.shape]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "BenchResult":
        if not isinstance(d.get("name"), str) or "us_per_call" not in d:
            raise ValueError(f"malformed BenchResult: {d!r}")
        return cls(
            name=d["name"],
            us_per_call=float(d["us_per_call"]),
            suite=d.get("suite", ""),
            shape=tuple(d["shape"]) if d.get("shape") is not None else None,
            dtype=d.get("dtype", "float32"),
            metrics=dict(d.get("metrics", {})),
        )

    def derived_str(self) -> str:
        """Legacy ``k=v;k=v`` CSV column for stdout compatibility."""
        return ";".join(f"{k}={v}" for k, v in self.metrics.items())


class Recorder:
    """Collects one suite's records and writes ``BENCH_<suite>.json``."""

    def __init__(self, suite: str, out_dir: str = "."):
        self.suite = suite
        self.out_dir = out_dir
        self.results: List[BenchResult] = []

    def add(self, name: str, us_per_call: float, *,
            shape: Optional[Sequence[int]] = None, dtype: str = "float32",
            **metrics: Metric) -> BenchResult:
        r = BenchResult(name=name, us_per_call=us_per_call, suite=self.suite,
                        shape=shape, dtype=dtype, metrics=metrics)
        self.results.append(r)
        return r

    @property
    def path(self) -> str:
        return os.path.join(self.out_dir, f"BENCH_{self.suite}.json")

    def to_dict(self) -> dict:
        try:
            import jax
            jax_version = jax.__version__
        except Exception:
            jax_version = "unknown"
        return {
            "schema": SCHEMA_VERSION,
            "suite": self.suite,
            "git_sha": git_sha(),
            "backend": backend_name(),
            "host": platform.node() or "unknown",
            "jax": jax_version,
            "created": datetime.datetime.now(
                datetime.timezone.utc).isoformat(timespec="seconds"),
            "results": [r.to_dict() for r in
                        sorted(self.results, key=lambda r: r.name)],
        }

    def write(self) -> str:
        os.makedirs(self.out_dir, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)
        return self.path


def load_bench(path: str) -> dict:
    """Load and validate a ``BENCH_*.json`` document."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "results" not in doc:
        raise ValueError(f"{path}: not a BENCH document")
    doc["results"] = [BenchResult.from_dict(d) for d in doc["results"]]
    return doc


# -- active-recorder context (used by benchmarks.common.emit) ----------------

_ACTIVE: List[Recorder] = []


def current_recorder() -> Optional[Recorder]:
    return _ACTIVE[-1] if _ACTIVE else None


class recording:
    """``with recording("ff_timing", out_dir=root) as rec: ...`` — routes
    every ``benchmarks.common.emit`` call into ``rec``."""

    def __init__(self, suite: str, out_dir: str = "."):
        self.recorder = Recorder(suite, out_dir)

    def __enter__(self) -> Recorder:
        _ACTIVE.append(self.recorder)
        return self.recorder

    def __exit__(self, *exc) -> None:
        _ACTIVE.pop()


def hlo_metrics(fn, *args) -> Dict[str, float]:
    """Compile ``fn(*args)`` and return loop-aware ``flops`` / ``bytes``
    from :mod:`repro.launch.hlo_stats` — the roofline terms attached to
    bench records so ``repro.perf.check`` can print achieved-vs-bound
    columns without recompiling anything.

    Pass the ALREADY-JITTED function the suite timed (anything exposing
    ``.lower``) and its executable is reused; a bare callable costs one
    extra compile."""
    import jax

    from repro.launch import hlo_stats

    lowered = (fn.lower(*args) if hasattr(fn, "lower")
               else jax.jit(fn).lower(*args))
    stats = hlo_stats.module_stats(lowered.compile().as_text(), 1)
    return {"flops": float(stats["flops"]), "bytes": float(stats["bytes"])}
