"""Diff two benchmark runs and flag regressions.

A *regression* is a record whose ``us_per_call`` grew by more than
``tol`` (relative) over the baseline, provided the absolute time is above
``min_us`` (sub-noise-floor cells can't regress meaningfully).  Records are
matched by name; added/removed records are reported but never fail the
gate — adding coverage must not require lockstep baseline edits.

The table is roofline-annotated: records that carry hlo_stats-derived
``flops`` / ``bytes`` metrics get achieved-GFLOP/s and arithmetic-intensity
columns plus the fraction of the (TPU-v5e) roofline bound the measurement
achieves — see :mod:`repro.launch.roofline` for the hardware constants.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.perf.record import BenchResult

DEFAULT_TOL = 0.25          # 25% slower than baseline fails the gate
DEFAULT_MIN_US = 50.0       # noise floor: current value AND the absolute
                            # slowdown must both exceed this to regress


@dataclasses.dataclass
class Row:
    name: str
    base_us: Optional[float]
    cur_us: Optional[float]
    ratio: Optional[float]            # cur/base; >1 is slower
    regressed: bool
    gflops: Optional[float] = None    # achieved, from the CURRENT record
    intensity: Optional[float] = None  # flops/byte
    roofline_frac: Optional[float] = None

    @property
    def status(self) -> str:
        if self.base_us is None:
            return "NEW"
        if self.cur_us is None:
            return "REMOVED"
        return "REGRESSED" if self.regressed else "ok"


def _roofline_cols(r: BenchResult):
    flops = r.metrics.get("flops")
    bytes_ = r.metrics.get("bytes")
    if not isinstance(flops, (int, float)) or flops <= 0:
        return None, None, None
    gflops = flops / max(r.us_per_call, 1e-9) / 1e3    # flops/us -> GFLOP/s
    intensity = None
    frac = None
    if isinstance(bytes_, (int, float)) and bytes_ > 0:
        from repro.launch.roofline import HBM_BW, PEAK_FLOPS

        intensity = flops / bytes_
        bound_s = max(flops / PEAK_FLOPS, bytes_ / HBM_BW)
        frac = bound_s / (r.us_per_call * 1e-6)
    return gflops, intensity, frac


def compare_runs(baseline: List[BenchResult], current: List[BenchResult],
                 *, tol: float = DEFAULT_TOL,
                 min_us: float = DEFAULT_MIN_US) -> List[Row]:
    base_by = {r.name: r for r in baseline}
    cur_by = {r.name: r for r in current}
    rows: List[Row] = []
    for name in sorted(set(base_by) | set(cur_by)):
        b, c = base_by.get(name), cur_by.get(name)
        ratio = None
        regressed = False
        if b is not None and c is not None:
            ratio = c.us_per_call / max(b.us_per_call, 1e-9)
            regressed = (ratio > 1.0 + tol
                         and c.us_per_call >= min_us
                         and c.us_per_call - b.us_per_call >= min_us)
        gfl, inten, frac = _roofline_cols(c) if c is not None else (
            None, None, None)
        rows.append(Row(
            name=name,
            base_us=b.us_per_call if b else None,
            cur_us=c.us_per_call if c else None,
            ratio=ratio, regressed=regressed,
            gflops=gfl, intensity=inten, roofline_frac=frac))
    return rows


def regressions(rows: List[Row]) -> List[Row]:
    return [r for r in rows if r.regressed]


def _fmt(v, spec="{:.1f}", na="-") -> str:
    return na if v is None else spec.format(v)


def format_table(rows: List[Row], *, show_ok: bool = True) -> str:
    hdr = (f"{'name':44s} {'base_us':>10s} {'cur_us':>10s} {'ratio':>7s} "
           f"{'GF/s':>8s} {'F/B':>7s} {'roof%':>6s}  status")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if not show_ok and r.status == "ok":
            continue
        lines.append(
            f"{r.name[:44]:44s} {_fmt(r.base_us):>10s} {_fmt(r.cur_us):>10s} "
            f"{_fmt(r.ratio, '{:.2f}'):>7s} {_fmt(r.gflops, '{:.2f}'):>8s} "
            f"{_fmt(r.intensity, '{:.1f}'):>7s} "
            f"{_fmt(r.roofline_frac and 100 * r.roofline_frac, '{:.1f}'):>6s}"
            f"  {r.status}")
    return "\n".join(lines)


def summarize(rows: List[Row]) -> Dict[str, int]:
    return {
        "compared": sum(1 for r in rows if r.ratio is not None),
        "new": sum(1 for r in rows if r.status == "NEW"),
        "removed": sum(1 for r in rows if r.status == "REMOVED"),
        "regressed": sum(1 for r in rows if r.regressed),
    }
