"""Pallas block-size autotuner with a persistent JSON cache.

The fused DYAD kernel (:mod:`repro.kernels.dyad_mm`) tiles its grid with
``(block_b, block_o, block_k)``.  The right tile depends on the operand
shapes, dtype, and backend — a fixed default leaves MXU utilization on the
table for every shape it wasn't hand-picked for.  This module sweeps
candidate tiles per ``(op, shape, dtype, backend)`` key, times the real
kernel, and persists the winner:

* user cache   — ``~/.cache/repro_perf/blocks.json`` (override the directory
  with ``REPRO_PERF_CACHE_DIR``); written atomically, corrupt files are
  treated as empty and rewritten on the next ``put``;
* repo defaults — ``src/repro/perf/tuned/defaults.json``, shipped with the
  package so fresh checkouts start from tuned tiles for the shapes the
  benchmarks exercise.

``get_tuned_blocks`` is the lookup the kernel wrappers call at trace time
(shapes are concrete then); explicit ``block_*`` arguments always win, so
the tuner itself times candidates without consulting the cache.

Batch sizes are bucketed to the next power of two: decode steps see
``B = batch`` while prefill sees ``B = batch * seq``, and tile choice is
insensitive to B within a bucket (the b-axis tile clamps to the bucket).
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import time
import warnings
from typing import Dict, Iterable, List, Optional, Tuple

from repro import obs
from repro.perf.record import backend_name as _backend
from repro.perf.record import time_us as _time_us

Blocks = Dict[str, int]

DEFAULT_BLOCKS: Blocks = {"block_b": 256, "block_o": 256, "block_k": 512}

# the ff megakernel tiles a 4th axis: block_j tiles the hidden (d_ff/n)
# feature dim that never leaves VMEM.
DEFAULT_FF_BLOCKS: Blocks = {"block_b": 256, "block_o": 256,
                             "block_k": 512, "block_j": 512}

# op keys that resolve 4-axis ff tiles (and carry d_mid in their cache key).
# The ``_w8`` variants are the quantized-weight-stream bodies: their key's
# dtype field carries the PAYLOAD dtype (int8/float8_e4m3fn) — quantized
# tiles stream 2-4x fewer bytes, so wider tiles fit the same VMEM budget
# and the tuned entries must never collide with the unquantized ones.
FF_OPS = ("dyad_ff_fused", "dyad_ff_fused_swiglu",
          "dyad_ff_fused_w8", "dyad_ff_fused_swiglu_w8")

# flash-attention op keys: ``block_b`` tiles q positions, ``block_k`` tiles
# the streamed key axis; ``block_o`` is carried but unused (the head dim is
# never tiled).  Their key names the layer-natural dims
# (B=q rows|batch, n=KV heads, k=head_dim, o=kv length) and carries the
# GQA ratio G as ``d_mid`` — G scales the resident q/acc rows (bQ*G), so
# tiles tuned for one grouping must not collide with another.  The paged
# decode op additionally carries the page size as ``d_page``: its key tile
# is clamped to a divisor of the page, so tiles tuned for one page size
# must not collide with another.
ATTN_OPS = ("flash_prefill", "flash_decode", "flash_decode_paged")

DEFAULT_ATTN_BLOCKS: Blocks = {"block_b": 256, "block_o": 128,
                               "block_k": 512}

# VMEM is ~16 MB/core on TPU v4/v5; leave headroom for double-buffered
# pipelines (factor 2 on streamed operands) and the fp32 accumulator(s).
VMEM_BUDGET_BYTES = 12 * 2 ** 20

_DEFAULTS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "tuned", "defaults.json")


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


# Tensor-parallel shard tag.  kernels/tp.py sets this around shard_map
# invocations (the body traces eagerly inside the outer jit trace, so
# trace-time ``get_tuned_blocks`` lookups in the per-shard kernels see it),
# and ``ensure_tuned_for_model`` sets it while sweeping per-shard shapes.
# Keys gain a ``|tp{N}`` suffix only for N > 1: a per-shard shape that
# happens to equal a single-device global shape (e.g. d_ff/tp at tp=2 vs a
# half-width model at tp=1) must not collide — their VMEM/ICI trade-offs
# differ — while every committed tp=1 cache entry stays valid unchanged.
_TP: "contextvars.ContextVar[int]" = contextvars.ContextVar(
    "repro_autotune_tp", default=1)


@contextlib.contextmanager
def tp_shards(n: int):
    """Tag autotune cache keys with a tensor-parallel shard count."""
    tok = _TP.set(max(int(n), 1))
    try:
        yield
    finally:
        _TP.reset(tok)


def current_tp() -> int:
    return _TP.get()


def tune_key(op: str, B: int, n: int, d_in: int, d_out: int,
             dtype: str = "float32", backend: Optional[str] = None,
             d_mid: Optional[int] = None,
             d_page: Optional[int] = None,
             tp: Optional[int] = None) -> str:
    """Canonical cache key; B is bucketed to the next power of two.
    ``d_mid`` (the ff megakernel's hidden width d_ff/n) extends the key for
    ops whose tiling couples three weight tensors — omitted (and absent
    from the key) for the single-matmul ops.  ``d_page`` extends it again
    for the paged decode op (key tiles clamp to the page size).  ``tp``
    defaults to the ambient :func:`tp_shards` count and suffixes the key
    with ``|tp{N}`` when the shape is a per-shard slice (N > 1)."""
    backend = backend or _backend()
    tp = current_tp() if tp is None else max(int(tp), 1)
    mid = f"|j{d_mid}" if d_mid is not None else ""
    page = f"|p{d_page}" if d_page is not None else ""
    shard = f"|tp{tp}" if tp > 1 else ""
    return (f"{op}|B{max(_next_pow2(B), 8)}|n{n}|k{d_in}|o{d_out}{mid}{page}"
            f"{shard}|{dtype}|{backend}")


class BlockCache:
    """Two-layer persistent cache: user file over packaged defaults."""

    def __init__(self, user_path: Optional[str] = None,
                 defaults_path: str = _DEFAULTS_PATH):
        if user_path is None:
            root = os.environ.get(
                "REPRO_PERF_CACHE_DIR",
                os.path.join(os.path.expanduser("~"), ".cache", "repro_perf"))
            user_path = os.path.join(root, "blocks.json")
        self.user_path = user_path
        self.defaults_path = defaults_path
        self._user: Optional[dict] = None
        self._defaults: Optional[dict] = None

    def _load(self, path: str) -> dict:
        try:
            with open(path) as f:
                doc = json.load(f)
            if not isinstance(doc, dict):
                raise ValueError("top-level JSON is not an object")
            return doc
        except FileNotFoundError:
            return {}
        except (json.JSONDecodeError, ValueError, OSError) as e:
            warnings.warn(f"repro.perf: ignoring corrupt block cache "
                          f"{path}: {e}")
            return {}

    @property
    def user(self) -> dict:
        if self._user is None:
            self._user = self._load(self.user_path)
        return self._user

    @property
    def defaults(self) -> dict:
        if self._defaults is None:
            self._defaults = self._load(self.defaults_path)
        return self._defaults

    def get(self, key: str) -> Optional[Blocks]:
        for layer in (self.user, self.defaults):
            entry = layer.get(key)
            if isinstance(entry, dict) and isinstance(
                    entry.get("blocks"), dict):
                b = entry["blocks"]
                if all(isinstance(b.get(f), int) and b[f] > 0
                       for f in ("block_b", "block_o", "block_k")):
                    out = {f: b[f] for f in
                           ("block_b", "block_o", "block_k")}
                    if isinstance(b.get("block_j"), int) and b["block_j"] > 0:
                        out["block_j"] = b["block_j"]
                    return out
        return None

    def get_entry(self, key: str) -> Optional[dict]:
        for layer in (self.user, self.defaults):
            if key in layer:
                return layer[key]
        return None

    def put(self, key: str, blocks: Blocks, **meta) -> None:
        self.user[key] = {"blocks": dict(blocks), **meta}
        os.makedirs(os.path.dirname(self.user_path) or ".", exist_ok=True)
        tmp = self.user_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.user, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.user_path)
        _memo_clear()          # new tiles must be visible to the next trace

    def invalidate(self) -> None:
        self._user = None
        self._defaults = None
        _memo_clear()


_CACHE: Optional[BlockCache] = None

# trace-time memo over get_tuned_blocks: a jitted model trace resolves tiles
# once per DYAD call site, and a 48-layer model traces hundreds of sites —
# without this each one re-walks the (possibly file-backed) JSON cache.
# Invalidated by put()/invalidate()/reset_cache().
_MEMO: Dict[str, Blocks] = {}
_MEMO_COUNTS = {"hits": 0, "misses": 0}


def _memo_clear() -> None:
    _MEMO.clear()


def memo_counts() -> Dict[str, int]:
    """Copy of the get_tuned_blocks memo hit/miss counters (observability +
    tests; counters survive _memo_clear so rates stay meaningful)."""
    return dict(_MEMO_COUNTS)


def get_cache() -> BlockCache:
    global _CACHE
    if _CACHE is None:
        _CACHE = BlockCache()
    return _CACHE


def reset_cache(cache: Optional[BlockCache] = None) -> None:
    """Swap / drop the process-wide cache (tests, env-var changes)."""
    global _CACHE
    _CACHE = cache
    _memo_clear()


def get_tuned_blocks(op: str, B: int, n: int, d_in: int, d_out: int,
                     dtype: str = "float32",
                     backend: Optional[str] = None,
                     d_mid: Optional[int] = None,
                     d_page: Optional[int] = None) -> Blocks:
    """Tuned blocks for this key, else the hardcoded defaults (the 4-axis
    ff defaults for the megakernel ops, which also pass ``d_mid``).  Called
    by the kernel wrappers at trace time; memoized in-process so repeated
    jit traces don't re-consult the JSON-backed cache per call site."""
    key = tune_key(op, B, n, d_in, d_out, dtype, backend, d_mid=d_mid,
                   d_page=d_page)
    hit = _MEMO.get(key)
    if hit is not None:
        _MEMO_COUNTS["hits"] += 1
        return dict(hit)
    _MEMO_COUNTS["misses"] += 1
    default = (DEFAULT_FF_BLOCKS if op in FF_OPS
               else DEFAULT_ATTN_BLOCKS if op in ATTN_OPS
               else DEFAULT_BLOCKS)
    found = get_cache().get(key)
    if found is None:
        out = dict(default)
    else:
        # tuned entries may predate a new tile axis: fill from the default
        # (and drop axes this op does not tile)
        out = {f: found.get(f, default[f]) for f in default}
    _MEMO[key] = dict(out)
    return out


# -- candidate generation -----------------------------------------------------


def _dtype_bytes(dtype: str) -> int:
    """Bytes per element for VMEM budgeting.  Unknown dtypes RAISE: a
    silent 4-byte default would let a quantized sweep admit tiles that
    blow the real budget (or reject tiles that fit)."""
    table = {"float32": 4, "bfloat16": 2, "float16": 2, "int8": 1,
             "float8_e4m3fn": 1, "float8_e5m2": 1}
    try:
        return table[dtype]
    except KeyError:
        raise ValueError(f"_dtype_bytes: unknown dtype {dtype!r} "
                         f"(know {sorted(table)})") from None


def vmem_estimate(bb: int, bo: int, bk: int, dtype: str,
                  n_acc: int = 1, wgrad: bool = False,
                  w_dtype: Optional[str] = None) -> int:
    """Double-buffered VMEM footprint of one grid step.

    Forward/dgrad tile roles: two (bb, bk) activation tiles + two (bo, bk)
    weight tiles streamed, n_acc (bb, bo) output tiles, fp32 accumulators of
    the same shape.  wgrad contracts the BATCH axis instead: two (bb, bk) x
    tiles + two (bb, bo) z tiles streamed, and the outputs/accumulators are
    weight-shaped (bo, bk).

    ``w_dtype`` (quantized forward only) prices the weight tiles at the
    PAYLOAD dtype and adds the two double-buffered fp32 (bo,) scale tiles —
    int8 streams admit wider tiles under the same budget."""
    ib = _dtype_bytes(dtype)
    wb = ib if w_dtype is None else _dtype_bytes(w_dtype)
    if wgrad:
        stream = 2 * (2 * bb * bk + 2 * bb * bo + n_acc * bo * bk) * ib
        acc = 4 * n_acc * bo * bk
    else:
        stream = 2 * (2 * bb * bk * ib + 2 * bo * bk * wb
                      + n_acc * bb * bo * ib)
        if w_dtype is not None:
            stream += 2 * 2 * bo * 4
        acc = 4 * n_acc * bb * bo
    return stream + acc


def vmem_estimate_ff(bb: int, bo: int, bk: int, bj: int, dtype: str,
                     gated: bool = False,
                     w_dtype: Optional[str] = None) -> int:
    """Double-buffered VMEM footprint of one ff-megakernel grid step.

    Streams: two (bb, bk) input tiles, the up (and, gated, gate) weight
    tiles (bj, bk), two down weight tiles (bo, bj), two (bb, bo) output
    tiles.  Resident fp32 accumulators: the (bb, bj) hidden tile (two when
    gated) plus the two (bb, bo) down tiles — three weight tensors and the
    in-VMEM hidden now share ONE budget, which is exactly why the ff ops
    tune separately from the single-matmul kernels.

    ``w_dtype`` (the ``_w8`` ops) prices every weight tile at the PAYLOAD
    dtype and adds the fp32 scale tiles ((bj,) per up tensor, (bo,) per
    down)."""
    ib = _dtype_bytes(dtype)
    wb = ib if w_dtype is None else _dtype_bytes(w_dtype)
    n_up = 4 if gated else 2
    stream = 2 * (2 * bb * bk * ib + n_up * bj * bk * wb
                  + 2 * bo * bj * wb + 2 * bb * bo * ib)
    if w_dtype is not None:
        stream += 2 * (n_up * bj + 2 * bo) * 4
    acc = 4 * ((2 if gated else 1) * bb * bj + 2 * bb * bo)
    return stream + acc


def vmem_estimate_attn(bq: int, bk: int, h: int, g: int,
                       dtype: str) -> int:
    """Double-buffered VMEM footprint of one flash grid step.

    Streams: the (bq*g, h) q tile, two (bk, h) K/V tiles, the (bq*g, h)
    output tile.  Resident fp32 softmax state: m and l (bq*g, 128 lanes
    each) plus the (bq*g, h) output accumulator; the transient (bq*g, bk)
    score/probability tile lives through the softmax update and the P·V
    dot on the same step, so it budgets like a resident buffer."""
    ib = _dtype_bytes(dtype)
    rows = bq * g
    stream = 2 * (rows * h + 2 * bk * h + rows * h) * ib
    state = 4 * (2 * rows * 128 + rows * h)
    scores = 4 * 2 * rows * bk            # s + p in flight
    return stream + state + scores


def candidate_blocks_attn(S: int, T: int, h: int, g: int,
                          dtype: str = "float32", decode: bool = False,
                          max_candidates: int = 24) -> List[Blocks]:
    """Power-of-two (block_b = q positions, block_k = keys) sweep for the
    flash ops, largest tiles first, filtered by :func:`vmem_estimate_attn`.
    Decode has a single q row per head group: only block_k sweeps."""
    bqs = ([1] if decode else
           [b for b in (1024, 512, 256, 128, 64)
            if b <= max(_next_pow2(S), 64)])
    bks = [b for b in (1024, 512, 256, 128)
           if b <= max(_next_pow2(T), 128)]
    out: List[Blocks] = []
    base = dict(DEFAULT_ATTN_BLOCKS)
    cands = ([] if decode else [base]) + [
        {"block_b": bq, "block_o": 128, "block_k": bk}
        for bq in bqs for bk in bks]
    seen = set()
    for cand in cands:
        sig = (cand["block_b"], cand["block_k"])
        if sig in seen:
            continue
        seen.add(sig)
        if vmem_estimate_attn(cand["block_b"], cand["block_k"], h, g,
                              dtype) > VMEM_BUDGET_BYTES:
            continue
        out.append(dict(cand))
        if len(out) >= max_candidates:
            break
    return out


def candidate_blocks_ff(B: int, n: int, d_in: int, d_out: int, d_ff: int,
                        dtype: str = "float32", gated: bool = False,
                        max_candidates: int = 32,
                        w_dtype: Optional[str] = None) -> List[Blocks]:
    """Power-of-two 4-axis sweep for the ff megakernel, largest tiles first
    (fewer grid steps), filtered by :func:`vmem_estimate_ff` (quant sweeps
    pass the payload ``w_dtype`` so the shrunken streams admit wider
    tiles)."""
    bbs = [b for b in (512, 256, 128, 64) if b <= max(_next_pow2(B), 64)]
    bos = [b for b in (512, 256, 128) if b <= max(_next_pow2(d_out), 128)]
    bks = [b for b in (512, 256, 128) if b <= max(_next_pow2(d_in), 128)]
    bjs = [b for b in (1024, 512, 256, 128)
           if b <= max(_next_pow2(d_ff), 128)]
    out: List[Blocks] = []
    seen = set()
    for cand in ([DEFAULT_FF_BLOCKS]
                 + [{"block_b": bb, "block_o": bo, "block_k": bk,
                     "block_j": bj}
                    for bj in bjs for bb in bbs for bo in bos for bk in bks]):
        sig = (cand["block_b"], cand["block_o"], cand["block_k"],
               cand["block_j"])
        if sig in seen:
            continue
        seen.add(sig)
        if vmem_estimate_ff(cand["block_b"], cand["block_o"],
                            cand["block_k"], cand["block_j"], dtype,
                            gated=gated,
                            w_dtype=w_dtype) > VMEM_BUDGET_BYTES:
            continue
        out.append(dict(cand))
        if len(out) >= max_candidates:
            break
    return out


def candidate_blocks(B: int, n: int, d_in: int, d_out: int,
                     dtype: str = "float32", n_acc: int = 1,
                     wgrad: bool = False,
                     max_candidates: int = 32,
                     w_dtype: Optional[str] = None) -> List[Blocks]:
    """Power-of-two tile sweep clamped to the (bucketed) dims and filtered
    by the VMEM budget.  Always contains the hardcoded default."""
    bbs = [b for b in (64, 128, 256, 512) if b <= max(_next_pow2(B), 64)]
    bos = [b for b in (128, 256, 512) if b <= max(_next_pow2(d_out), 128)]
    bks = [b for b in (128, 256, 512, 1024) if b <= max(_next_pow2(d_in), 128)]
    out: List[Blocks] = []
    seen = set()
    for cand in ([DEFAULT_BLOCKS]
                 + [{"block_b": bb, "block_o": bo, "block_k": bk}
                    for bb in bbs for bo in bos for bk in bks]):
        sig = (cand["block_b"], cand["block_o"], cand["block_k"])
        if sig in seen:
            continue
        seen.add(sig)
        if vmem_estimate(*sig, dtype=dtype, n_acc=n_acc, wgrad=wgrad,
                         w_dtype=w_dtype) > VMEM_BUDGET_BYTES:
            continue
        out.append(dict(cand))
        if len(out) >= max_candidates:
            break
    return out


# -- the sweep ----------------------------------------------------------------


def autotune_dyad(op: str, B: int, n: int, d_in: int, d_out: int,
                  dtype: str = "float32", *,
                  candidates: Optional[Iterable[Blocks]] = None,
                  iters: int = 3, warmup: int = 1,
                  cache: Optional[BlockCache] = None,
                  force: bool = False,
                  d_mid: Optional[int] = None,
                  d_page: Optional[int] = None,
                  act: str = "gelu") -> Tuple[Blocks, float]:
    """Sweep block sizes for one kernel shape; persist and return the winner.

    ``op`` is one of ``"dyad_mm_blocks"`` / ``"dyad_mm_blocks_two"`` (the
    forward kernels), ``"dyad_mm_dgrad"`` / ``"dyad_mm_dgrad_two"`` /
    ``"dyad_mm_wgrad"`` (the backward kernels — dgrad contracts d_out and
    produces d_in, so its ``block_o`` tiles d_in and ``block_k`` tiles
    d_out; wgrad contracts the batch axis), ``"dyad_ff_fused"`` /
    ``"dyad_ff_fused_swiglu"`` (the whole-ff megakernel — pass the hidden
    width d_ff/n as ``d_mid``; ``act`` picks the timed epilogue), or
    ``"dense_bmm"`` (the baseline).  ``(B, n, d_in, d_out)`` always names
    the LAYER-natural dims, the same key the trace-time lookup uses.

    The ``_w8`` suffix on a forward op (``dyad_mm_blocks[_two]_w8``,
    ``dyad_ff_fused[_swiglu]_w8``) sweeps the quantized-weight-stream body:
    ``dtype`` then names the PAYLOAD dtype ("int8"/"float8_e4m3fn" — the
    field the kernel wrappers key on) while activations run in bf16, the
    serving compute dtype.
    Returns ``(blocks, best_us)``.  A cache hit short-circuits the sweep
    unless ``force=True``.
    """
    import jax
    import jax.numpy as jnp

    cache = cache or get_cache()
    if op in FF_OPS and d_mid is None:
        raise ValueError(f"{op} needs d_mid (the hidden width d_ff/n)")
    if op in ATTN_OPS and d_mid is None:
        raise ValueError(f"{op} needs d_mid (the GQA ratio G)")
    if op == "flash_decode_paged" and d_page is None:
        raise ValueError(f"{op} needs d_page (the KV page size)")
    key = tune_key(op, B, n, d_in, d_out, dtype, d_mid=d_mid, d_page=d_page)
    if not force:
        hit = cache.get(key)
        if hit is not None:
            entry = cache.get_entry(key) or {}
            return hit, float(entry.get("us", 0.0))

    if op in ATTN_OPS:
        # flash attention: (B, n, d_in, d_out) = (q rows|batch, KV heads,
        # head_dim, kv length); d_mid is the GQA ratio G.
        import jax
        import jax.numpy as jnp

        from repro.kernels import flash_attn
        from repro.kernels.dyad_mm import _plan_axis
        from repro.kernels.ops import _interpret

        g = d_mid
        kd = jnp.dtype(dtype)
        kx = jax.random.PRNGKey(0)
        interpret = _interpret()
        decode = op in ("flash_decode", "flash_decode_paged")
        if op == "flash_decode_paged":
            # worst-case admitted state: every slot holds a full-length
            # sequence, each in its own pages (plus the scratch page 0)
            P = d_page
            nb = -(-d_out // P)
            q = jax.random.normal(kx, (B, n, g, d_in), kd)
            pk = jax.random.normal(jax.random.fold_in(kx, 1),
                                   (1 + B * nb, P, n, d_in), kd)
            pv = jax.random.normal(jax.random.fold_in(kx, 2),
                                   (1 + B * nb, P, n, d_in), kd)
            bt = 1 + jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
            idx = jnp.full((B,), d_out - 1, jnp.int32)   # full-cache step
            kernel = lambda **c: flash_attn.flash_decode_paged(
                q, pk, pv, bt, idx, l_real=d_out, block_k=c["block_k"],
                interpret=interpret)
        elif decode:
            q = jax.random.normal(kx, (B, n, g, d_in), kd)
            k = jax.random.normal(jax.random.fold_in(kx, 1),
                                  (B, d_out, n, d_in), kd)
            v = jax.random.normal(jax.random.fold_in(kx, 2),
                                  (B, d_out, n, d_in), kd)
            idx = jnp.full((B,), d_out - 1, jnp.int32)   # full-cache step
            kernel = lambda **c: flash_attn.flash_decode(
                q, k, v, idx, block_k=c["block_k"], interpret=interpret)
        else:
            q = jax.random.normal(kx, (1, B, n, g, d_in), kd)
            k = jax.random.normal(jax.random.fold_in(kx, 1),
                                  (1, d_out, n, d_in), kd)
            v = jax.random.normal(jax.random.fold_in(kx, 2),
                                  (1, d_out, n, d_in), kd)
            kernel = lambda **c: flash_attn.flash_prefill(
                q, k, v, 0, 0, causal=True, block_q=c["block_b"],
                block_k=c["block_k"], interpret=interpret)[0]
        cands = (list(candidates) if candidates is not None
                 else candidate_blocks_attn(B, d_out, d_in, g, dtype,
                                            decode=decode))
        seen_plans = set()
        deduped = []
        for cand in cands:
            if op == "flash_decode_paged":
                # the wrapper clamps the key tile to a page divisor:
                # distinct requests collapsing to one effective tile would
                # only measure noise twice
                from repro.kernels.dyad_mm import _largest_divisor
                plan = _largest_divisor(d_page,
                                        max(min(cand["block_k"], d_page), 1))
            else:
                plan = (_plan_axis(B, cand["block_b"], 8),
                        _plan_axis(d_out, cand["block_k"], 128))
            if plan in seen_plans:
                continue
            seen_plans.add(plan)
            deduped.append(cand)
        best, best_us = _time_candidates(kernel, deduped, key, iters, warmup)
        cache.put(key, best, us=round(best_us, 2), op=op,
                  candidates=len(deduped))
        return best, best_us

    quant = op.endswith("_w8")
    kd = jnp.dtype(jnp.bfloat16) if quant else jnp.dtype(dtype)
    kx = jax.random.PRNGKey(0)
    x1 = jax.random.normal(kx, (B, n, d_in), kd)
    x2 = jax.random.normal(jax.random.fold_in(kx, 1), (B, n, d_in), kd)
    w1 = jax.random.normal(jax.random.fold_in(kx, 2), (n, d_out, d_in), kd)
    w2 = jax.random.normal(jax.random.fold_in(kx, 3), (n, d_out, d_in), kd)
    if quant:
        from repro import quant as quant_lib
        quant_lib.resolve_dtype(dtype)    # payload name must be quantizable

    if op == "dense_bmm":
        # the baseline has no tile knobs; record its time under the default
        # key so compare tables can show fused-vs-dense per shape.
        f = jax.jit(lambda: jnp.einsum("bgk,gok->bgo", x1, w1)
                    + jnp.einsum("bgk,gok->bgo", x2, w2))
        us = _time_us(f, iters=iters, warmup=warmup)
        blocks = dict(DEFAULT_BLOCKS)
        cache.put(key, blocks, us=round(us, 2), op=op)
        return blocks, us

    from repro.kernels import dyad_mm
    from repro.kernels.ops import _interpret

    n_acc = 1 if op in ("dyad_mm_blocks", "dyad_mm_blocks_w8",
                        "dyad_mm_dgrad") else 2
    interpret = _interpret()

    if op in FF_OPS:
        gated = "swiglu" in op
        kact = "swiglu" if gated else act
        wu1 = jax.random.normal(jax.random.fold_in(kx, 4), (n, d_mid, d_in),
                                kd)
        wu2 = jax.random.normal(jax.random.fold_in(kx, 5), (n, d_mid, d_in),
                                kd)
        wd1 = jax.random.normal(jax.random.fold_in(kx, 6), (n, d_out, d_mid),
                                kd)
        wd2 = jax.random.normal(jax.random.fold_in(kx, 7), (n, d_out, d_mid),
                                kd)
        gates = {}
        if gated:
            gates = {"wg1": jax.random.normal(jax.random.fold_in(kx, 8),
                                              (n, d_mid, d_in), kd),
                     "wg2": jax.random.normal(jax.random.fold_in(kx, 9),
                                              (n, d_mid, d_in), kd)}
        if quant:
            (wu1, su1), (wu2, su2), (wd1, sd1), (wd2, sd2) = (
                quant_lib.quantize_dyad_weight(w, dtype)
                for w in (wu1, wu2, wd1, wd2))
            if gated:
                wg1, sg1 = quant_lib.quantize_dyad_weight(gates["wg1"],
                                                          dtype)
                wg2, sg2 = quant_lib.quantize_dyad_weight(gates["wg2"],
                                                          dtype)
                gates = {"wg1": wg1, "wg2": wg2, "sg1": sg1, "sg2": sg2}
            kernel = lambda **c: dyad_mm.dyad_ff_fused_q(
                x1, x2, wu1, wu2, wd1, wd2, su1, su2, sd1, sd2, act=kact,
                interpret=interpret, **gates, **c)
        else:
            kernel = lambda **c: dyad_mm.dyad_ff_fused(
                x1, x2, wu1, wu2, wd1, wd2, act=kact, interpret=interpret,
                **gates, **c)
        cands = (list(candidates) if candidates is not None
                 else candidate_blocks_ff(
                     B, n, d_in, d_out, d_mid,
                     str(kd) if quant else dtype, gated=gated,
                     w_dtype=dtype if quant else None))
        seen_plans = set()
        deduped = []
        for cand in cands:
            plan = dyad_mm.plan_ff_tiles(B, d_out, d_mid, d_in,
                                         cand["block_b"], cand["block_o"],
                                         cand["block_j"], cand["block_k"])
            if plan in seen_plans:
                continue
            seen_plans.add(plan)
            deduped.append(cand)
        best, best_us = _time_candidates(kernel, deduped, key, iters, warmup)
        cache.put(key, best, us=round(best_us, 2), op=op,
                  candidates=len(deduped))
        return best, best_us

    if op in ("dyad_mm_dgrad", "dyad_mm_dgrad_two"):
        # dgrad consumes per-component cotangents (B, n, d_out)
        z1 = jax.random.normal(jax.random.fold_in(kx, 4), (B, n, d_out), kd)
        z2 = jax.random.normal(jax.random.fold_in(kx, 5), (B, n, d_out), kd)
        kfn = {"dyad_mm_dgrad": dyad_mm.dyad_mm_dgrad,
               "dyad_mm_dgrad_two": dyad_mm.dyad_mm_dgrad_two}[op]
        kernel = lambda **c: kfn(z1, z2, w1, w2, interpret=interpret, **c)
        # produced axis is d_in, contracted is d_out: swap the feature dims
        # for candidate clamping and effective-tile dedup
        plan_dims = (B, d_in, d_out)
        cand_dims = (d_out, d_in)
    elif op == "dyad_mm_wgrad":
        z1 = jax.random.normal(jax.random.fold_in(kx, 4), (B, n, d_out), kd)
        z2 = jax.random.normal(jax.random.fold_in(kx, 5), (B, n, d_out), kd)
        kernel = lambda **c: dyad_mm.dyad_mm_wgrad(
            x1, x2, z1, z2, interpret=interpret, **c)
        plan_dims = (B, d_out, d_in)
        cand_dims = (d_in, d_out)
    elif quant:
        kfn = {"dyad_mm_blocks_w8": dyad_mm.dyad_mm_blocks_q,
               "dyad_mm_blocks_two_w8": dyad_mm.dyad_mm_blocks_two_q}[op]
        w1q, s1 = quant_lib.quantize_dyad_weight(w1, dtype)
        w2q, s2 = quant_lib.quantize_dyad_weight(w2, dtype)
        kernel = lambda **c: kfn(x1, x2, w1q, w2q, s1, s2,
                                 interpret=interpret, **c)
        plan_dims = (B, d_out, d_in)
        cand_dims = (d_in, d_out)
    else:
        kfn = {"dyad_mm_blocks": dyad_mm.dyad_mm_blocks,
               "dyad_mm_blocks_two": dyad_mm.dyad_mm_blocks_two}[op]
        kernel = lambda **c: kfn(x1, x2, w1, w2, interpret=interpret, **c)
        plan_dims = (B, d_out, d_in)
        cand_dims = (d_in, d_out)

    cands = list(candidates) if candidates is not None else candidate_blocks(
        B, n, cand_dims[0], cand_dims[1], str(kd) if quant else dtype,
        n_acc=n_acc, wgrad=(op == "dyad_mm_wgrad"),
        w_dtype=dtype if quant else None)
    # distinct requested blocks can clamp to identical EFFECTIVE tiles for
    # this concrete shape — timing those again only measures noise
    seen_plans = set()
    deduped = []
    for cand in cands:
        plan = dyad_mm.plan_tiles(*plan_dims, cand["block_b"],
                                  cand["block_o"], cand["block_k"])
        if plan in seen_plans:
            continue
        seen_plans.add(plan)
        deduped.append(cand)
    cands = deduped
    best, best_us = _time_candidates(kernel, cands, key, iters, warmup)
    cache.put(key, best, us=round(best_us, 2), op=op,
              candidates=len(cands))
    return best, best_us


def _time_candidates(kernel, cands: List[Blocks], key: str, iters: int,
                     warmup: int) -> Tuple[Blocks, float]:
    """Time every candidate and return the winner.

    Long sweeps used to be completely silent (a deep-model ``--autotune``
    looks like a hang): with ``REPRO_OBS_VERBOSE=1`` — or whenever the
    tracer is enabled — each candidate prints a progress line, and every
    measurement lands in the trace as an ``autotune_candidate`` span."""
    best: Optional[Blocks] = None
    best_us = float("inf")
    n = len(cands)
    chatty = obs.verbose()
    t_sweep = time.perf_counter()
    with obs.span("autotune_sweep", cat="autotune", key=key, candidates=n):
        for i, cand in enumerate(cands):
            with obs.span("autotune_candidate", cat="autotune", key=key,
                          i=i, **cand) as sp:
                try:
                    us = _time_us(lambda c=cand: kernel(**c),
                                  iters=iters, warmup=warmup)
                except Exception as e:   # invalid tiling for backend/shape
                    warnings.warn(f"repro.perf: candidate {cand} failed for "
                                  f"{key}: {e}")
                    if chatty:
                        print(f"[autotune] {key}: {i + 1}/{n} {cand} FAILED "
                              f"({type(e).__name__})", flush=True)
                    continue
                sp.set(us=round(us, 2))
            if chatty:
                print(f"[autotune] {key}: {i + 1}/{n} {cand} -> {us:.1f}us"
                      f"{'  <- best' if us < best_us else ''}", flush=True)
            if us < best_us:
                best, best_us = cand, us
    if best is None:
        raise RuntimeError(f"autotune: every candidate failed for {key}")
    if chatty:
        print(f"[autotune] {key}: winner {best} {best_us:.1f}us "
              f"({n} candidates in "
              f"{time.perf_counter() - t_sweep:.2f}s)", flush=True)
    return best, best_us


def model_dyad_shapes(cfg) -> List[Tuple[int, int, int]]:
    """Distinct ``(n_dyad, d_in_per_block, d_out_per_block)`` kernel shapes a
    model config routes through the fused kernel (ff site today)."""
    lin = getattr(cfg, "linear", None)
    if lin is None or not getattr(lin, "use_kernel", False):
        return []
    from repro.core import dyad

    shapes = set()
    pairs = []
    if lin.dyad_at("ff"):
        pairs += [(cfg.d_model, cfg.d_ff), (cfg.d_ff, cfg.d_model)]
    if lin.dyad_at("attn"):
        # hd is the RESOLVED head dim (the raw head_dim field defaults to 0)
        hd = getattr(cfg, "hd", None) or getattr(cfg, "head_dim", 0)
        q = cfg.n_heads * hd
        kv = cfg.n_kv_heads * hd
        pairs += [(cfg.d_model, q), (cfg.d_model, kv), (q, cfg.d_model)]
    for f_in, f_out in pairs:
        if f_in <= 0 or f_out <= 0:
            continue
        n = dyad.resolve_n_dyad(f_in, f_out, lin.n_dyad)
        shapes.add((n, f_in // n, f_out // n))
    return sorted(shapes)


def model_ff_fused_shape(cfg) -> Optional[Tuple[int, int, int]]:
    """``(n_dyad, d_in_per_block, d_ff_per_block)`` when the config routes
    its ff modules through the megakernel (``fuse_ff_kernel``), else None.
    The down output width per block equals d_in_per_block (ff maps
    d_model -> d_ff -> d_model).  Mirrors ``layers.mlp._ff_kernel_ready``:
    biased ff modules (``mlp_bias=True``, e.g. OPT) and unsupported
    epilogue activations fall back to the per-projection kernels, so
    sweeping megakernel tiles for them would burn minutes tuning an op
    that is never dispatched (and every candidate would fail for an
    unknown act)."""
    lin = getattr(cfg, "linear", None)
    if (lin is None or not getattr(lin, "fuse_ff_kernel", False)
            or not getattr(lin, "use_kernel", False)
            or not lin.dyad_at("ff")
            or getattr(cfg, "mlp_bias", False)):
        return None
    from repro.kernels.ref import ACTS

    if getattr(cfg, "act", "gelu") not in set(ACTS) | {"swiglu"}:
        return None
    from repro.core import dyad

    n = dyad.resolve_n_dyad(cfg.d_model, cfg.d_ff, lin.n_dyad)
    return (n, cfg.d_model // n, cfg.d_ff // n)


def bwd_ops_for_variant(variant: str) -> List[str]:
    """The backward kernel ops a DYAD variant routes through: OT's two dx
    components share a layout (ONE fused dgrad accumulator); IT/DT emit the
    components separately.  wgrad is variant-independent."""
    dgrad = "dyad_mm_dgrad" if variant == "ot" else "dyad_mm_dgrad_two"
    return [dgrad, "dyad_mm_wgrad"]


def model_attn_shape(cfg) -> Optional[Tuple[int, int, int]]:
    """``(n_kv_heads, gqa_ratio, head_dim)`` when the config routes its
    attention through the flash kernels (``flash_attn``), else None."""
    if not getattr(cfg, "flash_attn", False):
        return None
    heads, kv = getattr(cfg, "n_heads", 0), getattr(cfg, "n_kv_heads", 0)
    if heads <= 0 or kv <= 0:
        return None
    hd = getattr(cfg, "hd", None) or getattr(cfg, "head_dim", 0)
    if not hd:
        return None
    return kv, heads // kv, hd


def mesh_shard_counts(mesh=None, model_axis: str = "model"
                      ) -> Tuple[int, int]:
    """``(tp, dp)`` shard counts for a mesh: tp = the model-axis size,
    dp = every other axis folded together (the batch-sharding product).
    ``mesh=None`` consults the ambient activation-sharding context
    (:mod:`repro.sharding.ctx`); no mesh/ctx -> ``(1, 1)``."""
    if mesh is None:
        from repro.sharding import ctx as shard_ctx

        actx = shard_ctx.current()
        if actx is None:
            return 1, 1
        mesh, model_axis = actx.mesh, actx.model
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = max(int(sizes.get(model_axis, 1)), 1)
    total = 1
    for s in sizes.values():
        total *= int(s)
    return tp, max(total // tp, 1)


def ensure_tuned_for_model(cfg, tokens: int, *, dtype: Optional[str] = None,
                           iters: int = 2, include_bwd: bool = False,
                           seq_len: Optional[int] = None,
                           kv_len: Optional[int] = None,
                           page_size: Optional[int] = None,
                           mesh=None, model_axis: str = "model"
                           ) -> Dict[str, Blocks]:
    """Pre-tune every fused-kernel shape a model will hit with ``tokens``
    rows (decode: batch; prefill: batch*seq; train: batch*seq).  Serving
    calls this at engine construction — and ``launch/train.py --autotune``
    calls it with ``include_bwd=True`` — so the first jit trace already
    picks tuned tiles (a ``value_and_grad`` trace resolves the dgrad/wgrad
    tiles at trace time too).  No-op (empty dict) for configs that don't
    use the Pallas kernel.

    ``seq_len`` additionally tunes the ``flash_prefill`` tiles for that
    sequence length and ``kv_len`` the ``flash_decode`` tiles for a cache
    of that length (``tokens`` = decode batch rows; window-bounded ring
    caches clamp it) — both only for ``cfg.flash_attn`` configs.  A paged
    engine passes ``page_size`` too, which swaps the decode op for
    ``flash_decode_paged`` (the page size rides in its cache key).

    ``dtype`` defaults to the config's COMPUTE dtype — ops.py casts weights
    to the activation dtype, so that is the dtype trace-time lookups use.

    ``mesh`` (or, when None, the ambient activation-sharding context) makes
    the sweep tensor-parallel-aware: the ff megakernel and flash ops run
    per-shard under :mod:`repro.kernels.tp`, so their tiles are tuned at
    per-shard dims (hidden ``j/tp``, KV heads ``kvh/tp``, rows
    ``tokens/dp``) under :func:`tp_shards` — the ``|tp{N}`` keys the
    shard_map body will look up at trace time.  Non-divisible shards fall
    back to the einsum route in the layers, so their sweep is skipped.  The
    single-matmul dyad ops dispatch at global shapes (GSPMD partitions
    them), so they keep un-suffixed keys."""
    if dtype is None:
        dtype = getattr(cfg, "compute_dtype", None) or "float32"
    tp, dp = mesh_shard_counts(mesh, model_axis)
    tokens_shard = max(tokens // dp, 1)
    tuned: Dict[str, Blocks] = {}
    attn = model_attn_shape(cfg)
    if attn is not None:
        # sweep only when dispatch will actually consult the tiles
        # (PR-4 precedent: never burn minutes tuning an op that is never
        # dispatched — off-TPU the flash route needs REPRO_KERNEL_ATTN)
        from repro.kernels.ops import attn_route

        if attn_route() != "flash":
            attn = None
    if attn is not None and tp > 1:
        from repro.kernels import tp as ktp

        if not ktp.tp_enabled() or attn[0] % tp != 0:
            attn = None  # layer falls back to einsum attention under TP
    if attn is not None:
        kvh, g, hd = attn
        kvh //= tp
        with tp_shards(tp):
            if seq_len is not None and seq_len > 1:
                blocks, _ = autotune_dyad("flash_prefill", seq_len, kvh, hd,
                                          seq_len, dtype, d_mid=g,
                                          iters=iters)
                tuned[tune_key("flash_prefill", seq_len, kvh, hd, seq_len,
                               dtype, d_mid=g)] = blocks
            if kv_len is not None:
                win = getattr(cfg, "window", None)
                L = min(kv_len, win) if win else kv_len
                rows = max(tokens_shard if tp > 1 else tokens, 1)
                if page_size is not None:
                    blocks, _ = autotune_dyad(
                        "flash_decode_paged", rows, kvh, hd, L, dtype,
                        d_mid=g, d_page=page_size, iters=iters)
                    tuned[tune_key("flash_decode_paged", rows, kvh,
                                   hd, L, dtype, d_mid=g,
                                   d_page=page_size)] = blocks
                else:
                    blocks, _ = autotune_dyad("flash_decode", rows,
                                              kvh, hd, L, dtype, d_mid=g,
                                              iters=iters)
                    tuned[tune_key("flash_decode", rows, kvh, hd, L,
                                   dtype, d_mid=g)] = blocks
    variant = getattr(cfg.linear, "variant", "it")
    # quantized serving tunes the _w8 op keys too: their key dtype is the
    # PAYLOAD dtype (the field the kernel wrappers resolve on)
    qdt = None
    if getattr(cfg.linear, "quant", None):
        from repro import quant as quant_lib

        if quant_lib.enabled():
            qdt = str(quant_lib.resolve_dtype(cfg.linear.quant)[0])
    for n, d_in, d_out in model_dyad_shapes(cfg):
        ops = ["dyad_mm_blocks" if variant == "it" else "dyad_mm_blocks_two"]
        if qdt is not None:
            ops.append(ops[0] + "_w8")
        if include_bwd:
            ops += bwd_ops_for_variant(variant)
        for op in ops:
            dt = qdt if op.endswith("_w8") else dtype
            blocks, _ = autotune_dyad(op, tokens, n, d_in, d_out, dt,
                                      iters=iters)
            tuned[tune_key(op, tokens, n, d_in, d_out, dt)] = blocks
    ff = model_ff_fused_shape(cfg)
    if ff is not None and tp > 1:
        from repro.kernels import tp as ktp

        if not ktp.tp_enabled() or ff[2] % tp != 0:
            ff = None  # layer falls back to the einsum ff route under TP
    if ff is not None:
        n, k, j = ff
        j //= tp
        mact = getattr(cfg, "act", "gelu")
        op = "dyad_ff_fused_swiglu" if mact == "swiglu" else "dyad_ff_fused"
        with tp_shards(tp):
            rows = tokens_shard if tp > 1 else tokens
            blocks, _ = autotune_dyad(op, rows, n, k, k, dtype, d_mid=j,
                                      act=mact, iters=iters)
            tuned[tune_key(op, rows, n, k, k, dtype, d_mid=j)] = blocks
            if qdt is not None:
                blocks, _ = autotune_dyad(op + "_w8", rows, n, k, k, qdt,
                                          d_mid=j, act=mact, iters=iters)
                tuned[tune_key(op + "_w8", rows, n, k, k, qdt,
                               d_mid=j)] = blocks
            if include_bwd:
                # the megakernel VJP composes the existing bwd kernels; the
                # main loop above already tunes them at both ff shapes
                # except the OT-fused down dgrad (d_in = d_ff/n,
                # d_out = d_model/n)
                blocks, _ = autotune_dyad("dyad_mm_dgrad", rows, n, j, k,
                                          dtype, iters=iters)
                tuned[tune_key("dyad_mm_dgrad", rows, n, j, k,
                               dtype)] = blocks
    return tuned
