"""The paper's primary contribution: DYAD structured-sparse linear layers.

- :mod:`repro.core.dyad`    — DYAD-IT/OT/DT (+ -CAT execution path) + oracle.
- :mod:`repro.core.linear`  — the DENSE baseline.
- :mod:`repro.core.factory` — config-driven drop-in substitution by site/scope.
"""
from repro.core import dyad, factory, linear  # noqa: F401
from repro.core.dyad import DyadSpec  # noqa: F401
from repro.core.factory import DENSE, LinearCfg  # noqa: F401
