"""DYAD structured near-sparse linear layers (the paper's core contribution).

A DYAD layer approximates a dense linear ``y = x @ W.T + b`` (``W: f_out x f_in``)
with the sum of two block-structured components, each stored as a 3-D tensor of
shape ``(n_dyad, d_out, d_in)`` where ``f_in = n_dyad * d_in`` and
``f_out = n_dyad * d_out``:

* ``w1`` — BLOCKDIAG: a block-diagonal matrix.
* ``w2`` — BLOCKTRANS: block-diagonal *after* a fixed strided feature
  permutation.  The permutation is a pure re-view (reshape + transpose), so it
  costs no data movement; which side it lands on defines the variant:

  - ``it`` (Input Transpose):  permute input features of component 2.
  - ``ot`` (Output Transpose): permute output features of component 2.
  - ``dt`` (Double Transpose): both.

Activations here are feature-last (``x: (..., f_in) -> y: (..., f_out)``), the
transpose of the paper's column-major convention; the algebra is identical.

Compute/parameter cost: ``2 * f_out * f_in / n_dyad`` vs dense ``f_out * f_in``
— an ``n_dyad / 2`` reduction in both FLOPs and weight bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

VARIANTS = ("it", "ot", "dt")


@dataclasses.dataclass(frozen=True)
class DyadSpec:
    """Static configuration of one DYAD layer."""

    n_dyad: int = 4
    variant: str = "it"           # "it" | "ot" | "dt"
    cat: bool = False             # paper's -CAT: one bmm over 2*n_dyad blocks
    use_kernel: bool = False      # route through the Pallas kernel (TPU target)
    use_kernel_bwd: bool = True   # fused Pallas backward (only with use_kernel;
                                  # False = einsum-VJP oracle escape hatch)

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown DYAD variant {self.variant!r}")
        if self.n_dyad < 1:
            raise ValueError("n_dyad must be >= 1")


def resolve_n_dyad(f_in: int, f_out: int, requested: int) -> int:
    """Largest n <= requested dividing both feature dims (paper App. 5.1)."""
    n = min(requested, f_in, f_out)
    while n > 1 and (f_in % n or f_out % n):
        n -= 1
    return max(n, 1)


def init(
    key: jax.Array,
    f_in: int,
    f_out: int,
    spec: DyadSpec,
    *,
    bias: bool = True,
    dtype: jnp.dtype = jnp.float32,
) -> Params:
    """Paper-faithful init: uniform(-k, k) with k = 1/sqrt(f_in)."""
    n = spec.n_dyad
    if f_in % n or f_out % n:
        raise ValueError(
            f"DYAD dims must divide n_dyad: f_in={f_in} f_out={f_out} n_dyad={n}"
        )
    d_in, d_out = f_in // n, f_out // n
    k = 1.0 / jnp.sqrt(jnp.asarray(f_in, jnp.float32))
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {
        "w1": jax.random.uniform(k1, (n, d_out, d_in), dtype, -k, k),
        "w2": jax.random.uniform(k2, (n, d_out, d_in), dtype, -k, k),
    }
    if bias:
        p["b"] = jax.random.uniform(k3, (f_out,), dtype, -k, k)
    return p


def _lead(x: jax.Array) -> tuple:
    return x.shape[:-1]


def _block_views(x: jax.Array, n: int, d_in: int, variant: str):
    """Return (x1, x2): the block-contiguous and (maybe) strided views.

    x1[..., g, i] = x[..., g*d_in + i]       (BLOCKDIAG input, all variants)
    x2[..., g, i] = x[..., i*n + g]          (BLOCKTRANS input, it/dt)
    x2 = x1                                   (ot — permutation is on the output)
    """
    lead = _lead(x)
    x1 = x.reshape(*lead, n, d_in)
    if variant in ("it", "dt"):
        x2 = jnp.swapaxes(x.reshape(*lead, d_in, n), -1, -2)
    else:  # "ot"
        x2 = x1
    return x1, x2


def _combine_outputs(z1: jax.Array, z2: jax.Array, variant: str) -> jax.Array:
    """Fold per-block outputs back to a flat feature axis.

    z*: (..., n_dyad, d_out).  BLOCKDIAG output is always block-contiguous:
    y1[..., g*d_out + o] = z1[..., g, o].  BLOCKTRANS output is strided for
    ot/dt: y2[..., o*n + g] = z2[..., g, o].
    """
    lead = z1.shape[:-2]
    f_out = z1.shape[-2] * z1.shape[-1]
    y1 = z1.reshape(*lead, f_out)
    if variant in ("ot", "dt"):
        y2 = jnp.swapaxes(z2, -1, -2).reshape(*lead, f_out)
    else:
        y2 = z2.reshape(*lead, f_out)
    return y1 + y2


def apply(params: Params, x: jax.Array, spec: DyadSpec) -> jax.Array:
    """y = DYAD(x).  x: (..., f_in) -> (..., f_out)."""
    w1, w2 = params["w1"], params["w2"]
    n, d_out, d_in = w1.shape
    if x.shape[-1] != n * d_in:
        raise ValueError(f"expected {n * d_in} input features, got {x.shape[-1]}")

    if spec.use_kernel:
        from repro.kernels import ops as kops

        y = kops.dyad_mm(x, w1, w2, variant=spec.variant,
                         use_kernel_bwd=spec.use_kernel_bwd)
    else:
        w1, w2 = w1.astype(x.dtype), w2.astype(x.dtype)
        x1, x2 = _block_views(x, n, d_in, spec.variant)
        if spec.cat:
            # paper §3.4.3: one batched matmul over the concatenated blocks.
            xc = jnp.concatenate([x1, x2], axis=-2)          # (..., 2n, d_in)
            wc = jnp.concatenate([w1, w2], axis=0)           # (2n, d_out, d_in)
            z = jnp.einsum("...gi,goi->...go", xc, wc)
            z1, z2 = z[..., :n, :], z[..., n:, :]
        else:
            # faithful two-step path (two sequential bmms).
            z1 = jnp.einsum("...gi,goi->...go", x1, w1)
            z2 = jnp.einsum("...gi,goi->...go", x2, w2)
        y = _combine_outputs(z1, z2, spec.variant)

    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def apply_blocks(params: Params, x: jax.Array, spec: DyadSpec) -> jax.Array:
    """IT-variant apply that RETURNS the block layout ``(..., n, d_out)``
    instead of flattening.  Used by the fused DYAD MLP: under tensor
    parallelism the flat ``(..., f_out)`` view of a d_out-sharded hidden is
    interleaved (inexpressible for GSPMD -> forced all-gather); the 3-D
    layout shards cleanly."""
    if spec.variant != "it":
        raise ValueError("apply_blocks is defined for the IT variant")
    w1, w2 = params["w1"], params["w2"]
    n, d_out, d_in = w1.shape
    w1, w2 = w1.astype(x.dtype), w2.astype(x.dtype)
    x1, x2 = _block_views(x, n, d_in, "it")
    z = (jnp.einsum("...gi,goi->...go", x1, w1)
         + jnp.einsum("...gi,goi->...go", x2, w2))
    if "b" in params:
        z = z + params["b"].astype(z.dtype).reshape(n, d_out)
    return z


def apply_ot_from_blocks(params: Params, h: jax.Array) -> jax.Array:
    """OT-variant apply consuming a block-layout input ``(..., n, d_in)``.

    OT's two components BOTH read block-contiguous input (the permutation is
    on the output side, where it is a free local re-view after the TP
    reduction) — so a d_in-sharded block-layout hidden is consumed with zero
    data movement.  Returns the flat ``(..., f_out)``."""
    w1, w2 = params["w1"], params["w2"]
    n, d_out, d_in = w1.shape
    w1, w2 = w1.astype(h.dtype), w2.astype(h.dtype)
    z1 = jnp.einsum("...gi,goi->...go", h, w1)
    z2 = jnp.einsum("...gi,goi->...go", h, w2)
    y = _combine_outputs(z1, z2, "ot")
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def to_dense(params: Params, spec: DyadSpec) -> jax.Array:
    """Reconstruct the full structured (f_out, f_in) matrix — the oracle.

    apply(params, x, spec) == x @ to_dense(params, spec).T + b, exactly.
    Overlapping nonzeros between the two components ADD (the paper notes the
    components "share some non-zero elements"; the layer computes Y1 + Y2).
    """
    w1, w2 = params["w1"], params["w2"]
    n, d_out, d_in = w1.shape
    f_in, f_out = n * d_in, n * d_out
    g = jnp.arange(n)[:, None, None]
    o = jnp.arange(d_out)[None, :, None]
    i = jnp.arange(d_in)[None, None, :]

    rows1, cols1 = g * d_out + o, g * d_in + i                 # BLOCKDIAG
    if spec.variant == "it":
        rows2, cols2 = g * d_out + o, i * n + g
    elif spec.variant == "ot":
        rows2, cols2 = o * n + g, g * d_in + i
    else:  # "dt"
        rows2, cols2 = o * n + g, i * n + g

    W = jnp.zeros((f_out, f_in), w1.dtype)
    W = W.at[jnp.broadcast_to(rows1, w1.shape), jnp.broadcast_to(cols1, w1.shape)].add(w1)
    W = W.at[jnp.broadcast_to(rows2, w2.shape), jnp.broadcast_to(cols2, w2.shape)].add(w2)
    return W


def param_count(f_in: int, f_out: int, n_dyad: int, bias: bool = True) -> int:
    return 2 * f_out * f_in // n_dyad + (f_out if bias else 0)


def flops(batch: int, f_in: int, f_out: int, n_dyad: int) -> int:
    """Forward multiply-add FLOPs (2 per MAC), both components."""
    return 2 * 2 * batch * f_out * f_in // n_dyad
