"""Config-driven linear substitution — DYAD as a first-class framework feature.

Every linear layer in the framework is created through this factory with a
``site`` tag (``"ff"``, ``"attn"``, ``"ssm"``, ``"head"``, ...).  The model
config's :class:`LinearCfg` decides, per site, whether the layer is the DENSE
baseline or a DYAD variant — so flipping one config field swaps every ff
projection of any architecture to DYAD, exactly the paper's drop-in story.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import dyad, linear

Params = Dict[str, Any]

# sites a LinearCfg scope can capture
_SCOPES = {
    "none": frozenset(),
    "ff": frozenset({"ff"}),
    "ff+attn": frozenset({"ff", "attn"}),
    "ff+ssm": frozenset({"ff", "ssm"}),
    "all": frozenset({"ff", "attn", "ssm", "head"}),
}


@dataclasses.dataclass(frozen=True)
class LinearCfg:
    """Static, hashable description of the framework's linear-layer policy."""

    impl: str = "dense"            # "dense" | "dyad"
    n_dyad: int = 4
    variant: str = "it"            # "it" | "ot" | "dt"
    cat: bool = False
    use_kernel: bool = False
    use_kernel_bwd: bool = True    # fused Pallas backward (with use_kernel)
    scope: str = "ff"              # which sites receive DYAD when impl == "dyad"
    # beyond-paper (paper Future Work §4.i — heterogeneous variant mix):
    # fuse the ff module with up=IT / down=OT and a 3-D block-layout hidden,
    # eliminating the interleaved-sharding reshape between projections under
    # tensor parallelism (see EXPERIMENTS §Perf).
    fuse_mlp: bool = False
    # run that same up=IT/act/down=OT ff dataflow as ONE Pallas grid
    # (kernels.dyad_mm.dyad_ff_fused): the (..., n, d_ff/n) hidden lives
    # only in VMEM accumulator tiles, never in HBM.  Needs use_kernel=True;
    # layers.mlp dispatches when the ff params are bias-free DYAD.  Spec
    # token "ffused" (e.g. "dyad_it_4_kernel_ffused");
    # REPRO_KERNEL_FF=fused|split forces the route inside the op.
    fuse_ff_kernel: bool = False
    # serving-only weight quantization: "int8" | "fp8" streams the
    # per-block quantized sidecar leaves (repro.quant.quantize_params)
    # through the dequant-at-VMEM-load kernel bodies.  Forward-only — the
    # dispatch sites require the sidecars to be PRESENT (an un-quantized
    # param tree falls through to the fp routes untouched), so training
    # params never take it.  Spec token "w8"/"wfp8"
    # (e.g. "dyad_it_4_kernel_ffused_w8"); REPRO_KERNEL_QUANT=off restores
    # bit-identical fp behavior.
    quant: Optional[str] = None

    def dyad_at(self, site: str) -> bool:
        if self.impl != "dyad":
            return False
        try:
            return site in _SCOPES[self.scope]
        except KeyError:
            raise ValueError(f"unknown dyad scope {self.scope!r}") from None

    def replace(self, **kw) -> "LinearCfg":
        return dataclasses.replace(self, **kw)

    def spec(self, f_in: int, f_out: int) -> dyad.DyadSpec:
        n = dyad.resolve_n_dyad(f_in, f_out, self.n_dyad)
        return dyad.DyadSpec(
            n_dyad=n, variant=self.variant, cat=self.cat,
            use_kernel=self.use_kernel, use_kernel_bwd=self.use_kernel_bwd
        )


DENSE = LinearCfg(impl="dense")


def init(
    key: jax.Array,
    f_in: int,
    f_out: int,
    cfg: LinearCfg,
    *,
    site: str = "ff",
    bias: bool = True,
    dtype: jnp.dtype = jnp.float32,
) -> Params:
    if cfg.dyad_at(site):
        return dyad.init(key, f_in, f_out, cfg.spec(f_in, f_out), bias=bias, dtype=dtype)
    return linear.init(key, f_in, f_out, bias=bias, dtype=dtype)


def apply(params: Params, x: jax.Array, cfg: LinearCfg, *, site: str = "ff") -> jax.Array:
    if "w1" in params:  # dyad params
        n, d_out, d_in = params["w1"].shape
        if cfg.quant and cfg.use_kernel:
            from repro import obs, quant
            from repro.kernels import ops as kops

            # forward-only: requires the offline sidecars — a tree without
            # them (training params) falls through to the fp routes.
            ready = quant.module_quantized(params) and quant.enabled()
            obs.route_event("mm_quant", cfg.quant if ready else "fp_fallback",
                            site=site)
            if ready:
                y = kops.dyad_mm_quant(x, params["w1_q"], params["w2_q"],
                                       params["w1_s"], params["w2_s"],
                                       variant=cfg.variant)
                if "b" in params:
                    y = y + params["b"].astype(y.dtype)
                return y
        return dyad.apply(params, x, cfg.spec(n * d_in, n * d_out))
    return linear.apply(params, x)


def param_count(f_in: int, f_out: int, cfg: LinearCfg, *, site: str = "ff",
                bias: bool = True) -> int:
    if cfg.dyad_at(site):
        n = dyad.resolve_n_dyad(f_in, f_out, cfg.n_dyad)
        return dyad.param_count(f_in, f_out, n, bias)
    return linear.param_count(f_in, f_out, bias)
