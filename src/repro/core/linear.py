"""DENSE baseline linear layer (the paper's comparison point)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def init(
    key: jax.Array,
    f_in: int,
    f_out: int,
    *,
    bias: bool = True,
    dtype: jnp.dtype = jnp.float32,
) -> Params:
    """Matches the paper's DENSE baseline (and torch.nn.Linear default):
    uniform(-k, k) with k = 1/sqrt(f_in)."""
    k = 1.0 / jnp.sqrt(jnp.asarray(f_in, jnp.float32))
    k1, k2 = jax.random.split(key)
    p: Params = {"w": jax.random.uniform(k1, (f_out, f_in), dtype, -k, k)}
    if bias:
        p["b"] = jax.random.uniform(k2, (f_out,), dtype, -k, k)
    return p


def apply(params: Params, x: jax.Array) -> jax.Array:
    y = x @ params["w"].T.astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def param_count(f_in: int, f_out: int, bias: bool = True) -> int:
    return f_out * f_in + (f_out if bias else 0)


def flops(batch: int, f_in: int, f_out: int) -> int:
    return 2 * batch * f_out * f_in
