"""Flash-attention Pallas TPU kernels: fused prefill + ring-cache decode.

Two kernels move the attention hot path onto the same tuned-tile footing
as the DYAD matmul/ff kernels (:mod:`repro.kernels.dyad_mm`):

* :func:`flash_prefill` — ONE grid ``(B, K, S/bQ, T/bK)`` with the key axis
  sequential-innermost.  Online-softmax state (m, l, acc) lives in fp32
  VMEM scratch and is revisited across key tiles, so the ``(S, T)`` score
  matrix never exists — each ``(bQ·G, bK)`` score tile is consumed in
  VMEM by the softmax update and the P·V dot on the same grid step.  GQA
  is handled by folding the G query heads that share a KV head into the
  q-tile rows: one streamed K/V tile serves all G heads.  Causal and
  sliding-window masking get STATIC band skipping — the key-tile index
  map clamps out-of-band tiles onto an in-band neighbour (no DMA is
  issued for a revisited block) and ``pl.when`` skips their compute, so
  fully-masked key tiles cost neither bandwidth nor FLOPs.

* :func:`flash_decode` — the S=1 ring-buffer cache path.  q is broadcast
  across key tiles of the ``(B, L, K, h)`` cache; the per-slot key
  position is computed IN-KERNEL from the scalar-prefetched write index
  ``idx`` (``pos[j] = idx - (idx - j) mod L`` — the ring layout of
  ``layers.attention``), so both the homogeneous ``Engine`` (scalar idx)
  and the per-slot ``ContinuousBatchingEngine`` (vector idx) decode steps
  hit the same kernel.  Key tiles wholly beyond ``idx`` (unwrapped cache)
  are skipped with ``pl.when``.

Backward (:func:`flash_prefill_grads`): the standard two-kernel flash
backward — probabilities are RECOMPUTED per tile from the saved
log-sum-exp (``lse = m + log l``), never stored.  ``dq`` runs on the
forward grid (key axis innermost, one fp32 dq accumulator per q tile);
``dk``/``dv`` run the transposed grid (q axis innermost, two fp32
accumulators per key tile).  Both reuse the same band-skip logic.

Masking contract (shared with ``layers.attention``): query row ``r`` of
tile ``qi`` sits at absolute position ``q_off + qi*bQ + r//G``; key
column ``c`` at ``k_off + c``.  ``q_off``/``k_off`` are scalar-prefetched
per-batch vectors, which covers the no-cache forward (``k_off = 0``) and
the fresh-stream cache prefill (``q_off = k_off = idx``) with one kernel.
Masked probabilities are zeroed EXPLICITLY (``where(mask, e, 0)``), so a
fully-masked row yields output 0 (l = 0 guard), exactly like the XLA
paths after their ``jnp.maximum(l, 1e-30)`` guard.

Tile selection: ``block_q`` (query positions per tile) and ``block_k``
(keys per tile) resolve from the autotune cache under the
``flash_prefill`` / ``flash_decode`` op keys (``repro.perf.autotune``;
``block_b`` in the cache dict tiles q positions, ``block_k`` tiles keys,
``block_o`` is unused — the head dim is never tiled).  Degenerate (odd /
prime) S, T pad up to tile units exactly like ``plan_tiles``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dyad_mm import _CompilerParams, _largest_divisor, _plan_axis

NEG_INF = -1e30
_TINY = 1e-30

# minimal healthy tiles: q positions are sublane-like (unit 8); keys are
# the lane axis of the score tile (unit 128)
_UNIT_Q = 8
_UNIT_K = 128
# lanes carried by the m/l softmax-state scratch (all lanes hold the same
# value; 128 matches the fp32 native tile so no partial-lane relayouts)
_STATE_LANES = 128


def resolve_attn_blocks(op: str, rows: int, n_kv: int, h: int, kv_len: int,
                        dtype, g: int, block_q=None, block_k=None,
                        page: Optional[int] = None):
    """Fill unspecified flash tile sizes from the autotune cache (explicit
    arguments always win).  ``block_b`` in the cached dict tiles q
    positions, ``block_k`` tiles keys; the GQA ratio ``g`` rides in the
    key as ``d_mid`` (it scales the resident q/acc rows ``bQ*G``) and the
    page size rides as ``d_page`` for the paged decode op — a key tile can
    never span a page boundary, so tiles tuned for one page size must not
    collide with another."""
    if block_q is None or block_k is None:
        from repro.perf.autotune import get_tuned_blocks

        tuned = get_tuned_blocks(op, rows, n_kv, h, kv_len,
                                 str(jnp.dtype(dtype)), d_mid=g,
                                 d_page=page)
        block_q = tuned["block_b"] if block_q is None else block_q
        block_k = tuned["block_k"] if block_k is None else block_k
    return block_q, block_k


def _as_offsets(off, B: int):
    """Normalize a scalar / (B,)-vector offset to an int32 (B,) vector."""
    off = jnp.asarray(off, jnp.int32).reshape(-1)
    return jnp.broadcast_to(off, (B,))


def _fold_gqa(q):
    """(B, S, K, G, h) -> (B, K, S*G, h): row r = s*G + g, so the G query
    heads sharing a KV head are adjacent rows of one q tile."""
    B, S, K, G, h = q.shape
    return q.transpose(0, 2, 1, 3, 4).reshape(B, K, S * G, h)


def _unfold_gqa(o, S: int, G: int):
    B, K, SG, h = o.shape
    return o.reshape(B, K, SG // G, G, h).transpose(0, 2, 1, 3, 4)[:, :S]


def _band(causal: bool, window: Optional[int], d, qi, ki, bQ: int, bT: int):
    """Is key tile ``ki`` inside the (causal, window) band of q tile ``qi``?
    ``d = q_off - k_off`` (per-batch).  Returns None when unbanded."""
    conds = []
    if causal:
        conds.append(ki * bT <= d + (qi + 1) * bQ - 1)
    if window is not None:
        conds.append((ki + 1) * bT - 1 >= d + qi * bQ - window + 1)
    if not conds:
        return None
    out = conds[0]
    for c in conds[1:]:
        out = jnp.logical_and(out, c)
    return out


def _kv_index_map(causal: bool, window: Optional[int], bQ: int, bT: int,
                  nt: int):
    """Key/value index map with static band clamping: out-of-band grid
    steps re-request the nearest in-band tile, so Pallas issues no DMA for
    them (same-block revisit) and ``pl.when`` skips their compute."""

    def index(b, kh, qi, ki, qoff_ref, koff_ref):
        if not causal and window is None:
            return (b, kh, ki, 0)
        d = qoff_ref[b] - koff_ref[b]
        ki_eff = ki
        if causal:
            last = jnp.maximum((d + (qi + 1) * bQ - 1) // bT, 0)
            ki_eff = jnp.minimum(ki_eff, last)
        if window is not None:
            first = jnp.clip((d + qi * bQ - window + 1) // bT, 0, nt - 1)
            ki_eff = jnp.maximum(ki_eff, first)
        return (b, kh, ki_eff, 0)

    return index


def _tile_mask(qoff, koff, qi, ki, bQ: int, bT: int, G: int, t_real: int,
               causal: bool, window: Optional[int]):
    """(bQ*G, bT) boolean validity mask for one score tile."""
    bQG = bQ * G
    rows = jax.lax.broadcasted_iota(jnp.int32, (bQG, bT), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bQG, bT), 1) + ki * bT
    qrow = qoff + qi * bQ + rows // G
    kcol = koff + cols
    mask = cols < t_real
    if causal:
        mask = jnp.logical_and(mask, kcol <= qrow)
    if window is not None:
        mask = jnp.logical_and(mask, qrow - kcol < window)
    return mask


# -- forward ------------------------------------------------------------------


def _prefill_kernel(qoff_ref, koff_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                    G: int, bQ: int, bT: int, t_real: int, causal: bool,
                    window: Optional[int], scale: float, save_lse: bool):
    if save_lse:
        lse_ref, m_s, l_s, acc = rest
    else:
        m_s, l_s, acc = rest
    b, qi, ki = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    nt = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    def compute():
        # the cache-prefill path streams K/V in the cache dtype, which may
        # differ from the query's compute dtype: promote per-tile in VMEM
        ct = jnp.promote_types(q_ref.dtype, k_ref.dtype)
        q = q_ref[0, 0].astype(ct)                       # (bQ*G, h)
        k = k_ref[0, 0].astype(ct)                       # (bT, h)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bQ*G, bT)
        mask = _tile_mask(qoff_ref[b], koff_ref[b], qi, ki, bQ, bT, G,
                          t_real, causal, window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_curr = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)             # (bQ*G, 128)
        alpha = jnp.exp(m_prev - m_next)
        # explicit zeroing: fully-masked rows keep l == 0 -> output 0
        p = jnp.where(mask, jnp.exp(s - m_next[:, :1]), 0.0)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_s[...] = m_next
        acc[...] = acc[...] * alpha[:, :1] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    band = _band(causal, window, qoff_ref[b] - koff_ref[b], qi, ki, bQ, bT)
    if band is None:
        compute()
    else:
        pl.when(band)(compute)

    @pl.when(ki == nt - 1)
    def _flush():
        l = l_s[:, :1]
        o_ref[0, 0] = (acc[...] / jnp.maximum(l, _TINY)).astype(o_ref.dtype)
        if save_lse:
            lse_ref[0, 0, :] = (m_s[:, 0]
                                + jnp.log(jnp.maximum(l_s[:, 0], _TINY)))


@functools.partial(
    jax.jit, static_argnames=("bQ", "bT", "G", "causal", "window", "t_real",
                              "save_lse", "interpret")
)
def _prefill_impl(q, k, v, qoff, koff, *, bQ, bT, G, causal, window, t_real,
                  save_lse, interpret):
    B, K, SG, h = q.shape
    Tp = k.shape[2]
    nq, nt = SG // (bQ * G), Tp // bT
    grid = (B, K, nq, nt)
    bQG = bQ * G

    q_spec = pl.BlockSpec((1, 1, bQG, h),
                          lambda b, kh, qi, ki, qo, ko: (b, kh, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, bT, h),
                           _kv_index_map(causal, window, bQ, bT, nt))
    o_spec = pl.BlockSpec((1, 1, bQG, h),
                          lambda b, kh, qi, ki, qo, ko: (b, kh, qi, 0))
    out_shape = jax.ShapeDtypeStruct((B, K, SG, h), q.dtype)
    out_specs, out_shapes = [o_spec], [out_shape]
    if save_lse:
        out_specs.append(pl.BlockSpec(
            (1, 1, bQG), lambda b, kh, qi, ki, qo, ko: (b, kh, qi)))
        out_shapes.append(jax.ShapeDtypeStruct((B, K, SG), jnp.float32))

    scale = 1.0 / float(h) ** 0.5
    body = functools.partial(
        _prefill_kernel, G=G, bQ=bQ, bT=bT, t_real=t_real, causal=causal,
        window=window, scale=scale, save_lse=save_lse)
    out = pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((bQG, _STATE_LANES), jnp.float32),
                pltpu.VMEM((bQG, _STATE_LANES), jnp.float32),
                pltpu.VMEM((bQG, h), jnp.float32),
            ],
        ),
        out_shape=out_shapes,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qoff, koff, q, k, v)
    return (out[0], out[1]) if save_lse else (out[0], None)


def _plan_attn(S: int, T: int, block_q: int, block_k: int):
    bQ, Sp = _plan_axis(S, block_q, _UNIT_Q)
    bT, Tp = _plan_axis(T, block_k, _UNIT_K)
    return bQ, Sp, bT, Tp


def _pad_axis1(x, to: int):
    d = to - x.shape[1]
    return jnp.pad(x, ((0, 0), (0, d)) + ((0, 0),) * (x.ndim - 2)) if d else x


def flash_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_off=0,
    k_off=0,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    save_lse: bool = False,
    block_q: int = None,
    block_k: int = None,
    interpret: bool = False,
):
    """Fused flash attention forward.

    q: (B, S, K, G, h); k, v: (B, T, K, h) — the layer-natural GQA layout.
    Query position ``s`` sits at ``q_off + s``; key ``t`` at ``k_off + t``
    (scalars or (B,) vectors — positions must be CONTIGUOUS from the
    offset, which every dispatch site guarantees).  Returns
    ``(out (B,S,K,G,h), lse)`` where ``lse`` is the (B, K, S*G) fp32
    log-sum-exp when ``save_lse`` (the backward residual), else None.
    """
    B, S, K, G, h = q.shape
    T = k.shape[1]
    bq, bk = resolve_attn_blocks("flash_prefill", S, K, h, T, q.dtype, G,
                                 block_q, block_k)
    bQ, Sp, bT, Tp = _plan_attn(S, T, bq, bk)
    q = _fold_gqa(_pad_axis1(q, Sp))
    k = _pad_axis1(k, Tp).transpose(0, 2, 1, 3)
    v = _pad_axis1(v, Tp).transpose(0, 2, 1, 3)
    o, lse = _prefill_impl(
        q, k, v, _as_offsets(q_off, B), _as_offsets(k_off, B),
        bQ=bQ, bT=bT, G=G, causal=causal, window=window, t_real=T,
        save_lse=save_lse, interpret=interpret)
    o = _unfold_gqa(o, S, G)
    if lse is not None and Sp != S:
        lse = lse.reshape(B, K, Sp, G)[:, :, :S].reshape(B, K, S * G)
    return o, lse


# -- backward: dq -------------------------------------------------------------
#
# Same grid as the forward (key axis innermost); probabilities recomputed
# per tile from the saved lse, one fp32 (bQ*G, h) dq accumulator.


def _dq_kernel(qoff_ref, koff_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
               delta_ref, dq_ref, acc, *, G: int, bQ: int, bT: int,
               t_real: int, causal: bool, window: Optional[int],
               scale: float):
    b, qi, ki = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    nt = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    def compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = _tile_mask(qoff_ref[b], koff_ref[b], qi, ki, bQ, bT, G,
                          t_real, causal, window)
        lse = lse_ref[0, 0, :][:, None]                     # (bQ*G, 1)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(
            do_ref[0, 0], v_ref[0, 0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0, :][:, None]) * scale
        acc[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    band = _band(causal, window, qoff_ref[b] - koff_ref[b], qi, ki, bQ, bT)
    if band is None:
        compute()
    else:
        pl.when(band)(compute)

    @pl.when(ki == nt - 1)
    def _flush():
        dq_ref[0, 0] = acc[...].astype(dq_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bQ", "bT", "G", "causal", "window", "t_real",
                              "interpret")
)
def _dq_impl(q, k, v, do, lse, delta, qoff, koff, *, bQ, bT, G, causal,
             window, t_real, interpret):
    B, K, SG, h = q.shape
    Tp = k.shape[2]
    nq, nt = SG // (bQ * G), Tp // bT
    bQG = bQ * G

    q_spec = pl.BlockSpec((1, 1, bQG, h),
                          lambda b, kh, qi, ki, qo, ko: (b, kh, qi, 0))
    kv_spec = pl.BlockSpec((1, 1, bT, h),
                           _kv_index_map(causal, window, bQ, bT, nt))
    row_spec = pl.BlockSpec((1, 1, bQG),
                            lambda b, kh, qi, ki, qo, ko: (b, kh, qi))
    scale = 1.0 / float(h) ** 0.5
    body = functools.partial(_dq_kernel, G=G, bQ=bQ, bT=bT, t_real=t_real,
                             causal=causal, window=window, scale=scale)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, K, nq, nt),
            in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
            out_specs=[q_spec],
            scratch_shapes=[pltpu.VMEM((bQG, h), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, K, SG, h), q.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qoff, koff, q, k, v, do, lse, delta)[0]


# -- backward: dk / dv --------------------------------------------------------
#
# Transposed grid ``(B, K, T/bK, S/bQ)`` — the q axis is the reduction,
# innermost, so the two (bT, h) fp32 accumulators are revisited per key
# tile.  The q-side index map clamps out-of-band q tiles symmetrically.


def _q_index_map(causal: bool, window: Optional[int], bQ: int, bT: int,
                 nq: int):
    def index(b, kh, ki, qi, qoff_ref, koff_ref):
        if not causal and window is None:
            return (b, kh, qi, 0)
        d = qoff_ref[b] - koff_ref[b]
        qi_eff = qi
        if causal:
            # rows qrow >= kcol_min: qi >= (ki*bT - d) // bQ
            first = jnp.clip((ki * bT - d) // bQ, 0, nq - 1)
            qi_eff = jnp.maximum(qi_eff, first)
        if window is not None:
            last = jnp.maximum(
                ((ki + 1) * bT - 1 + window - 1 - d) // bQ, 0)
            qi_eff = jnp.minimum(qi_eff, last)
        return (b, kh, qi_eff, 0)

    return index


def _dkv_kernel(qoff_ref, koff_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                delta_ref, dk_ref, dv_ref, kacc, vacc, *, G: int, bQ: int,
                bT: int, t_real: int, causal: bool, window: Optional[int],
                scale: float):
    b, ki, qi = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        kacc[...] = jnp.zeros_like(kacc)
        vacc[...] = jnp.zeros_like(vacc)

    def compute():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = _tile_mask(qoff_ref[b], koff_ref[b], qi, ki, bQ, bT, G,
                          t_real, causal, window)
        lse = lse_ref[0, 0, :][:, None]
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        do = do_ref[0, 0]
        # dv += P^T · dO  — contract the q rows
        vacc[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(
            do, v_ref[0, 0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, 0, :][:, None]) * scale
        kacc[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    band = _band(causal, window, qoff_ref[b] - koff_ref[b], qi, ki, bQ, bT)
    if band is None:
        compute()
    else:
        pl.when(band)(compute)

    @pl.when(qi == nq - 1)
    def _flush():
        dk_ref[0, 0] = kacc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = vacc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bQ", "bT", "G", "causal", "window", "t_real",
                              "interpret")
)
def _dkv_impl(q, k, v, do, lse, delta, qoff, koff, *, bQ, bT, G, causal,
              window, t_real, interpret):
    B, K, SG, h = q.shape
    Tp = k.shape[2]
    nq, nt = SG // (bQ * G), Tp // bT
    bQG = bQ * G

    q_spec = pl.BlockSpec((1, 1, bQG, h),
                          _q_index_map(causal, window, bQ, bT, nq))
    kv_spec = pl.BlockSpec((1, 1, bT, h),
                           lambda b, kh, ki, qi, qo, ko: (b, kh, ki, 0))

    def row_index(b, kh, ki, qi, qo, ko):
        return _q_index_map(causal, window, bQ, bT, nq)(
            b, kh, ki, qi, qo, ko)[:3]

    row_spec = pl.BlockSpec((1, 1, bQG), row_index)
    scale = 1.0 / float(h) ** 0.5
    body = functools.partial(_dkv_kernel, G=G, bQ=bQ, bT=bT, t_real=t_real,
                             causal=causal, window=window, scale=scale)
    out_sds = jax.ShapeDtypeStruct((B, K, Tp, h), k.dtype)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, K, nt, nq),
            in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
            out_specs=[kv_spec, kv_spec],
            scratch_shapes=[pltpu.VMEM((bT, h), jnp.float32),
                            pltpu.VMEM((bT, h), jnp.float32)],
        ),
        out_shape=[out_sds, out_sds],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(qoff, koff, q, k, v, do, lse, delta)


def flash_prefill_grads(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    o: jax.Array,
    lse: jax.Array,
    do: jax.Array,
    q_off=0,
    k_off=0,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = None,
    block_k: int = None,
    interpret: bool = False,
):
    """Flash backward: (dq, dk, dv) at the layer-natural layouts.

    ``lse`` is the (B, K, S*G) residual from ``flash_prefill(...,
    save_lse=True)``; probabilities are recomputed per tile from it —
    the ``(S, T)`` score matrix is never materialized here either.
    """
    B, S, K, G, h = q.shape
    T = k.shape[1]
    bq, bk = resolve_attn_blocks("flash_prefill", S, K, h, T, q.dtype, G,
                                 block_q, block_k)
    bQ, Sp, bT, Tp = _plan_attn(S, T, bq, bk)
    qf = _fold_gqa(_pad_axis1(q, Sp))
    dof = _fold_gqa(_pad_axis1(do.astype(q.dtype), Sp))
    kf = _pad_axis1(k, Tp).transpose(0, 2, 1, 3)
    vf = _pad_axis1(v, Tp).transpose(0, 2, 1, 3)
    of = _fold_gqa(_pad_axis1(o, Sp))
    delta = jnp.sum(of.astype(jnp.float32) * dof.astype(jnp.float32),
                    axis=-1)                                   # (B, K, SG)
    if Sp != S:
        # pad with a LARGE lse so recomputed p = exp(s - lse) underflows to
        # exactly 0 on the padded rows (NEG_INF would overflow to inf)
        lse = jnp.pad(lse.reshape(B, K, S, G),
                      ((0, 0), (0, 0), (0, Sp - S), (0, 0)),
                      constant_values=-NEG_INF).reshape(B, K, Sp * G)
    qoff, koff = _as_offsets(q_off, B), _as_offsets(k_off, B)
    kw = dict(bQ=bQ, bT=bT, G=G, causal=causal, window=window, t_real=T,
              interpret=interpret)
    dq = _dq_impl(qf, kf, vf, dof, lse, delta, qoff, koff, **kw)
    dk, dv = _dkv_impl(qf, kf, vf, dof, lse, delta, qoff, koff, **kw)
    dq = _unfold_gqa(dq, S, G)
    dk = dk.transpose(0, 2, 1, 3)[:, :T]
    dv = dv.transpose(0, 2, 1, 3)[:, :T]
    return dq, dk, dv


# -- decode: the S=1 ring-cache step ------------------------------------------


def _decode_kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc, *,
                   bT: int, l_real: int, window: Optional[int],
                   scale: float):
    b, t = pl.program_id(0), pl.program_id(2)
    nt = pl.num_programs(2)
    idx = idx_ref[b]

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    # slots wholly beyond the write index (unwrapped cache) hold nothing:
    # skip their tiles entirely.  A wrapped ring (idx >= L) keeps every
    # tile active since t*bT < L <= idx.
    @pl.when(t * bT <= idx)
    def _compute():
        G = q_ref.shape[2]
        # the cache may hold a different dtype than the query (bf16 KV
        # under fp32 compute or vice versa): promote per-tile in VMEM
        ct = jnp.promote_types(q_ref.dtype, k_ref.dtype)
        q = q_ref[0, 0].astype(ct)                        # (G, h)
        k = k_ref[0, 0].astype(ct)                        # (bT, h)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bT)
        j = jax.lax.broadcasted_iota(jnp.int32, (G, bT), 1) + t * bT
        # ring layout: slot j holds absolute position idx - (idx - j) % L
        pos = idx - jnp.remainder(idx - j, l_real)
        mask = jnp.logical_and(pos >= 0, j < l_real)
        if window is not None:
            mask = jnp.logical_and(mask, idx - pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_curr = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_curr)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.where(mask, jnp.exp(s - m_next[:, :1]), 0.0)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_s[...] = m_next
        acc[...] = acc[...] * alpha[:, :1] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _flush():
        l = l_s[:, :1]
        o_ref[0, 0] = (acc[...] / jnp.maximum(l, _TINY)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bT", "l_real", "window", "interpret")
)
def _decode_impl(q, k, v, idx, *, bT, l_real, window, interpret):
    B, K, G, h = q.shape
    Lp = k.shape[2]
    nt = Lp // bT

    q_spec = pl.BlockSpec((1, 1, G, h), lambda b, kh, t, i: (b, kh, 0, 0))
    kv_spec = pl.BlockSpec((1, 1, bT, h), lambda b, kh, t, i: (b, kh, t, 0))
    scale = 1.0 / float(h) ** 0.5
    body = functools.partial(_decode_kernel, bT=bT, l_real=l_real,
                             window=window, scale=scale)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, K, nt),
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=[q_spec],
            scratch_shapes=[
                pltpu.VMEM((G, _STATE_LANES), jnp.float32),
                pltpu.VMEM((G, _STATE_LANES), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, K, G, h), q.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx, q, k, v)[0]


def flash_decode(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    idx,
    *,
    window: Optional[int] = None,
    block_k: int = None,
    interpret: bool = False,
):
    """One-token decode attention over a ring-buffer KV cache.

    q: (B, 1, K, G, h) or (B, K, G, h) — the single new (roped) query.
    k, v: (B, L, K, h) — the POST-WRITE cache.  ``idx`` is the cache
    write index of the current token (scalar, or (B,) per-slot vector
    from the continuous-batching engine); each slot's absolute position
    is derived from it in-kernel, so wrapped rings, bounded-window
    caches, and heterogeneous per-slot positions all resolve exactly.
    Returns (B, 1, K, G, h) / (B, K, G, h) matching the q rank.
    """
    squeeze = q.ndim == 5
    if squeeze:
        q = q[:, 0]
    B, K, G, h = q.shape
    L = k.shape[1]
    _, bk = resolve_attn_blocks("flash_decode", B, K, h, L, q.dtype, G,
                                None, block_k)
    bT, Lp = _plan_axis(L, bk, _UNIT_K)
    k = _pad_axis1(k, Lp).transpose(0, 2, 1, 3)
    v = _pad_axis1(v, Lp).transpose(0, 2, 1, 3)
    o = _decode_impl(q, k, v, _as_offsets(idx, B), bT=bT, l_real=L,
                     window=window, interpret=interpret)
    return o[:, None] if squeeze else o


# -- paged decode: gather K/V tiles through a block table ---------------------
#
# The paged-KV variant of :func:`flash_decode`.  The cache is a PAGE POOL
# ``(n_pages, P, K, h)`` shared by every slot; each slot owns an ordered
# block table row mapping its logical block ``j // P`` to a physical page.
# Both the block table and the per-slot write indices are scalar-prefetched,
# so the K/V index map can route every grid step's DMA to the right page
# BEFORE the kernel body runs — the gather costs an index computation, not
# a materialized per-slot cache copy.  The key-tile size is clamped to a
# divisor of the page size (a tile never spans a page boundary), and tiles
# wholly beyond a slot's write index are clamped onto the last live tile
# (revisited block = no DMA) with ``pl.when`` skipping their compute, so
# short sequences in a long-capacity table cost neither bandwidth nor
# FLOPs.  Unallocated block-table entries MUST still hold a valid page id
# (the engine points them at the reserved scratch page 0).


def _decode_paged_kernel(idx_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_s,
                         l_s, acc, *, bT: int, l_real: int,
                         window: Optional[int], scale: float):
    b, t = pl.program_id(0), pl.program_id(2)
    nt = pl.num_programs(2)
    idx = idx_ref[b]

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    # tiles wholly beyond the write index hold nothing: skip their compute
    # (their DMA was already clamped onto a live tile by the index map).
    @pl.when(t * bT <= idx)
    def _compute():
        G = q_ref.shape[2]
        ct = jnp.promote_types(q_ref.dtype, k_ref.dtype)
        q = q_ref[0, 0].astype(ct)                        # (G, h)
        k = k_ref[0, 0].astype(ct)                        # (bT, h)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, bT)
        # logical position IS the tile coordinate: the block table is
        # ordered, pages never wrap (no ring arithmetic).
        j = jax.lax.broadcasted_iota(jnp.int32, (G, bT), 1) + t * bT
        mask = jnp.logical_and(j <= idx, j < l_real)
        if window is not None:
            mask = jnp.logical_and(mask, idx - j < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.where(mask, jnp.exp(s - m_next[:, :1]), 0.0)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_s[...] = m_next
        acc[...] = acc[...] * alpha[:, :1] + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _flush():
        l = l_s[:, :1]
        o_ref[0, 0] = (acc[...] / jnp.maximum(l, _TINY)).astype(o_ref.dtype)


def _paged_kv_index_map(bT: int, tiles_per_page: int):
    """Route grid step ``t`` of slot ``b`` to page ``bt[b, t*bT // P]``.
    Dead tiles (beyond the write index) re-request the last live tile so
    Pallas issues no DMA for them (same-block revisit)."""

    def index(b, kh, t, idx_ref, bt_ref):
        t_eff = jnp.minimum(t, jnp.maximum(idx_ref[b], 0) // bT)
        blk = t_eff // tiles_per_page
        return (bt_ref[b, blk], kh, t_eff % tiles_per_page, 0)

    return index


def _paged_scale_index_map(bT: int, tiles_per_page: int):
    """The 3-D twin of :func:`_paged_kv_index_map` for the per-token-row
    scale pools ``(n_pages, K, P)`` — the SAME block-table gather routes
    the (1, 1, bT) scale tile alongside its quantized K/V tile."""

    def index(b, kh, t, idx_ref, bt_ref):
        t_eff = jnp.minimum(t, jnp.maximum(idx_ref[b], 0) // bT)
        blk = t_eff // tiles_per_page
        return (bt_ref[b, blk], kh, t_eff % tiles_per_page)

    return index


def _decode_paged_kernel_q(idx_ref, bt_ref, q_ref, k_ref, v_ref, sk_ref,
                           sv_ref, o_ref, m_s, l_s, acc, *, bT: int,
                           l_real: int, window: Optional[int],
                           scale: float):
    """Quantized-KV twin of :func:`_decode_paged_kernel`: K/V tiles arrive
    as int8 payloads and are dequantized IN-KERNEL with their per-token-row
    fp32 scales.  Each scale is constant along the head dim the dots
    contract, so dequant folds into the score columns (``s * sk[None, :]``)
    and the probability rows (``p * sv[None, :]``) exactly — the payload is
    never expanded to fp in HBM.  Dead page rows hold zero scales (pool
    init), which the position mask already excludes."""
    b, t = pl.program_id(0), pl.program_id(2)
    nt = pl.num_programs(2)
    idx = idx_ref[b]

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    @pl.when(t * bT <= idx)
    def _compute():
        G = q_ref.shape[2]
        q = q_ref[0, 0]                                   # (G, h)
        k = k_ref[0, 0].astype(q.dtype)                   # (bT, h) dequant
        sk = sk_ref[0, 0]                                 # (bT,) fp32
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sk[None, :] * scale
        j = jax.lax.broadcasted_iota(jnp.int32, (G, bT), 1) + t * bT
        mask = jnp.logical_and(j <= idx, j < l_real)
        if window is not None:
            mask = jnp.logical_and(mask, idx - j < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[...]
        m_next = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.where(mask, jnp.exp(s - m_next[:, :1]), 0.0)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_s[...] = m_next
        sv = sv_ref[0, 0]                                 # (bT,) fp32
        acc[...] = acc[...] * alpha[:, :1] + jax.lax.dot_general(
            p * sv[None, :], v_ref[0, 0].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _flush():
        l = l_s[:, :1]
        o_ref[0, 0] = (acc[...] / jnp.maximum(l, _TINY)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bT", "l_real", "window", "interpret")
)
def _decode_paged_impl(q, k, v, idx, bt, *, bT, l_real, window, interpret):
    B, K, G, h = q.shape
    P = k.shape[2]
    tp = P // bT
    nt = bt.shape[1] * tp

    q_spec = pl.BlockSpec((1, 1, G, h), lambda b, kh, t, i, m: (b, kh, 0, 0))
    kv_spec = pl.BlockSpec((1, 1, bT, h), _paged_kv_index_map(bT, tp))
    scale = 1.0 / float(h) ** 0.5
    body = functools.partial(_decode_paged_kernel, bT=bT, l_real=l_real,
                             window=window, scale=scale)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, K, nt),
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=[q_spec],
            scratch_shapes=[
                pltpu.VMEM((G, _STATE_LANES), jnp.float32),
                pltpu.VMEM((G, _STATE_LANES), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, K, G, h), q.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx, bt, q, k, v)[0]


@functools.partial(
    jax.jit, static_argnames=("bT", "l_real", "window", "interpret")
)
def _decode_paged_q_impl(q, k, v, sk, sv, idx, bt, *, bT, l_real, window,
                         interpret):
    B, K, G, h = q.shape
    P = k.shape[2]
    tp = P // bT
    nt = bt.shape[1] * tp

    q_spec = pl.BlockSpec((1, 1, G, h), lambda b, kh, t, i, m: (b, kh, 0, 0))
    kv_spec = pl.BlockSpec((1, 1, bT, h), _paged_kv_index_map(bT, tp))
    s_spec = pl.BlockSpec((1, 1, bT), _paged_scale_index_map(bT, tp))
    scale = 1.0 / float(h) ** 0.5
    body = functools.partial(_decode_paged_kernel_q, bT=bT, l_real=l_real,
                             window=window, scale=scale)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, K, nt),
            in_specs=[q_spec, kv_spec, kv_spec, s_spec, s_spec],
            out_specs=[q_spec],
            scratch_shapes=[
                pltpu.VMEM((G, _STATE_LANES), jnp.float32),
                pltpu.VMEM((G, _STATE_LANES), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, K, G, h), q.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx, bt, q, k, v, sk, sv)[0]


def flash_decode_paged(
    q: jax.Array,
    pages_k: jax.Array,
    pages_v: jax.Array,
    block_table: jax.Array,
    idx,
    *,
    l_real: Optional[int] = None,
    window: Optional[int] = None,
    block_k: int = None,
    interpret: bool = False,
    scales_k: Optional[jax.Array] = None,
    scales_v: Optional[jax.Array] = None,
):
    """One-token decode attention over a PAGED KV cache.

    q: (B, 1, K, G, h) or (B, K, G, h) — the single new (roped) query.
    pages_k, pages_v: (n_pages, P, K, h) — the shared post-write page pool.
    ``block_table``: (B, n_blocks) int32, slot b's logical block ``j // P``
    lives in physical page ``block_table[b, j // P]`` (unallocated entries
    must point at a valid page — the engine's scratch page 0).  ``idx``:
    (B,) per-slot write index of the current token; logical positions are
    the tile coordinates themselves (ordered block tables, no ring).
    ``l_real`` bounds the logical length when the capacity ``n_blocks * P``
    overshoots it (page sizes that don't divide max_len).

    ``scales_k``/``scales_v`` (together) mark the pools as QUANTIZED:
    int8 payloads with per-token-row fp32 scales ``(n_pages, P, K)``
    (``repro.quant.quantize_kv_rows`` at the write site).  The kernel
    gathers the scale tiles through the same prefetched block table and
    dequantizes in-VMEM — K/V stream 2-4x fewer HBM bytes.
    Returns (B, 1, K, G, h) / (B, K, G, h) matching the q rank.
    """
    if (scales_k is None) != (scales_v is None):
        raise ValueError("scales_k and scales_v must be passed together")
    squeeze = q.ndim == 5
    if squeeze:
        q = q[:, 0]
    B, K, G, h = q.shape
    P = pages_k.shape[1]
    NB = block_table.shape[1]
    cap = NB * P
    if l_real is None:
        l_real = cap
    _, bk = resolve_attn_blocks("flash_decode_paged", B, K, h, cap,
                                pages_k.dtype if scales_k is not None
                                else q.dtype,
                                G, None, block_k, page=P)
    # a key tile must stay inside one page: largest divisor of P under the
    # requested tile (pages are pow2 in practice, so this is a pow2 clamp)
    bT = _largest_divisor(P, max(min(bk, P), 1))
    k = pages_k.transpose(0, 2, 1, 3)                     # (NP, K, P, h)
    v = pages_v.transpose(0, 2, 1, 3)
    if scales_k is not None:
        o = _decode_paged_q_impl(
            q, k, v,
            scales_k.transpose(0, 2, 1),                  # (NP, K, P)
            scales_v.transpose(0, 2, 1),
            _as_offsets(idx, B), jnp.asarray(block_table, jnp.int32),
            bT=bT, l_real=int(l_real), window=window, interpret=interpret)
    else:
        o = _decode_paged_impl(q, k, v, _as_offsets(idx, B),
                               jnp.asarray(block_table, jnp.int32),
                               bT=bT, l_real=int(l_real), window=window,
                               interpret=interpret)
    return o[:, None] if squeeze else o
