"""Fused DYAD matmul Pallas TPU kernel.

One ``pallas_call`` computes BOTH dyad components into a single VMEM-resident
fp32 accumulator:

    out[b, g, o] = sum_k x1[b, g, k] * w1[g, o, k] + x2[b, g, k] * w2[g, o, k]

This goes beyond the paper's ``-CAT`` trick: instead of concatenating the two
components into one ``2*n_dyad``-block bmm (which still materializes the
concatenated activations), both partial products accumulate in-register/VMEM
with zero extra HBM traffic.  The feature permutation that defines the
BLOCKTRANS component is handled by the caller as a strided re-view (``ops.py``)
so every tile the kernel streams HBM->VMEM is contiguous and 128-aligned.

Grid: ``(n_dyad, B/bB, d_out/bO, d_in/bK)`` — the k axis is innermost so the
accumulator tile is revisited on consecutive steps; block=g, batch and out
tiles are embarrassingly parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _largest_divisor(dim: int, target: int) -> int:
    d = min(dim, target)
    while dim % d:
        d -= 1
    return d


def _dyad_kernel(x1_ref, x2_ref, w1_ref, w2_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bB, bK) x (bO, bK)^T -> (bB, bO), accumulated in fp32 on the MXU.
    dn = (((1,), (1,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[:, 0, :] = acc_ref[...].astype(o_ref.dtype)


def _dyad_kernel_two(x1_ref, x2_ref, w1_ref, w2_ref, o1_ref, o2_ref,
                     acc1_ref, acc2_ref, *, nk: int):
    """Two-accumulator body for OT/DT, whose components write to different
    output layouts (BLOCKDIAG contiguous vs BLOCKTRANS strided): the kernel
    emits both per-block products; the caller applies the output re-view."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    dn = (((1,), (1,)), ((), ()))
    acc1_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc2_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o1_ref[:, 0, :] = acc1_ref[...].astype(o1_ref.dtype)
        o2_ref[:, 0, :] = acc2_ref[...].astype(o2_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_o", "block_k", "interpret")
)
def dyad_mm_blocks_two(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: bool = False,
):
    """As :func:`dyad_mm_blocks` but returns (z1, z2) separately (OT/DT)."""
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    bB = _largest_divisor(B, block_b)
    bO = _largest_divisor(d_out, block_o)
    bK = _largest_divisor(d_in, block_k)
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bO, bK), lambda g, b, o, k: (g, o, k))
    o_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, k: (b, g, o))
    out_sds = jax.ShapeDtypeStruct((B, n, d_out), x1.dtype)

    return pl.pallas_call(
        functools.partial(_dyad_kernel_two, nk=nk),
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[out_sds, out_sds],
        scratch_shapes=[
            pltpu.VMEM((bB, bO), jnp.float32),
            pltpu.VMEM((bB, bO), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, w1, w2)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_o", "block_k", "interpret")
)
def dyad_mm_blocks(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = 256,
    block_o: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused dual-bmm over per-block views.

    x1, x2: (B, n_dyad, d_in) — block-contiguous / permuted input views.
    w1, w2: (n_dyad, d_out, d_in).
    Returns (B, n_dyad, d_out), dtype of x1.
    """
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    bB = _largest_divisor(B, block_b)
    bO = _largest_divisor(d_out, block_o)
    bK = _largest_divisor(d_in, block_k)
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bO, bK), lambda g, b, o, k: (g, o, k))
    o_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, k: (b, g, o))

    return pl.pallas_call(
        functools.partial(_dyad_kernel, nk=nk),
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, n, d_out), x1.dtype),
        scratch_shapes=[pltpu.VMEM((bB, bO), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, w1, w2)
