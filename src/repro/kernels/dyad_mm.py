"""Fused DYAD matmul Pallas TPU kernel.

One ``pallas_call`` computes BOTH dyad components into a single VMEM-resident
fp32 accumulator:

    out[b, g, o] = sum_k x1[b, g, k] * w1[g, o, k] + x2[b, g, k] * w2[g, o, k]

This goes beyond the paper's ``-CAT`` trick: instead of concatenating the two
components into one ``2*n_dyad``-block bmm (which still materializes the
concatenated activations), both partial products accumulate in-register/VMEM
with zero extra HBM traffic.  The feature permutation that defines the
BLOCKTRANS component is handled by the caller as a strided re-view (``ops.py``)
so every tile the kernel streams HBM->VMEM is contiguous and 128-aligned.

Grid: ``(n_dyad, B/bB, d_out/bO, d_in/bK)`` — the k axis is innermost so the
accumulator tile is revisited on consecutive steps; block=g, batch and out
tiles are embarrassingly parallel.

Tile selection
--------------
``block_b/block_o/block_k`` default to the autotuned sizes for this
``(shape, dtype, backend)`` key (:func:`repro.perf.autotune.get_tuned_blocks`;
falls back to 256/256/512 when the shape was never tuned).  Tiles are then
*planned* per axis: a dimension whose largest divisor under the requested
block is degenerate (prime or odd dims used to collapse to 1-wide tiles and
a catastrophic grid) is zero-padded up to a tile-unit multiple instead —
zero rows/columns contribute nothing and are sliced off the output.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

# minimal healthy tile per axis: sublane granularity on the batch axis,
# lane granularity on the feature axes (fp32 native tile is (8, 128))
_UNIT_B = 8
_UNIT_FEAT = 128


def _largest_divisor(dim: int, target: int) -> int:
    d = min(dim, target)
    while dim % d:
        d -= 1
    return d


def _plan_axis(dim: int, block: int, unit: int):
    """(tile, padded_dim) for one grid axis.

    Healthy case: the largest divisor of ``dim`` under ``block`` is at least
    one tile unit (or the whole axis) — use it, no padding.  Degenerate case
    (prime/odd dims whose best divisor is tiny): round the axis up to a
    multiple of the unit so a real tile exists; the caller zero-pads."""
    u = max(min(unit, block), 1)
    d = _largest_divisor(dim, block)
    if d >= min(u, dim):
        return d, dim
    padded = -(-dim // u) * u
    return _largest_divisor(padded, block), padded


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Concrete grid tiling for one fused-kernel invocation."""

    bB: int
    bO: int
    bK: int
    padded_b: int
    padded_o: int
    padded_k: int

    @property
    def grid_steps(self) -> int:
        return ((self.padded_b // self.bB) * (self.padded_o // self.bO)
                * (self.padded_k // self.bK))


def plan_tiles(B: int, d_out: int, d_in: int,
               block_b: int, block_o: int, block_k: int) -> TilePlan:
    bB, pb = _plan_axis(B, block_b, _UNIT_B)
    bO, po = _plan_axis(d_out, block_o, _UNIT_FEAT)
    bK, pk = _plan_axis(d_in, block_k, _UNIT_FEAT)
    return TilePlan(bB=bB, bO=bO, bK=bK,
                    padded_b=pb, padded_o=po, padded_k=pk)


def resolve_blocks(op: str, B: int, n: int, d_in: int, d_out: int, dtype,
                   block_b=None, block_o=None, block_k=None):
    """Fill unspecified block sizes from the autotune cache (explicit
    arguments always win).  Runs at trace time — shapes are concrete."""
    if block_b is None or block_o is None or block_k is None:
        from repro.perf.autotune import get_tuned_blocks

        tuned = get_tuned_blocks(op, B, n, d_in, d_out,
                                 str(jnp.dtype(dtype)))
        block_b = tuned["block_b"] if block_b is None else block_b
        block_o = tuned["block_o"] if block_o is None else block_o
        block_k = tuned["block_k"] if block_k is None else block_k
    return block_b, block_o, block_k


def _pad_inputs(plan: TilePlan, x1, x2, w1, w2):
    B, _, d_in = x1.shape
    _, d_out, _ = w1.shape
    db, do, dk = (plan.padded_b - B, plan.padded_o - d_out,
                  plan.padded_k - d_in)
    if db or dk:
        x1 = jnp.pad(x1, ((0, db), (0, 0), (0, dk)))
        x2 = jnp.pad(x2, ((0, db), (0, 0), (0, dk)))
    if do or dk:
        w1 = jnp.pad(w1, ((0, 0), (0, do), (0, dk)))
        w2 = jnp.pad(w2, ((0, 0), (0, do), (0, dk)))
    return x1, x2, w1, w2


def _dyad_kernel(x1_ref, x2_ref, w1_ref, w2_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bB, bK) x (bO, bK)^T -> (bB, bO), accumulated in fp32 on the MXU.
    dn = (((1,), (1,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[:, 0, :] = acc_ref[...].astype(o_ref.dtype)


def _dyad_kernel_two(x1_ref, x2_ref, w1_ref, w2_ref, o1_ref, o2_ref,
                     acc1_ref, acc2_ref, *, nk: int):
    """Two-accumulator body for OT/DT, whose components write to different
    output layouts (BLOCKDIAG contiguous vs BLOCKTRANS strided): the kernel
    emits both per-block products; the caller applies the output re-view."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    dn = (((1,), (1,)), ((), ()))
    acc1_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc2_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o1_ref[:, 0, :] = acc1_ref[...].astype(o1_ref.dtype)
        o2_ref[:, 0, :] = acc2_ref[...].astype(o2_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bK", "interpret")
)
def _dyad_mm_two_impl(x1, x2, w1, w2, *, bB: int, bO: int, bK: int,
                      interpret: bool):
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bO, bK), lambda g, b, o, k: (g, o, k))
    o_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, k: (b, g, o))
    out_sds = jax.ShapeDtypeStruct((B, n, d_out), x1.dtype)

    return pl.pallas_call(
        functools.partial(_dyad_kernel_two, nk=nk),
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[out_sds, out_sds],
        scratch_shapes=[
            pltpu.VMEM((bB, bO), jnp.float32),
            pltpu.VMEM((bB, bO), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, w1, w2)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bK", "interpret")
)
def _dyad_mm_impl(x1, x2, w1, w2, *, bB: int, bO: int, bK: int,
                  interpret: bool):
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bO, bK), lambda g, b, o, k: (g, o, k))
    o_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, k: (b, g, o))

    return pl.pallas_call(
        functools.partial(_dyad_kernel, nk=nk),
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, n, d_out), x1.dtype),
        scratch_shapes=[pltpu.VMEM((bB, bO), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, w1, w2)


def dyad_mm_blocks_two(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
):
    """As :func:`dyad_mm_blocks` but returns (z1, z2) separately (OT/DT)."""
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    bb, bo, bk = resolve_blocks("dyad_mm_blocks_two", B, n, d_in, d_out,
                                x1.dtype, block_b, block_o, block_k)
    plan = plan_tiles(B, d_out, d_in, bb, bo, bk)
    x1, x2, w1, w2 = _pad_inputs(plan, x1, x2, w1, w2)
    z1, z2 = _dyad_mm_two_impl(x1, x2, w1, w2, bB=plan.bB, bO=plan.bO,
                               bK=plan.bK, interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_out:
        z1, z2 = z1[:B, :, :d_out], z2[:B, :, :d_out]
    return z1, z2


def dyad_mm_blocks(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused dual-bmm over per-block views.

    x1, x2: (B, n_dyad, d_in) — block-contiguous / permuted input views.
    w1, w2: (n_dyad, d_out, d_in).
    Returns (B, n_dyad, d_out), dtype of x1.

    Block sizes default to the autotuned tiles for this shape/dtype/backend
    (``repro.perf.autotune``); pass explicit values to override.
    """
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    bb, bo, bk = resolve_blocks("dyad_mm_blocks", B, n, d_in, d_out,
                                x1.dtype, block_b, block_o, block_k)
    plan = plan_tiles(B, d_out, d_in, bb, bo, bk)
    x1, x2, w1, w2 = _pad_inputs(plan, x1, x2, w1, w2)
    out = _dyad_mm_impl(x1, x2, w1, w2, bB=plan.bB, bO=plan.bO, bK=plan.bK,
                        interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_out:
        out = out[:B, :, :d_out]
    return out
