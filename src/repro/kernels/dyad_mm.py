"""Fused DYAD matmul Pallas TPU kernels — forward, backward, AND the
whole-ff megakernel.

Forward: one ``pallas_call`` computes BOTH dyad components into a single
VMEM-resident fp32 accumulator:

    out[b, g, o] = sum_k x1[b, g, k] * w1[g, o, k] + x2[b, g, k] * w2[g, o, k]

Megakernel (:func:`dyad_ff_fused`): the transformer ff module — up (and,
for SwiGLU, gate) DYAD matmul, activation epilogue, and the OT
down-projection — in ONE grid.  The ``(..., n, d_ff/n)`` hidden exists only
as an fp32 VMEM accumulator tile: it is activated in-register and consumed
by the down dot on the same grid step, so the three-dispatch split path's
hidden HBM round-trip (write (..., d_ff), read it back) disappears
entirely.  See the "megakernel" section below.

Backward: two more fused kernels keep the whole training hot path on Pallas
tiles (``kernels/ops.py`` routes its custom VJP through them):

* ``dyad_mm_dgrad``      — dx[b, g, i] = sum_o z1[b,g,o]*w1[g,o,i]
                                       + z2[b,g,o]*w2[g,o,i]
  (cotangent x transposed blocks, both components fused into ONE fp32
  accumulator — the add that ``ref.unview`` otherwise does in jnp);
* ``dyad_mm_dgrad_two``  — same contraction but the two components are
  emitted separately (variants whose input views live in different
  layouts: the caller applies the inverse re-view, then adds);
* ``dyad_mm_wgrad``      — dw1[g,o,i] = sum_b z1[b,g,o]*x1[b,g,i] and
  dw2 likewise, both weight grads in one grid with two fp32 accumulator
  tiles (the batch reduction never leaves VMEM).

No kernel ever materializes a transposed weight: the dgrad contraction runs
over the ``o`` axis of the SAME ``(n, d_out, d_in)`` weight tiles the forward
streams, and wgrad contracts the batch axis of the activation/cotangent
tiles directly.

This goes beyond the paper's ``-CAT`` trick: instead of concatenating the two
components into one ``2*n_dyad``-block bmm (which still materializes the
concatenated activations), both partial products accumulate in-register/VMEM
with zero extra HBM traffic.  The feature permutation that defines the
BLOCKTRANS component is handled by the caller as a strided re-view (``ops.py``)
so every tile the kernel streams HBM->VMEM is contiguous and 128-aligned.

Grid: ``(n_dyad, B/bB, d_out/bO, d_in/bK)`` — the k axis is innermost so the
accumulator tile is revisited on consecutive steps; block=g, batch and out
tiles are embarrassingly parallel.

Tile selection
--------------
``block_b/block_o/block_k`` default to the autotuned sizes for this
``(shape, dtype, backend)`` key (:func:`repro.perf.autotune.get_tuned_blocks`;
falls back to 256/256/512 when the shape was never tuned).  Tiles are then
*planned* per axis: a dimension whose largest divisor under the requested
block is degenerate (prime or odd dims used to collapse to 1-wide tiles and
a catastrophic grid) is zero-padded up to a tile-unit multiple instead —
zero rows/columns contribute nothing and are sliced off the output.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

# minimal healthy tile per axis: sublane granularity on the batch axis,
# lane granularity on the feature axes (fp32 native tile is (8, 128))
_UNIT_B = 8
_UNIT_FEAT = 128


def _largest_divisor(dim: int, target: int) -> int:
    d = min(dim, target)
    while dim % d:
        d -= 1
    return d


def _plan_axis(dim: int, block: int, unit: int):
    """(tile, padded_dim) for one grid axis.

    Healthy case: the largest divisor of ``dim`` under ``block`` is at least
    one tile unit (or the whole axis) — use it, no padding.  Degenerate case
    (prime/odd dims whose best divisor is tiny): round the axis up to a
    multiple of the unit so a real tile exists; the caller zero-pads."""
    u = max(min(unit, block), 1)
    d = _largest_divisor(dim, block)
    if d >= min(u, dim):
        return d, dim
    padded = -(-dim // u) * u
    return _largest_divisor(padded, block), padded


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Concrete grid tiling for one fused-kernel invocation."""

    bB: int
    bO: int
    bK: int
    padded_b: int
    padded_o: int
    padded_k: int

    @property
    def grid_steps(self) -> int:
        return ((self.padded_b // self.bB) * (self.padded_o // self.bO)
                * (self.padded_k // self.bK))


def plan_tiles(B: int, d_out: int, d_in: int,
               block_b: int, block_o: int, block_k: int) -> TilePlan:
    bB, pb = _plan_axis(B, block_b, _UNIT_B)
    bO, po = _plan_axis(d_out, block_o, _UNIT_FEAT)
    bK, pk = _plan_axis(d_in, block_k, _UNIT_FEAT)
    return TilePlan(bB=bB, bO=bO, bK=bK,
                    padded_b=pb, padded_o=po, padded_k=pk)


def resolve_blocks(op: str, B: int, n: int, d_in: int, d_out: int, dtype,
                   block_b=None, block_o=None, block_k=None):
    """Fill unspecified block sizes from the autotune cache (explicit
    arguments always win).  Runs at trace time — shapes are concrete."""
    if block_b is None or block_o is None or block_k is None:
        from repro.perf.autotune import get_tuned_blocks

        tuned = get_tuned_blocks(op, B, n, d_in, d_out,
                                 str(jnp.dtype(dtype)))
        block_b = tuned["block_b"] if block_b is None else block_b
        block_o = tuned["block_o"] if block_o is None else block_o
        block_k = tuned["block_k"] if block_k is None else block_k
    return block_b, block_o, block_k


def _pad_inputs(plan: TilePlan, x1, x2, w1, w2):
    B, _, d_in = x1.shape
    _, d_out, _ = w1.shape
    db, do, dk = (plan.padded_b - B, plan.padded_o - d_out,
                  plan.padded_k - d_in)
    if db or dk:
        x1 = jnp.pad(x1, ((0, db), (0, 0), (0, dk)))
        x2 = jnp.pad(x2, ((0, db), (0, 0), (0, dk)))
    if do or dk:
        w1 = jnp.pad(w1, ((0, 0), (0, do), (0, dk)))
        w2 = jnp.pad(w2, ((0, 0), (0, do), (0, dk)))
    return x1, x2, w1, w2


def _dyad_kernel(x1_ref, x2_ref, w1_ref, w2_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bB, bK) x (bO, bK)^T -> (bB, bO), accumulated in fp32 on the MXU.
    dn = (((1,), (1,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[:, 0, :] = acc_ref[...].astype(o_ref.dtype)


def _dyad_kernel_two(x1_ref, x2_ref, w1_ref, w2_ref, o1_ref, o2_ref,
                     acc1_ref, acc2_ref, *, nk: int):
    """Two-accumulator body for OT/DT, whose components write to different
    output layouts (BLOCKDIAG contiguous vs BLOCKTRANS strided): the kernel
    emits both per-block products; the caller applies the output re-view."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    dn = (((1,), (1,)), ((), ()))
    acc1_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc2_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o1_ref[:, 0, :] = acc1_ref[...].astype(o1_ref.dtype)
        o2_ref[:, 0, :] = acc2_ref[...].astype(o2_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bK", "interpret")
)
def _dyad_mm_two_impl(x1, x2, w1, w2, *, bB: int, bO: int, bK: int,
                      interpret: bool):
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bO, bK), lambda g, b, o, k: (g, o, k))
    o_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, k: (b, g, o))
    out_sds = jax.ShapeDtypeStruct((B, n, d_out), x1.dtype)

    return pl.pallas_call(
        functools.partial(_dyad_kernel_two, nk=nk),
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[out_sds, out_sds],
        scratch_shapes=[
            pltpu.VMEM((bB, bO), jnp.float32),
            pltpu.VMEM((bB, bO), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, w1, w2)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bK", "interpret")
)
def _dyad_mm_impl(x1, x2, w1, w2, *, bB: int, bO: int, bK: int,
                  interpret: bool):
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bO, bK), lambda g, b, o, k: (g, o, k))
    o_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, k: (b, g, o))

    return pl.pallas_call(
        functools.partial(_dyad_kernel, nk=nk),
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, n, d_out), x1.dtype),
        scratch_shapes=[pltpu.VMEM((bB, bO), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, w1, w2)


def dyad_mm_blocks_two(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
):
    """As :func:`dyad_mm_blocks` but returns (z1, z2) separately (OT/DT)."""
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    bb, bo, bk = resolve_blocks("dyad_mm_blocks_two", B, n, d_in, d_out,
                                x1.dtype, block_b, block_o, block_k)
    plan = plan_tiles(B, d_out, d_in, bb, bo, bk)
    x1, x2, w1, w2 = _pad_inputs(plan, x1, x2, w1, w2)
    z1, z2 = _dyad_mm_two_impl(x1, x2, w1, w2, bB=plan.bB, bO=plan.bO,
                               bK=plan.bK, interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_out:
        z1, z2 = z1[:B, :, :d_out], z2[:B, :, :d_out]
    return z1, z2


def dyad_mm_blocks(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused dual-bmm over per-block views.

    x1, x2: (B, n_dyad, d_in) — block-contiguous / permuted input views.
    w1, w2: (n_dyad, d_out, d_in).
    Returns (B, n_dyad, d_out), dtype of x1.

    Block sizes default to the autotuned tiles for this shape/dtype/backend
    (``repro.perf.autotune``); pass explicit values to override.
    """
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    bb, bo, bk = resolve_blocks("dyad_mm_blocks", B, n, d_in, d_out,
                                x1.dtype, block_b, block_o, block_k)
    plan = plan_tiles(B, d_out, d_in, bb, bo, bk)
    x1, x2, w1, w2 = _pad_inputs(plan, x1, x2, w1, w2)
    out = _dyad_mm_impl(x1, x2, w1, w2, bB=plan.bB, bO=plan.bO, bK=plan.bK,
                        interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_out:
        out = out[:B, :, :d_out]
    return out


# -- backward: dgrad (input cotangent) ----------------------------------------
#
# Grid ``(n, B/bB, d_in/bI, d_out/bK)`` — the reduction now runs over the
# OUTPUT feature axis ``o``, innermost so the dx accumulator tile is revisited
# on consecutive steps.  Tile roles for the autotune ``blocks`` dict keep the
# layer-natural names: ``block_o`` tiles the produced feature axis (d_in here),
# ``block_k`` tiles the contracted one (d_out here).


def _dgrad_kernel(z1_ref, z2_ref, w1_ref, w2_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bB, bK) x (bK, bI) -> (bB, bI): contract z's o axis with w's o axis —
    # the transposed-block product without ever transposing the weight tile.
    dn = (((1,), (0,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(
        z1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc_ref[...] += jax.lax.dot_general(
        z2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[:, 0, :] = acc_ref[...].astype(o_ref.dtype)


def _dgrad_kernel_two(z1_ref, z2_ref, w1_ref, w2_ref, o1_ref, o2_ref,
                      acc1_ref, acc2_ref, *, nk: int):
    """Two-accumulator dgrad for variants whose per-component input views
    live in different layouts (IT/DT: component 2's dx must be un-permuted
    before the add, which is a re-view the caller applies)."""
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    dn = (((1,), (0,)), ((), ()))
    acc1_ref[...] += jax.lax.dot_general(
        z1_ref[:, 0, :], w1_ref[0], dn, preferred_element_type=jnp.float32
    )
    acc2_ref[...] += jax.lax.dot_general(
        z2_ref[:, 0, :], w2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o1_ref[:, 0, :] = acc1_ref[...].astype(o1_ref.dtype)
        o2_ref[:, 0, :] = acc2_ref[...].astype(o2_ref.dtype)


def _dgrad_specs(bB: int, bI: int, bK: int):
    z_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, i, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bK, bI), lambda g, b, i, k: (g, k, i))
    o_spec = pl.BlockSpec((bB, 1, bI), lambda g, b, i, k: (b, g, i))
    return z_spec, w_spec, o_spec


@functools.partial(
    jax.jit, static_argnames=("bB", "bI", "bK", "fused", "interpret")
)
def _dgrad_impl(z1, z2, w1, w2, *, bB: int, bI: int, bK: int, fused: bool,
                interpret: bool):
    B, n, d_out = z1.shape
    _, _, d_in = w1.shape
    nk = d_out // bK
    grid = (n, B // bB, d_in // bI, nk)
    z_spec, w_spec, o_spec = _dgrad_specs(bB, bI, bK)
    out_sds = jax.ShapeDtypeStruct((B, n, d_in), z1.dtype)
    acc = pltpu.VMEM((bB, bI), jnp.float32)

    if fused:
        return pl.pallas_call(
            functools.partial(_dgrad_kernel, nk=nk),
            grid=grid,
            in_specs=[z_spec, z_spec, w_spec, w_spec],
            out_specs=o_spec,
            out_shape=out_sds,
            scratch_shapes=[acc],
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary"),
            ),
            interpret=interpret,
        )(z1, z2, w1, w2)
    return pl.pallas_call(
        functools.partial(_dgrad_kernel_two, nk=nk),
        grid=grid,
        in_specs=[z_spec, z_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[out_sds, out_sds],
        scratch_shapes=[acc, acc],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(z1, z2, w1, w2)


def _dgrad_prepare(op: str, z1, z2, w1, w2, block_b, block_o, block_k):
    B, n, d_out = z1.shape
    _, _, d_in = w1.shape
    bb, bo, bk = resolve_blocks(op, B, n, d_in, d_out, z1.dtype,
                                block_b, block_o, block_k)
    # produced axis = d_in (tiled by block_o), contracted axis = d_out
    plan = plan_tiles(B, d_in, d_out, bb, bo, bk)
    db, di, dk = (plan.padded_b - B, plan.padded_o - d_in,
                  plan.padded_k - d_out)
    if db or dk:
        z1 = jnp.pad(z1, ((0, db), (0, 0), (0, dk)))
        z2 = jnp.pad(z2, ((0, db), (0, 0), (0, dk)))
    if di or dk:
        w1 = jnp.pad(w1, ((0, 0), (0, dk), (0, di)))
        w2 = jnp.pad(w2, ((0, 0), (0, dk), (0, di)))
    return z1, z2, w1, w2, plan


def dyad_mm_dgrad(
    z1: jax.Array,
    z2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused input cotangent: both components accumulate into ONE tile.

    z1, z2: (B, n_dyad, d_out) per-component cotangent views.
    w1, w2: (n_dyad, d_out, d_in).
    Returns dx (B, n_dyad, d_in), dtype of z1.  Valid whenever both dx
    components share a layout (the OT variant's input side).
    """
    B, _, _ = z1.shape
    _, _, d_in = w1.shape
    z1, z2, w1, w2, plan = _dgrad_prepare("dyad_mm_dgrad", z1, z2, w1, w2,
                                          block_b, block_o, block_k)
    dx = _dgrad_impl(z1, z2, w1, w2, bB=plan.bB, bI=plan.bO, bK=plan.bK,
                     fused=True, interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_in:
        dx = dx[:B, :, :d_in]
    return dx


def dyad_mm_dgrad_two(
    z1: jax.Array,
    z2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
):
    """As :func:`dyad_mm_dgrad` but returns (dx1, dx2) separately (IT/DT)."""
    B, _, _ = z1.shape
    _, _, d_in = w1.shape
    z1, z2, w1, w2, plan = _dgrad_prepare("dyad_mm_dgrad_two", z1, z2, w1, w2,
                                          block_b, block_o, block_k)
    dx1, dx2 = _dgrad_impl(z1, z2, w1, w2, bB=plan.bB, bI=plan.bO,
                           bK=plan.bK, fused=False, interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_in:
        dx1, dx2 = dx1[:B, :, :d_in], dx2[:B, :, :d_in]
    return dx1, dx2


# -- backward: wgrad (weight cotangents) --------------------------------------
#
# Grid ``(n, d_out/bO, d_in/bI, B/bB)`` — the reduction runs over the batch
# axis, innermost so both (bO, bI) fp32 accumulator tiles are revisited on
# consecutive steps.  One grid produces BOTH dw1 and dw2: the per-step dots
# share scheduling, and neither partial sum ever round-trips to HBM.


def _wgrad_kernel(x1_ref, x2_ref, z1_ref, z2_ref, o1_ref, o2_ref,
                  acc1_ref, acc2_ref, *, nb: int):
    b = pl.program_id(3)

    @pl.when(b == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    # (bB, bO)^T x (bB, bI) -> (bO, bI): contract the batch axes.
    dn = (((0,), (0,)), ((), ()))
    acc1_ref[...] += jax.lax.dot_general(
        z1_ref[:, 0, :], x1_ref[:, 0, :], dn,
        preferred_element_type=jnp.float32
    )
    acc2_ref[...] += jax.lax.dot_general(
        z2_ref[:, 0, :], x2_ref[:, 0, :], dn,
        preferred_element_type=jnp.float32
    )

    @pl.when(b == nb - 1)
    def _flush():
        o1_ref[0, :, :] = acc1_ref[...].astype(o1_ref.dtype)
        o2_ref[0, :, :] = acc2_ref[...].astype(o2_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bI", "out_dtype", "interpret")
)
def _wgrad_impl(x1, x2, z1, z2, *, bB: int, bO: int, bI: int,
                out_dtype: str, interpret: bool):
    B, n, d_in = x1.shape
    _, _, d_out = z1.shape
    nb = B // bB
    grid = (n, d_out // bO, d_in // bI, nb)

    x_spec = pl.BlockSpec((bB, 1, bI), lambda g, o, i, b: (b, g, i))
    z_spec = pl.BlockSpec((bB, 1, bO), lambda g, o, i, b: (b, g, o))
    o_spec = pl.BlockSpec((1, bO, bI), lambda g, o, i, b: (g, o, i))
    out_sds = jax.ShapeDtypeStruct((n, d_out, d_in), jnp.dtype(out_dtype))
    acc = pltpu.VMEM((bO, bI), jnp.float32)

    return pl.pallas_call(
        functools.partial(_wgrad_kernel, nb=nb),
        grid=grid,
        in_specs=[x_spec, x_spec, z_spec, z_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[out_sds, out_sds],
        scratch_shapes=[acc, acc],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, z1, z2)


def dyad_mm_wgrad(
    x1: jax.Array,
    x2: jax.Array,
    z1: jax.Array,
    z2: jax.Array,
    *,
    out_dtype=None,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
):
    """Fused weight cotangents with fp32 accumulator tiles.

    x1, x2: (B, n_dyad, d_in) per-component input views (the residuals).
    z1, z2: (B, n_dyad, d_out) per-component cotangent views.
    Returns (dw1, dw2): (n_dyad, d_out, d_in) in ``out_dtype`` (defaults to
    x1's dtype) — the cast happens once, from the fp32 accumulator.
    """
    B, n, d_in = x1.shape
    _, _, d_out = z1.shape
    out_dtype = jnp.dtype(out_dtype if out_dtype is not None else x1.dtype)
    bb, bo, bk = resolve_blocks("dyad_mm_wgrad", B, n, d_in, d_out,
                                x1.dtype, block_b, block_o, block_k)
    plan = plan_tiles(B, d_out, d_in, bb, bo, bk)
    db, do, di = (plan.padded_b - B, plan.padded_o - d_out,
                  plan.padded_k - d_in)
    if db or di:
        x1 = jnp.pad(x1, ((0, db), (0, 0), (0, di)))
        x2 = jnp.pad(x2, ((0, db), (0, 0), (0, di)))
    if db or do:
        z1 = jnp.pad(z1, ((0, db), (0, 0), (0, do)))
        z2 = jnp.pad(z2, ((0, db), (0, 0), (0, do)))
    dw1, dw2 = _wgrad_impl(x1, x2, z1, z2, bB=plan.bB, bO=plan.bO,
                           bI=plan.bK, out_dtype=str(out_dtype),
                           interpret=interpret)
    if plan.padded_o != d_out or plan.padded_k != d_in:
        dw1, dw2 = dw1[:, :d_out, :d_in], dw2[:, :d_out, :d_in]
    return dw1, dw2


# -- megakernel: the whole ff module in one grid ------------------------------
#
# ``dyad_ff_fused`` computes, per dyad block g:
#
#     pre[b,g,j] = sum_k x1[b,g,k]*wu1[g,j,k] + x2[b,g,k]*wu2[g,j,k]   (up, IT)
#     h[b,g,j]   = act(pre)                       (SwiGLU: silu(gate_pre)*pre)
#     z*[b,g,o]  = sum_j h[b,g,j]*wd*[g,o,j]                         (down, OT)
#
# Grid ``(n, B/bB, d_out/bO, d_ff_b/bJ, d_in/bK)``: j (the hidden feature
# axis) and k (the up contraction) are sequential-innermost, everything else
# embarrassingly parallel.  Per (g, b, o) the down accumulators (bB, bO) are
# revisited across (j, k); per (g, b, o, j) the hidden accumulator (bB, bJ)
# is revisited across k, activated in-register at ``k == nk-1``, and fed
# straight into the down dot — the hidden NEVER exists in HBM.  Operand
# streaming (x tiles, up/gate/down weight tiles) overlaps the MXU work via
# the standard Pallas double-buffered pipeline over grid steps.
#
# The o axis revisits recompute the hidden once per output tile; for DYAD ff
# dims the per-block down output d_model/n fits one tile (d_out/bO == 1), so
# in practice the hidden is computed exactly once.

# ONE activation table for kernel epilogue and oracle — keep them in sync
from repro.kernels.ref import ACTS as _FF_ACTS  # noqa: E402


@dataclasses.dataclass(frozen=True)
class FFTilePlan:
    """Concrete 4-axis tiling for one megakernel invocation."""

    bB: int
    bO: int
    bJ: int
    bK: int
    padded_b: int
    padded_o: int
    padded_j: int
    padded_k: int

    @property
    def grid_steps(self) -> int:
        return ((self.padded_b // self.bB) * (self.padded_o // self.bO)
                * (self.padded_j // self.bJ) * (self.padded_k // self.bK))


def plan_ff_tiles(B: int, d_out: int, d_ff: int, d_in: int,
                  block_b: int, block_o: int, block_j: int,
                  block_k: int) -> FFTilePlan:
    """Tile all four megakernel axes, padding degenerate dims exactly like
    :func:`plan_tiles`.  Zero-padding stays exact through the activation:
    padded j columns of the DOWN weights are zero, so whatever act(0) is,
    it contributes nothing to the output."""
    bB, pb = _plan_axis(B, block_b, _UNIT_B)
    bO, po = _plan_axis(d_out, block_o, _UNIT_FEAT)
    bJ, pj = _plan_axis(d_ff, block_j, _UNIT_FEAT)
    bK, pk = _plan_axis(d_in, block_k, _UNIT_FEAT)
    return FFTilePlan(bB=bB, bO=bO, bJ=bJ, bK=bK, padded_b=pb, padded_o=po,
                      padded_j=pj, padded_k=pk)


def resolve_ff_blocks(op: str, B: int, n: int, d_in: int, d_out: int,
                      d_ff: int, dtype, block_b=None, block_o=None,
                      block_k=None, block_j=None):
    """Fill unspecified megakernel block sizes from the autotune cache
    (explicit arguments always win).  The ff key carries the hidden width
    (``d_mid``) on top of the usual dims — three weight tensors share one
    VMEM budget, so tiles tuned for a different d_ff must never collide."""
    if (block_b is None or block_o is None or block_k is None
            or block_j is None):
        from repro.perf.autotune import get_tuned_blocks

        tuned = get_tuned_blocks(op, B, n, d_in, d_out,
                                 str(jnp.dtype(dtype)), d_mid=d_ff)
        block_b = tuned["block_b"] if block_b is None else block_b
        block_o = tuned["block_o"] if block_o is None else block_o
        block_k = tuned["block_k"] if block_k is None else block_k
        block_j = tuned["block_j"] if block_j is None else block_j
    return block_b, block_o, block_k, block_j


def _ff_kernel(x1_ref, x2_ref, wu1_ref, wu2_ref, wd1_ref, wd2_ref,
               z1_ref, z2_ref, hacc_ref, acc1_ref, acc2_ref, *,
               nj: int, nk: int, act: str):
    j = pl.program_id(3)
    k = pl.program_id(4)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_down():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    @pl.when(k == 0)
    def _init_up():
        hacc_ref[...] = jnp.zeros_like(hacc_ref)

    # up: (bB, bK) x (bJ, bK)^T -> (bB, bJ), fp32 on the MXU.
    dn = (((1,), (1,)), ((), ()))
    hacc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], wu1_ref[0], dn, preferred_element_type=jnp.float32
    )
    hacc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], wu2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _act_and_down():
        # activation epilogue in-register, then the down dot consumes the
        # hidden tile without it ever leaving VMEM.
        h = _FF_ACTS[act](hacc_ref[...]).astype(x1_ref.dtype)
        acc1_ref[...] += jax.lax.dot_general(
            h, wd1_ref[0], dn, preferred_element_type=jnp.float32
        )
        acc2_ref[...] += jax.lax.dot_general(
            h, wd2_ref[0], dn, preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(j == nj - 1, k == nk - 1))
    def _flush():
        z1_ref[:, 0, :] = acc1_ref[...].astype(z1_ref.dtype)
        z2_ref[:, 0, :] = acc2_ref[...].astype(z2_ref.dtype)


def _ff_kernel_swiglu(x1_ref, x2_ref, wg1_ref, wg2_ref, wu1_ref, wu2_ref,
                      wd1_ref, wd2_ref, z1_ref, z2_ref, gacc_ref, hacc_ref,
                      acc1_ref, acc2_ref, *, nj: int, nk: int):
    """SwiGLU body: TWO up accumulators (gate + up) share the k loop; the
    gated product forms in-register at the k flush."""
    j = pl.program_id(3)
    k = pl.program_id(4)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_down():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    @pl.when(k == 0)
    def _init_up():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)
        hacc_ref[...] = jnp.zeros_like(hacc_ref)

    dn = (((1,), (1,)), ((), ()))
    gacc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], wg1_ref[0], dn, preferred_element_type=jnp.float32
    )
    gacc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], wg2_ref[0], dn, preferred_element_type=jnp.float32
    )
    hacc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], wu1_ref[0], dn, preferred_element_type=jnp.float32
    )
    hacc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], wu2_ref[0], dn, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _act_and_down():
        h = (jax.nn.silu(gacc_ref[...]) * hacc_ref[...]).astype(x1_ref.dtype)
        acc1_ref[...] += jax.lax.dot_general(
            h, wd1_ref[0], dn, preferred_element_type=jnp.float32
        )
        acc2_ref[...] += jax.lax.dot_general(
            h, wd2_ref[0], dn, preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(j == nj - 1, k == nk - 1))
    def _flush():
        z1_ref[:, 0, :] = acc1_ref[...].astype(z1_ref.dtype)
        z2_ref[:, 0, :] = acc2_ref[...].astype(z2_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bJ", "bK", "act", "interpret")
)
def _dyad_ff_impl(x1, x2, weights, *, bB: int, bO: int, bJ: int, bK: int,
                  act: str, interpret: bool):
    B, n, d_in = x1.shape
    gated = act == "swiglu"
    wd1 = weights[-2]
    d_ffb = wd1.shape[2]
    d_out = wd1.shape[1]
    nj = d_ffb // bJ
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nj, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, j, k: (b, g, k))
    wu_spec = pl.BlockSpec((1, bJ, bK), lambda g, b, o, j, k: (g, j, k))
    wd_spec = pl.BlockSpec((1, bO, bJ), lambda g, b, o, j, k: (g, o, j))
    z_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, j, k: (b, g, o))
    out_sds = jax.ShapeDtypeStruct((B, n, d_out), x1.dtype)

    n_up = 4 if gated else 2
    in_specs = [x_spec, x_spec] + [wu_spec] * n_up + [wd_spec, wd_spec]
    scratch = ([pltpu.VMEM((bB, bJ), jnp.float32)] * (2 if gated else 1)
               + [pltpu.VMEM((bB, bO), jnp.float32)] * 2)
    body = (functools.partial(_ff_kernel_swiglu, nj=nj, nk=nk) if gated
            else functools.partial(_ff_kernel, nj=nj, nk=nk, act=act))

    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=[z_spec, z_spec],
        out_shape=[out_sds, out_sds],
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, *weights)


def dyad_ff_fused(
    x1: jax.Array,
    x2: jax.Array,
    wu1: jax.Array,
    wu2: jax.Array,
    wd1: jax.Array,
    wd2: jax.Array,
    *,
    wg1: jax.Array = None,
    wg2: jax.Array = None,
    act: str = "gelu",
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    block_j: int = None,
    interpret: bool = False,
):
    """The whole DYAD ff module in one Pallas grid; hidden stays in VMEM.

    x1, x2:   (B, n_dyad, d_in) block-contiguous / permuted input views (IT).
    wu1, wu2: (n_dyad, d_ff_b, d_in) up weights; wg1/wg2 likewise for the
              SwiGLU gate (required iff ``act == "swiglu"``).
    wd1, wd2: (n_dyad, d_out, d_ff_b) down weights (OT: consumed from the
              block layout, so both components read the SAME hidden tile).
    Returns (z1, z2): (B, n_dyad, d_out) down-projection components — the
    caller applies the OT output re-view + add (``ref.combine``).

    Tiles default to the autotuned sizes under the ``dyad_ff_fused`` /
    ``dyad_ff_fused_swiglu`` op key (which carries d_ff); explicit
    ``block_*`` arguments override.
    """
    gated = act == "swiglu"
    if gated != (wg1 is not None) or gated != (wg2 is not None):
        raise ValueError("wg1/wg2 must be passed exactly when act='swiglu'")
    if act not in _FF_ACTS and not gated:
        raise ValueError(f"unsupported megakernel activation {act!r}")
    B, n, d_in = x1.shape
    _, d_ffb, _ = wu1.shape
    _, d_out, _ = wd1.shape
    op = "dyad_ff_fused_swiglu" if gated else "dyad_ff_fused"
    bb, bo, bk, bj = resolve_ff_blocks(op, B, n, d_in, d_out, d_ffb,
                                       x1.dtype, block_b, block_o, block_k,
                                       block_j)
    plan = plan_ff_tiles(B, d_out, d_ffb, d_in, bb, bo, bj, bk)
    db, do = plan.padded_b - B, plan.padded_o - d_out
    dj, dk = plan.padded_j - d_ffb, plan.padded_k - d_in
    if db or dk:
        x1 = jnp.pad(x1, ((0, db), (0, 0), (0, dk)))
        x2 = jnp.pad(x2, ((0, db), (0, 0), (0, dk)))
    ups = (wg1, wg2, wu1, wu2) if gated else (wu1, wu2)
    if dj or dk:
        ups = tuple(jnp.pad(w, ((0, 0), (0, dj), (0, dk))) for w in ups)
    downs = (wd1, wd2)
    if do or dj:
        downs = tuple(jnp.pad(w, ((0, 0), (0, do), (0, dj))) for w in downs)
    z1, z2 = _dyad_ff_impl(x1, x2, ups + downs, bB=plan.bB, bO=plan.bO,
                           bJ=plan.bJ, bK=plan.bK, act=act,
                           interpret=interpret)
    if db or do:
        z1, z2 = z1[:B, :, :d_out], z2[:B, :, :d_out]
    return z1, z2


# -- quantized bodies: int8/fp8 weight streams, dequant at the VMEM load ------
#
# Weight tiles stream in their QUANTIZED dtype (1 byte/elem — the HBM
# stream the forward is bound on shrinks 2-4x); the per-(block, out_row)
# fp32 scales (``repro.quant.quantize_dyad_weight``) ride as tiny sidecar
# operands.  Because each scale is constant along the contracted axis, the
# dequant is a single epilogue multiply on the fp32 partial product:
#
#     acc += (x_tile @ q_tile^T) * s_tile        (exact: s is k-invariant)
#
# — the integer payload is cast to the activation dtype in-register (int8
# magnitudes <= 127 and every fp8 value are exactly representable in bf16
# and fp32, so the cast is lossless) and never exists dequantized in HBM.
# Activation/hidden dataflow, grids, and tile planning are identical to
# the unquantized bodies; the ops autotune under ``*_w8`` keys whose dtype
# field carries the weight payload dtype.


def _dyad_kernel_q(x1_ref, x2_ref, w1_ref, w2_ref, s1_ref, s2_ref, o_ref,
                   acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dn = (((1,), (1,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0].astype(x1_ref.dtype), dn,
        preferred_element_type=jnp.float32) * s1_ref[0]
    acc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0].astype(x2_ref.dtype), dn,
        preferred_element_type=jnp.float32) * s2_ref[0]

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[:, 0, :] = acc_ref[...].astype(o_ref.dtype)


def _dyad_kernel_two_q(x1_ref, x2_ref, w1_ref, w2_ref, s1_ref, s2_ref,
                       o1_ref, o2_ref, acc1_ref, acc2_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    dn = (((1,), (1,)), ((), ()))
    acc1_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], w1_ref[0].astype(x1_ref.dtype), dn,
        preferred_element_type=jnp.float32) * s1_ref[0]
    acc2_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], w2_ref[0].astype(x2_ref.dtype), dn,
        preferred_element_type=jnp.float32) * s2_ref[0]

    @pl.when(k == nk - 1)
    def _flush():
        o1_ref[:, 0, :] = acc1_ref[...].astype(o1_ref.dtype)
        o2_ref[:, 0, :] = acc2_ref[...].astype(o2_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bK", "fused", "interpret")
)
def _dyad_mm_q_impl(x1, x2, w1, w2, s1, s2, *, bB: int, bO: int, bK: int,
                    fused: bool, interpret: bool):
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, k: (b, g, k))
    w_spec = pl.BlockSpec((1, bO, bK), lambda g, b, o, k: (g, o, k))
    s_spec = pl.BlockSpec((1, bO), lambda g, b, o, k: (g, o))
    o_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, k: (b, g, o))
    out_sds = jax.ShapeDtypeStruct((B, n, d_out), x1.dtype)
    acc = pltpu.VMEM((bB, bO), jnp.float32)
    in_specs = [x_spec, x_spec, w_spec, w_spec, s_spec, s_spec]
    params = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"))

    if fused:
        return pl.pallas_call(
            functools.partial(_dyad_kernel_q, nk=nk),
            grid=grid, in_specs=in_specs, out_specs=o_spec,
            out_shape=out_sds, scratch_shapes=[acc],
            compiler_params=params, interpret=interpret,
        )(x1, x2, w1, w2, s1, s2)
    return pl.pallas_call(
        functools.partial(_dyad_kernel_two_q, nk=nk),
        grid=grid, in_specs=in_specs, out_specs=[o_spec, o_spec],
        out_shape=[out_sds, out_sds], scratch_shapes=[acc, acc],
        compiler_params=params, interpret=interpret,
    )(x1, x2, w1, w2, s1, s2)


def _prep_quant_mm(op, x1, x2, w1, w2, s1, s2, block_b, block_o, block_k):
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    # the op key's dtype field carries the WEIGHT payload dtype (int8/fp8):
    # quantized tiles stream fewer bytes, so their tuned tiles must never
    # collide with the unquantized entries for the same shape.
    bb, bo, bk = resolve_blocks(op, B, n, d_in, d_out, w1.dtype,
                                block_b, block_o, block_k)
    plan = plan_tiles(B, d_out, d_in, bb, bo, bk)
    x1, x2, w1, w2 = _pad_inputs(plan, x1, x2, w1, w2)
    do = plan.padded_o - d_out
    if do:
        # padded out rows hold zero weights; their scale value is moot
        s1 = jnp.pad(s1, ((0, 0), (0, do)))
        s2 = jnp.pad(s2, ((0, 0), (0, do)))
    return x1, x2, w1, w2, s1, s2, plan


def dyad_mm_blocks_q(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    s1: jax.Array,
    s2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
) -> jax.Array:
    """:func:`dyad_mm_blocks` with quantized weight streams.

    w1, w2: (n_dyad, d_out, d_in) int8/fp8 payloads; s1, s2: (n_dyad,
    d_out) fp32 per-(block, out_row) scales.  Output in x1's dtype."""
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    x1, x2, w1, w2, s1, s2, plan = _prep_quant_mm(
        "dyad_mm_blocks_w8", x1, x2, w1, w2, s1, s2,
        block_b, block_o, block_k)
    out = _dyad_mm_q_impl(x1, x2, w1, w2, s1, s2, bB=plan.bB, bO=plan.bO,
                          bK=plan.bK, fused=True, interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_out:
        out = out[:B, :, :d_out]
    return out


def dyad_mm_blocks_two_q(
    x1: jax.Array,
    x2: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    s1: jax.Array,
    s2: jax.Array,
    *,
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    interpret: bool = False,
):
    """As :func:`dyad_mm_blocks_q` but returns (z1, z2) separately (OT/DT)."""
    B, n, d_in = x1.shape
    _, d_out, _ = w1.shape
    x1, x2, w1, w2, s1, s2, plan = _prep_quant_mm(
        "dyad_mm_blocks_two_w8", x1, x2, w1, w2, s1, s2,
        block_b, block_o, block_k)
    z1, z2 = _dyad_mm_q_impl(x1, x2, w1, w2, s1, s2, bB=plan.bB, bO=plan.bO,
                             bK=plan.bK, fused=False, interpret=interpret)
    if plan.padded_b != B or plan.padded_o != d_out:
        z1, z2 = z1[:B, :, :d_out], z2[:B, :, :d_out]
    return z1, z2


def _ff_kernel_q(x1_ref, x2_ref, wu1_ref, wu2_ref, wd1_ref, wd2_ref,
                 su1_ref, su2_ref, sd1_ref, sd2_ref, z1_ref, z2_ref,
                 hacc_ref, acc1_ref, acc2_ref, *, nj: int, nk: int,
                 act: str):
    j = pl.program_id(3)
    k = pl.program_id(4)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_down():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    @pl.when(k == 0)
    def _init_up():
        hacc_ref[...] = jnp.zeros_like(hacc_ref)

    dn = (((1,), (1,)), ((), ()))
    hacc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], wu1_ref[0].astype(x1_ref.dtype), dn,
        preferred_element_type=jnp.float32) * su1_ref[0]
    hacc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], wu2_ref[0].astype(x2_ref.dtype), dn,
        preferred_element_type=jnp.float32) * su2_ref[0]

    @pl.when(k == nk - 1)
    def _act_and_down():
        h = _FF_ACTS[act](hacc_ref[...]).astype(x1_ref.dtype)
        acc1_ref[...] += jax.lax.dot_general(
            h, wd1_ref[0].astype(h.dtype), dn,
            preferred_element_type=jnp.float32) * sd1_ref[0]
        acc2_ref[...] += jax.lax.dot_general(
            h, wd2_ref[0].astype(h.dtype), dn,
            preferred_element_type=jnp.float32) * sd2_ref[0]

    @pl.when(jnp.logical_and(j == nj - 1, k == nk - 1))
    def _flush():
        z1_ref[:, 0, :] = acc1_ref[...].astype(z1_ref.dtype)
        z2_ref[:, 0, :] = acc2_ref[...].astype(z2_ref.dtype)


def _ff_kernel_swiglu_q(x1_ref, x2_ref, wg1_ref, wg2_ref, wu1_ref, wu2_ref,
                        wd1_ref, wd2_ref, sg1_ref, sg2_ref, su1_ref,
                        su2_ref, sd1_ref, sd2_ref, z1_ref, z2_ref,
                        gacc_ref, hacc_ref, acc1_ref, acc2_ref, *,
                        nj: int, nk: int):
    j = pl.program_id(3)
    k = pl.program_id(4)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_down():
        acc1_ref[...] = jnp.zeros_like(acc1_ref)
        acc2_ref[...] = jnp.zeros_like(acc2_ref)

    @pl.when(k == 0)
    def _init_up():
        gacc_ref[...] = jnp.zeros_like(gacc_ref)
        hacc_ref[...] = jnp.zeros_like(hacc_ref)

    dn = (((1,), (1,)), ((), ()))
    gacc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], wg1_ref[0].astype(x1_ref.dtype), dn,
        preferred_element_type=jnp.float32) * sg1_ref[0]
    gacc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], wg2_ref[0].astype(x2_ref.dtype), dn,
        preferred_element_type=jnp.float32) * sg2_ref[0]
    hacc_ref[...] += jax.lax.dot_general(
        x1_ref[:, 0, :], wu1_ref[0].astype(x1_ref.dtype), dn,
        preferred_element_type=jnp.float32) * su1_ref[0]
    hacc_ref[...] += jax.lax.dot_general(
        x2_ref[:, 0, :], wu2_ref[0].astype(x2_ref.dtype), dn,
        preferred_element_type=jnp.float32) * su2_ref[0]

    @pl.when(k == nk - 1)
    def _act_and_down():
        h = (jax.nn.silu(gacc_ref[...]) * hacc_ref[...]).astype(x1_ref.dtype)
        acc1_ref[...] += jax.lax.dot_general(
            h, wd1_ref[0].astype(h.dtype), dn,
            preferred_element_type=jnp.float32) * sd1_ref[0]
        acc2_ref[...] += jax.lax.dot_general(
            h, wd2_ref[0].astype(h.dtype), dn,
            preferred_element_type=jnp.float32) * sd2_ref[0]

    @pl.when(jnp.logical_and(j == nj - 1, k == nk - 1))
    def _flush():
        z1_ref[:, 0, :] = acc1_ref[...].astype(z1_ref.dtype)
        z2_ref[:, 0, :] = acc2_ref[...].astype(z2_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bB", "bO", "bJ", "bK", "act", "interpret")
)
def _dyad_ff_q_impl(x1, x2, weights, scales, *, bB: int, bO: int, bJ: int,
                    bK: int, act: str, interpret: bool):
    B, n, d_in = x1.shape
    gated = act == "swiglu"
    wd1 = weights[-2]
    d_ffb = wd1.shape[2]
    d_out = wd1.shape[1]
    nj = d_ffb // bJ
    nk = d_in // bK
    grid = (n, B // bB, d_out // bO, nj, nk)

    x_spec = pl.BlockSpec((bB, 1, bK), lambda g, b, o, j, k: (b, g, k))
    wu_spec = pl.BlockSpec((1, bJ, bK), lambda g, b, o, j, k: (g, j, k))
    wd_spec = pl.BlockSpec((1, bO, bJ), lambda g, b, o, j, k: (g, o, j))
    su_spec = pl.BlockSpec((1, bJ), lambda g, b, o, j, k: (g, j))
    sd_spec = pl.BlockSpec((1, bO), lambda g, b, o, j, k: (g, o))
    z_spec = pl.BlockSpec((bB, 1, bO), lambda g, b, o, j, k: (b, g, o))
    out_sds = jax.ShapeDtypeStruct((B, n, d_out), x1.dtype)

    n_up = 4 if gated else 2
    in_specs = ([x_spec, x_spec] + [wu_spec] * n_up + [wd_spec, wd_spec]
                + [su_spec] * n_up + [sd_spec, sd_spec])
    scratch = ([pltpu.VMEM((bB, bJ), jnp.float32)] * (2 if gated else 1)
               + [pltpu.VMEM((bB, bO), jnp.float32)] * 2)
    body = (functools.partial(_ff_kernel_swiglu_q, nj=nj, nk=nk) if gated
            else functools.partial(_ff_kernel_q, nj=nj, nk=nk, act=act))

    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=[z_spec, z_spec],
        out_shape=[out_sds, out_sds],
        scratch_shapes=scratch,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x1, x2, *weights, *scales)


def dyad_ff_fused_q(
    x1: jax.Array,
    x2: jax.Array,
    wu1: jax.Array,
    wu2: jax.Array,
    wd1: jax.Array,
    wd2: jax.Array,
    su1: jax.Array,
    su2: jax.Array,
    sd1: jax.Array,
    sd2: jax.Array,
    *,
    wg1: jax.Array = None,
    wg2: jax.Array = None,
    sg1: jax.Array = None,
    sg2: jax.Array = None,
    act: str = "gelu",
    block_b: int = None,
    block_o: int = None,
    block_k: int = None,
    block_j: int = None,
    interpret: bool = False,
):
    """:func:`dyad_ff_fused` with quantized weight streams.

    wu*/wg*: (n, d_ff_b, d_in) int8/fp8 payloads with su*/sg* (n, d_ff_b)
    fp32 scales; wd*: (n, d_out, d_ff_b) payloads with sd* (n, d_out)
    scales.  Activation/hidden dataflow is IDENTICAL to the unquantized
    megakernel — only the weight streams shrink.  Tiles resolve under the
    ``dyad_ff_fused[_swiglu]_w8`` op keys (dtype field = payload dtype)."""
    gated = act == "swiglu"
    if gated != (wg1 is not None) or gated != (wg2 is not None):
        raise ValueError("wg1/wg2 must be passed exactly when act='swiglu'")
    if gated and (sg1 is None or sg2 is None):
        raise ValueError("sg1/sg2 must be passed when act='swiglu'")
    if act not in _FF_ACTS and not gated:
        raise ValueError(f"unsupported megakernel activation {act!r}")
    B, n, d_in = x1.shape
    _, d_ffb, _ = wu1.shape
    _, d_out, _ = wd1.shape
    op = "dyad_ff_fused_swiglu_w8" if gated else "dyad_ff_fused_w8"
    bb, bo, bk, bj = resolve_ff_blocks(op, B, n, d_in, d_out, d_ffb,
                                       wu1.dtype, block_b, block_o, block_k,
                                       block_j)
    plan = plan_ff_tiles(B, d_out, d_ffb, d_in, bb, bo, bj, bk)
    db, do = plan.padded_b - B, plan.padded_o - d_out
    dj, dk = plan.padded_j - d_ffb, plan.padded_k - d_in
    if db or dk:
        x1 = jnp.pad(x1, ((0, db), (0, 0), (0, dk)))
        x2 = jnp.pad(x2, ((0, db), (0, 0), (0, dk)))
    ups = (wg1, wg2, wu1, wu2) if gated else (wu1, wu2)
    s_ups = (sg1, sg2, su1, su2) if gated else (su1, su2)
    if dj or dk:
        ups = tuple(jnp.pad(w, ((0, 0), (0, dj), (0, dk))) for w in ups)
    if dj:
        s_ups = tuple(jnp.pad(s, ((0, 0), (0, dj))) for s in s_ups)
    downs = (wd1, wd2)
    s_downs = (sd1, sd2)
    if do or dj:
        downs = tuple(jnp.pad(w, ((0, 0), (0, do), (0, dj))) for w in downs)
    if do:
        s_downs = tuple(jnp.pad(s, ((0, 0), (0, do))) for s in s_downs)
    z1, z2 = _dyad_ff_q_impl(x1, x2, ups + downs, s_ups + s_downs,
                             bB=plan.bB, bO=plan.bO, bJ=plan.bJ, bK=plan.bK,
                             act=act, interpret=interpret)
    if db or do:
        z1, z2 = z1[:B, :, :d_out], z2[:B, :, :d_out]
    return z1, z2
