"""jit'd differentiable wrappers around the fused DYAD Pallas kernels.

Two public ops: ``dyad_mm`` (one DYAD linear) and ``dyad_ff`` (the whole
ff module — up [+ SwiGLU gate], activation, down — through the one-grid
megakernel; see the ff section at the bottom of this file).

``dyad_mm(x, w1, w2, variant=...)``:

* forward — builds the two strided block views (pure re-views, folded into the
  operands' layouts by XLA) and calls the fused forward kernel;
* backward — custom VJP routed through the fused backward dataflow
  (``use_kernel_bwd=True``, the default): on TPU the Pallas kernels
  (:func:`repro.kernels.dyad_mm.dyad_mm_dgrad` / ``dyad_mm_dgrad_two`` for
  the input cotangent, ``dyad_mm_wgrad`` for both weight cotangents, all
  with fp32 accumulator tiles); on other backends a compiled XLA lowering
  of the SAME dataflow (:func:`_bwd_direct`) — it contracts directly in the
  permuted layouts so none of the strided views (``x2``, ``z2bar``) or the
  ``dx2`` un-view are ever materialized, and accumulates in fp32 exactly
  like the kernel.  The Pallas interpreter is NOT on the non-TPU hot path:
  its grid loop re-carries every operand per step, which is right for
  bit-level validation (tests pass ``interpret=True`` explicitly) and wrong
  for throughput.  Set ``REPRO_KERNEL_BWD=pallas`` to force the Pallas
  route off-TPU (validation/timing of the true kernels), or
  ``REPRO_KERNEL_BWD=xla`` to force the compiled fallback on TPU.

The pre-kernel einsum backward survives as the oracle
(:func:`repro.kernels.ref.dyad_mm_bwd_ref`), selectable with
``use_kernel_bwd=False`` — gradient-equivalence tests pin every route
against it to fp32 tolerance.

Variant dataflow in the backward (the permutations are bijective, so the
cotangent "un-views" are exact inverses of the forward views):

* ``ot`` — both dx components land block-contiguous, so ONE fused
  accumulator computes ``dx = z1bar.w1 + z2bar.w2`` in-kernel;
* ``it``/``dt`` — component 2's dx lives in the permuted layout, so the
  kernel emits both products and the zero-copy un-view + add happens here
  (the XLA fallback instead writes component 2 directly into the permuted
  layout: ``bgo,goi->big``).

On non-TPU backends the forward kernel runs in ``interpret=True`` mode,
which executes the kernel body in Python for bit-correct validation on CPU.

Tile sizes: the kernel calls below pass no explicit ``block_*``, so the
wrappers resolve tiles from the autotune cache per (op, shape, dtype,
backend) — see :mod:`repro.perf.autotune`.  Run the tuner
(``launch/train.py --autotune``, ``launch/serve.py --autotune``, or
``ensure_tuned_for_model``) BEFORE the first trace of a jitted caller: the
resolved tiles are baked into the trace, including the ``value_and_grad``
trace of a train step.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro import faults, obs
from repro.kernels import flash_attn, ref
from repro.kernels.dyad_mm import (dyad_ff_fused, dyad_ff_fused_q,
                                   dyad_mm_blocks, dyad_mm_blocks_q,
                                   dyad_mm_blocks_two, dyad_mm_blocks_two_q,
                                   dyad_mm_dgrad, dyad_mm_dgrad_two,
                                   dyad_mm_wgrad)


@functools.lru_cache(maxsize=None)
def _backend_is_tpu() -> bool:
    """The backend never changes within a process — resolve the (relatively
    expensive) jax backend query once instead of on every trace of every
    call site.  Env-var escape hatches stay dynamic (plain dict lookups):
    tests and benchmarks flip them between traces."""
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    """Single source of truth for the kernel execution mode — the autotuner
    and benchmarks reuse this so tuned tiles are measured the same way the
    serving and training hot paths run them."""
    interpret = not _backend_is_tpu()
    obs.route_event("pallas_exec", "interpret" if interpret else "compiled")
    return interpret


def _use_pallas_bwd() -> bool:
    """Route the backward through the Pallas kernels?  TPU: yes (that is
    the hot path they exist for).  Elsewhere: only when forced with
    ``REPRO_KERNEL_BWD=pallas`` — the default is the compiled XLA lowering
    of the same dataflow (:func:`_bwd_direct`).  Checked at trace time."""
    forced = os.environ.get("REPRO_KERNEL_BWD", "").lower()
    if forced == "pallas":
        use = True
    elif forced == "xla":
        use = False
    else:
        use = _backend_is_tpu()
    # trace-time decision, recorded so a silent fall-off from the Pallas
    # kernels shows up in obs.route_counts() / the exported timeline
    obs.route_event("kernel_bwd", "pallas" if use else "xla",
                    forced=bool(forced))
    return use


def _ff_route() -> str:
    """Which forward route does ``dyad_ff`` take?  ``fused`` (the default:
    the one-grid megakernel) or ``split`` (up [+ gate] kernel dispatch, XLA
    activation, down kernel dispatch — the pre-megakernel dataflow, with
    the hidden round-tripping through HBM).  ``REPRO_KERNEL_FF=fused|split``
    forces either; checked at trace time."""
    forced = os.environ.get("REPRO_KERNEL_FF", "").lower()
    route = forced if forced in ("fused", "split") else "fused"
    obs.route_event("ff", route, forced=route == forced)
    return route


def attn_route() -> str:
    """Which route does attention take when the config opts into flash
    (``cfg.flash_attn``)?  ``flash`` (the Pallas kernels) on TPU, ``xla``
    (the existing chunked/naive einsum paths) elsewhere — off-TPU the
    kernels would run the interpreter, which is validation-grade, not a
    hot path.  ``REPRO_KERNEL_ATTN=flash|xla`` forces either; checked at
    trace time."""
    forced = os.environ.get("REPRO_KERNEL_ATTN", "").lower()
    route = (forced if forced in ("flash", "xla")
             else "flash" if _backend_is_tpu() else "xla")
    obs.route_event("attn", route, forced=route == forced)
    return route


def _bwd_direct(x2d, w1, w2, g2d, variant: str):
    """Compiled non-TPU lowering of the fused kernel backward.

    Mirrors dgrad/wgrad kernel semantics — fp32 accumulation, component
    fusion — but expressed as direct-layout contractions: the BLOCKTRANS
    operand is read through the free ``(B, d, n)`` reshape (``big`` /
    ``bog`` subscripts) and component 2's dx is PRODUCED in the permuted
    layout, so unlike the einsum oracle no ``x2`` / ``z2bar`` / un-view
    copy is ever materialized.
    """
    B, f_in = x2d.shape
    n, d_out, d_in = w1.shape
    f32 = jnp.float32
    x1 = x2d.reshape(B, n, d_in)
    xr = x2d.reshape(B, d_in, n)          # x2[b,g,i] == xr[b,i,g]
    z1 = g2d.reshape(B, n, d_out)
    gr = g2d.reshape(B, d_out, n)         # z2bar[b,g,o] == gr[b,o,g]

    dw1 = jnp.einsum("bgi,bgo->goi", x1, z1, preferred_element_type=f32)
    dx1 = jnp.einsum("bgo,goi->bgi", z1, w1, preferred_element_type=f32)
    if variant == "it":
        dw2 = jnp.einsum("big,bgo->goi", xr, z1, preferred_element_type=f32)
        dx2r = jnp.einsum("bgo,goi->big", z1, w2, preferred_element_type=f32)
        dx = dx1.reshape(B, f_in) + dx2r.reshape(B, f_in)
    elif variant == "ot":
        dw2 = jnp.einsum("bgi,bog->goi", x1, gr, preferred_element_type=f32)
        dx2 = jnp.einsum("bog,goi->bgi", gr, w2, preferred_element_type=f32)
        dx = (dx1 + dx2).reshape(B, f_in)
    else:  # "dt"
        dw2 = jnp.einsum("big,bog->goi", xr, gr, preferred_element_type=f32)
        dx2r = jnp.einsum("bog,goi->big", gr, w2, preferred_element_type=f32)
        dx = dx1.reshape(B, f_in) + dx2r.reshape(B, f_in)
    return dx, dw1, dw2


@functools.lru_cache(maxsize=None)
def _make_dyad_mm(variant: str, use_kernel_bwd: bool = True):
    @jax.custom_vjp
    def op(x, w1, w2):
        n, d_out, _ = w1.shape
        lead = x.shape[:-1]
        x2d = x.reshape(-1, x.shape[-1])
        x1, x2 = ref.block_views(x2d, n, variant)
        w1c, w2c = w1.astype(x.dtype), w2.astype(x.dtype)
        if variant == "it":
            # IT: both components share the block-contiguous OUTPUT layout,
            # so one fused accumulator suffices (the "super--CAT" path).
            z = dyad_mm_blocks(x1, x2, w1c, w2c, interpret=_interpret())
            y = z.reshape(-1, n * d_out)
        else:
            # OT/DT: component 2 writes a strided output layout; the kernel
            # emits both products and the re-view happens here (zero-copy).
            z1, z2 = dyad_mm_blocks_two(x1, x2, w1c, w2c, interpret=_interpret())
            y = ref.combine(z1, z2, variant)
        return y.reshape(*lead, n * d_out)

    def fwd(x, w1, w2):
        return op(x, w1, w2), (x, w1, w2)

    def bwd_einsum(resids, g):
        x, w1, w2 = resids
        return ref.dyad_mm_bwd_ref(x, w1, w2, g, variant=variant)

    def bwd_kernel(resids, g):
        x, w1, w2 = resids
        n = w1.shape[0]
        lead = x.shape[:-1]
        f_in = x.shape[-1]
        x2d = x.reshape(-1, f_in)
        g2d = g.reshape(-1, g.shape[-1]).astype(x.dtype)
        w1c, w2c = w1.astype(x.dtype), w2.astype(x.dtype)

        if not _use_pallas_bwd():
            dx, dw1, dw2 = _bwd_direct(x2d, w1c, w2c, g2d, variant)
            return (dx.reshape(*lead, f_in).astype(x.dtype),
                    dw1.astype(w1.dtype), dw2.astype(w2.dtype))

        x1, x2 = ref.block_views(x2d, n, variant)
        z1bar, z2bar = ref.split_cotangent(g2d, n, variant)
        interpret = _interpret()
        if variant == "ot":
            # both dx components are block-contiguous: fused single-tile
            # accumulate in-kernel (the add the einsum oracle does in jnp).
            dx3 = dyad_mm_dgrad(z1bar, z2bar, w1c, w2c, interpret=interpret)
            dx = dx3.reshape(-1, f_in)
        else:
            dx1, dx2 = dyad_mm_dgrad_two(z1bar, z2bar, w1c, w2c,
                                         interpret=interpret)
            dx = ref.unview(dx1, dx2, variant)
        dw1, dw2 = dyad_mm_wgrad(x1, x2, z1bar, z2bar, out_dtype=w1.dtype,
                                 interpret=interpret)
        return (dx.reshape(*lead, f_in).astype(x.dtype), dw1,
                dw2.astype(w2.dtype))

    op.defvjp(fwd, bwd_kernel if use_kernel_bwd else bwd_einsum)
    return op


def dyad_mm(x, w1, w2, *, variant: str = "it", use_kernel_bwd: bool = True):
    """Fused DYAD matmul: (..., f_in) -> (..., f_out), no bias.

    ``use_kernel_bwd=False`` swaps the backward to the pure-einsum oracle
    (``ref.dyad_mm_bwd_ref``) — the escape hatch for debugging gradients or
    backends where the fused backward underperforms.
    """
    return _make_dyad_mm(variant, use_kernel_bwd)(x, w1, w2)


# -- the ff megakernel op -----------------------------------------------------
#
# ``dyad_ff`` is the whole transformer ff module as one differentiable op:
# up = IT (strided view on the replicated input), activation, down = OT
# (strided view on the reduced output) — the mixed-variant dataflow of
# ``layers.mlp._fused_dyad_mlp``, but executed by ONE Pallas grid
# (:func:`repro.kernels.dyad_mm.dyad_ff_fused`) so the ``(..., n, d_ff/n)``
# hidden never exists in HBM.
#
# Backward: the fused VJP REMATERIALIZES the hidden (the forward deliberately
# never stored it) with one up-kernel dispatch, then composes the existing
# fused backward kernels: ``dyad_mm_dgrad`` for the down input cotangent (OT:
# both dx components share the block layout — one fused accumulator),
# ``dyad_mm_wgrad`` for both down weight grads, the activation VJP
# elementwise in XLA, then ``dyad_mm_wgrad`` + ``dyad_mm_dgrad_two`` for the
# up (and gate) side.  Off-TPU the same dataflow lowers to compiled XLA
# einsums in direct layouts (:func:`_ff_bwd_direct`), exactly like
# :func:`_bwd_direct` for the single matmul; ``REPRO_KERNEL_BWD`` applies.


def _ff_act_fwd(act, g_pre, u_pre):
    """(h, residuals) for the activation epilogue in BLOCK layout."""
    if act == "swiglu":
        return jax.vjp(lambda g, u: jax.nn.silu(g) * u, g_pre, u_pre)
    return jax.vjp(ref.ACTS[act], u_pre)


def _ff_forward(x, wg, wu, wd, act):
    """Shared forward: returns flat (..., f_out).  wg is None when ungated."""
    n, _, _ = wu[0].shape
    d_out = wd[0].shape[1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    dt = x.dtype
    wu1, wu2 = (w.astype(dt) for w in wu)
    wd1, wd2 = (w.astype(dt) for w in wd)
    x1, x2 = ref.block_views(x2d, n, "it")
    interpret = _interpret()
    route = _ff_route()
    if route == "fused":
        wg1, wg2 = (w.astype(dt) for w in wg) if wg is not None else (None,
                                                                      None)
        z1, z2 = dyad_ff_fused(x1, x2, wu1, wu2, wd1, wd2, wg1=wg1, wg2=wg2,
                               act=act, interpret=interpret)
    else:
        u = dyad_mm_blocks(x1, x2, wu1, wu2, interpret=interpret)
        if wg is not None:
            g_pre = dyad_mm_blocks(x1, x2, wg[0].astype(dt),
                                   wg[1].astype(dt), interpret=interpret)
            h = jax.nn.silu(g_pre) * u
        else:
            h = ref.ACTS[act](u)
        z1, z2 = dyad_mm_blocks_two(h, h, wd1, wd2, interpret=interpret)
    y = ref.combine(z1, z2, "ot")
    # chaos hook: ``kernel_nan`` with route=ff_fused / ff_split simulates a
    # numerically-broken kernel on the active route (trace-time; no-op
    # unless a fault schedule is armed)
    y = faults.poison(y, "kernel_nan", route=f"ff_{route}")
    return y.reshape(*lead, n * d_out)


def _ff_bwd_kernel(x, wg, wu, wd, g, act):
    """Pallas-kernel backward: rematerialized hidden + fused dgrad/wgrad."""
    n = wu[0].shape[0]
    lead = x.shape[:-1]
    f_in = x.shape[-1]
    dt = x.dtype
    x2d = x.reshape(-1, f_in)
    g2d = g.reshape(-1, g.shape[-1]).astype(dt)
    x1, x2 = ref.block_views(x2d, n, "it")
    wu1, wu2 = (w.astype(dt) for w in wu)
    wd1, wd2 = (w.astype(dt) for w in wd)
    interpret = _interpret()

    u_pre = dyad_mm_blocks(x1, x2, wu1, wu2, interpret=interpret)
    if wg is not None:
        g_pre = dyad_mm_blocks(x1, x2, wg[0].astype(dt), wg[1].astype(dt),
                               interpret=interpret)
        h, act_vjp = _ff_act_fwd(act, g_pre, u_pre)
    else:
        h, act_vjp = _ff_act_fwd(act, None, u_pre)

    z1bar, z2bar = ref.split_cotangent(g2d, n, "ot")
    dwd1, dwd2 = dyad_mm_wgrad(h, h, z1bar, z2bar, out_dtype=wd[0].dtype,
                               interpret=interpret)
    # OT down: both dh components share the block layout -> ONE fused tile.
    dh = dyad_mm_dgrad(z1bar, z2bar, wd1, wd2, interpret=interpret)

    if wg is not None:
        dg_pre, du_pre = act_vjp(dh)
        dg_pre = dg_pre.astype(dt)
    else:
        (du_pre,) = act_vjp(dh)
    du_pre = du_pre.astype(dt)

    dwu1, dwu2 = dyad_mm_wgrad(x1, x2, du_pre, du_pre,
                               out_dtype=wu[0].dtype, interpret=interpret)
    dx1, dx2 = dyad_mm_dgrad_two(du_pre, du_pre, wu1, wu2,
                                 interpret=interpret)
    dx = ref.unview(dx1, dx2, "it")
    dgs = ()
    if wg is not None:
        dwg1, dwg2 = dyad_mm_wgrad(x1, x2, dg_pre, dg_pre,
                                   out_dtype=wg[0].dtype, interpret=interpret)
        dxg1, dxg2 = dyad_mm_dgrad_two(dg_pre, dg_pre, wg[0].astype(dt),
                                       wg[1].astype(dt), interpret=interpret)
        dx = dx + ref.unview(dxg1, dxg2, "it")
        dgs = (dwg1, dwg2.astype(wg[1].dtype))
    return (dx.reshape(*lead, f_in).astype(x.dtype), *dgs,
            dwu1, dwu2.astype(wu[1].dtype),
            dwd1, dwd2.astype(wd[1].dtype))


def _ff_bwd_direct(x, wg, wu, wd, g, act):
    """Compiled non-TPU lowering of the megakernel backward: direct-layout
    contractions (the BLOCKTRANS operands are read through the free
    ``(B, d, n)`` reshapes), fp32 accumulation, rematerialized hidden —
    no strided view, hidden store, or dx un-view is ever materialized."""
    f32 = jnp.float32
    n, d_ffb, d_in = wu[0].shape
    d_out = wd[0].shape[1]
    lead = x.shape[:-1]
    f_in = x.shape[-1]
    dt = x.dtype
    x2d = x.reshape(-1, f_in)
    B = x2d.shape[0]
    g2d = g.reshape(-1, g.shape[-1]).astype(dt)
    x1 = x2d.reshape(B, n, d_in)
    xr = x2d.reshape(B, d_in, n)              # x2[b,g,k] == xr[b,k,g]
    z1 = g2d.reshape(B, n, d_out)
    gr = g2d.reshape(B, d_out, n)             # z2bar[b,g,o] == gr[b,o,g]
    wu1, wu2 = (w.astype(dt) for w in wu)
    wd1, wd2 = (w.astype(dt) for w in wd)

    def up(w1, w2):
        pre = (jnp.einsum("bgk,gjk->bgj", x1, w1,
                          preferred_element_type=f32)
               + jnp.einsum("bkg,gjk->bgj", xr, w2,
                            preferred_element_type=f32))
        return pre.astype(dt)

    u_pre = up(wu1, wu2)
    if wg is not None:
        wg1, wg2 = (w.astype(dt) for w in wg)
        h, act_vjp = _ff_act_fwd(act, up(wg1, wg2), u_pre)
    else:
        h, act_vjp = _ff_act_fwd(act, None, u_pre)

    dwd1 = jnp.einsum("bgj,bgo->goj", h, z1, preferred_element_type=f32)
    dwd2 = jnp.einsum("bgj,bog->goj", h, gr, preferred_element_type=f32)
    dh = (jnp.einsum("bgo,goj->bgj", z1, wd1, preferred_element_type=f32)
          + jnp.einsum("bog,goj->bgj", gr, wd2,
                       preferred_element_type=f32)).astype(dt)

    if wg is not None:
        dg_pre, du_pre = act_vjp(dh)
    else:
        (du_pre,) = act_vjp(dh)

    def down_grads(du, w1, w2):
        dw1 = jnp.einsum("bgk,bgj->gjk", x1, du, preferred_element_type=f32)
        dw2 = jnp.einsum("bkg,bgj->gjk", xr, du, preferred_element_type=f32)
        # component 2's dx is PRODUCED in the permuted layout (bkg): the
        # un-view is a free reshape, never a copy.
        dx = (jnp.einsum("bgj,gjk->bgk", du, w1,
                         preferred_element_type=f32).reshape(B, f_in)
              + jnp.einsum("bgj,gjk->bkg", du, w2,
                           preferred_element_type=f32).reshape(B, f_in))
        return dw1, dw2, dx

    dwu1, dwu2, dx = down_grads(du_pre, wu1, wu2)
    dgs = ()
    if wg is not None:
        dwg1, dwg2, dxg = down_grads(dg_pre, wg1, wg2)
        dx = dx + dxg
        dgs = (dwg1.astype(wg[0].dtype), dwg2.astype(wg[1].dtype))
    return (dx.reshape(*lead, f_in).astype(x.dtype), *dgs,
            dwu1.astype(wu[0].dtype), dwu2.astype(wu[1].dtype),
            dwd1.astype(wd[0].dtype), dwd2.astype(wd[1].dtype))


@functools.lru_cache(maxsize=None)
def _make_dyad_ff(act: str, use_kernel_bwd: bool = True):
    gated = act == "swiglu"

    def bwd(resids, g):
        if gated:
            x, wg1, wg2, wu1, wu2, wd1, wd2 = resids
            wg = (wg1, wg2)
        else:
            x, wu1, wu2, wd1, wd2 = resids
            wg = None
        if not use_kernel_bwd:
            # pure-einsum oracle: autodiff of the reference forward.
            args = (x, wu1, wu2, wd1, wd2) + ((wg1, wg2) if gated else ())
            if gated:
                f = lambda x, wu1, wu2, wd1, wd2, wg1, wg2: ref.dyad_ff_ref(
                    x, wu1, wu2, wd1, wd2, wg1, wg2, act=act)
            else:
                f = lambda x, wu1, wu2, wd1, wd2: ref.dyad_ff_ref(
                    x, wu1, wu2, wd1, wd2, act=act)
            _, vjp = jax.vjp(f, *args)
            grads = vjp(g)
            if gated:
                dx, dwu1, dwu2, dwd1, dwd2, dwg1, dwg2 = grads
                return (dx, dwg1, dwg2, dwu1, dwu2, dwd1, dwd2)
            return grads
        route = _ff_bwd_kernel if _use_pallas_bwd() else _ff_bwd_direct
        return route(x, wg, (wu1, wu2), (wd1, wd2), g, act)

    if gated:
        @jax.custom_vjp
        def op(x, wg1, wg2, wu1, wu2, wd1, wd2):
            return _ff_forward(x, (wg1, wg2), (wu1, wu2), (wd1, wd2), act)

        def fwd(x, wg1, wg2, wu1, wu2, wd1, wd2):
            return (op(x, wg1, wg2, wu1, wu2, wd1, wd2),
                    (x, wg1, wg2, wu1, wu2, wd1, wd2))
    else:
        @jax.custom_vjp
        def op(x, wu1, wu2, wd1, wd2):
            return _ff_forward(x, None, (wu1, wu2), (wd1, wd2), act)

        def fwd(x, wu1, wu2, wd1, wd2):
            return op(x, wu1, wu2, wd1, wd2), (x, wu1, wu2, wd1, wd2)

    op.defvjp(fwd, bwd)
    return op


def dyad_ff(params, x, *, act: str = "gelu", use_kernel_bwd: bool = True):
    """The whole DYAD ff module as one differentiable op (bias-free).

    ``params`` is the ``layers.mlp`` param dict: ``{"up", "down"}`` (+
    ``"gate"`` for ``act="swiglu"``), each holding DYAD ``w1``/``w2``.
    Forward runs the one-grid Pallas megakernel (``REPRO_KERNEL_FF=split``
    falls back to the two/three-dispatch kernel chain); backward composes
    the fused dgrad/wgrad kernels on TPU and compiled direct-layout XLA
    elsewhere.  ``use_kernel_bwd=False`` swaps the backward to autodiff of
    the einsum oracle (``ref.dyad_ff_ref``).
    """
    op = _make_dyad_ff(act, use_kernel_bwd)
    if act == "swiglu":
        return op(x, params["gate"]["w1"], params["gate"]["w2"],
                  params["up"]["w1"], params["up"]["w2"],
                  params["down"]["w1"], params["down"]["w2"])
    return op(x, params["up"]["w1"], params["up"]["w2"],
              params["down"]["w1"], params["down"]["w2"])


# -- quantized forward routes -------------------------------------------------
#
# Serving-only: the quantized weights are a frozen snapshot, so these are
# plain forward functions OUTSIDE the custom-VJP machinery — dispatch sites
# (``layers.mlp``, ``core.factory``) route here only when not differentiating.
# They stream the int8/fp8 SIDECAR leaves (``w*_q``/``w*_s`` from
# ``repro.quant.quantize_params``) and never touch the retained fp32
# originals — in particular there is no ``w.astype(x.dtype)`` cast: the
# payload reaches the kernel in its quantized dtype and is dequantized at
# the VMEM load (scale into the fp32 accumulator epilogue).


def dyad_mm_quant(x, w1q, w2q, s1, s2, *, variant: str = "it"):
    """Forward-only :func:`dyad_mm` streaming quantized weight sidecars.

    w1q/w2q: (n, d_out, d_in) int8/fp8 payloads; s1/s2: (n, d_out) fp32
    per-(block, out_row) scales."""
    n, d_out, _ = w1q.shape
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    x1, x2 = ref.block_views(x2d, n, variant)
    interpret = _interpret()
    if variant == "it":
        z = dyad_mm_blocks_q(x1, x2, w1q, w2q, s1, s2, interpret=interpret)
        y = z.reshape(-1, n * d_out)
    else:
        z1, z2 = dyad_mm_blocks_two_q(x1, x2, w1q, w2q, s1, s2,
                                      interpret=interpret)
        y = ref.combine(z1, z2, variant)
    return y.reshape(*lead, n * d_out)


def dyad_ff_quant(params, x, *, act: str = "gelu"):
    """Forward-only :func:`dyad_ff` streaming quantized weight sidecars.

    ``params`` is the ``layers.mlp`` param dict AFTER
    ``repro.quant.quantize_params`` (every projection carries
    ``w1_q``/``w1_s``/``w2_q``/``w2_s``).  The fused route runs the
    quantized megakernel (:func:`repro.kernels.dyad_mm.dyad_ff_fused_q`);
    ``REPRO_KERNEL_FF=split`` composes the quantized mm kernels instead
    (up [+ gate], XLA activation, down) — the same escape hatch surface as
    the unquantized op."""
    up, down = params["up"], params["down"]
    gated = act == "swiglu"
    n = up["w1_q"].shape[0]
    d_out = down["w1_q"].shape[1]
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    x1, x2 = ref.block_views(x2d, n, "it")
    interpret = _interpret()
    if _ff_route() == "fused":
        gate_kw = {}
        if gated:
            g = params["gate"]
            gate_kw = dict(wg1=g["w1_q"], wg2=g["w2_q"],
                           sg1=g["w1_s"], sg2=g["w2_s"])
        z1, z2 = dyad_ff_fused_q(
            x1, x2, up["w1_q"], up["w2_q"], down["w1_q"], down["w2_q"],
            up["w1_s"], up["w2_s"], down["w1_s"], down["w2_s"],
            act=act, interpret=interpret, **gate_kw)
    else:
        u = dyad_mm_blocks_q(x1, x2, up["w1_q"], up["w2_q"],
                             up["w1_s"], up["w2_s"], interpret=interpret)
        if gated:
            g = params["gate"]
            g_pre = dyad_mm_blocks_q(x1, x2, g["w1_q"], g["w2_q"],
                                     g["w1_s"], g["w2_s"],
                                     interpret=interpret)
            h = jax.nn.silu(g_pre) * u
        else:
            h = ref.ACTS[act](u)
        z1, z2 = dyad_mm_blocks_two_q(h, h, down["w1_q"], down["w2_q"],
                                      down["w1_s"], down["w2_s"],
                                      interpret=interpret)
    y = ref.combine(z1, z2, "ot")
    return y.reshape(*lead, n * d_out)


# -- the flash-attention ops --------------------------------------------------
#
# ``flash_attention`` wraps the fused prefill kernel
# (:func:`repro.kernels.flash_attn.flash_prefill`) in a custom VJP:
#
# * forward — one Pallas grid, online softmax in VMEM (the fwd primal saves
#   nothing; under differentiation the fwd rule additionally emits the
#   per-row log-sum-exp residual);
# * backward — on TPU the flash backward kernels
#   (:func:`flash_attn.flash_prefill_grads`: dq on the forward grid, dk/dv
#   on the transposed grid, probabilities RECOMPUTED per tile from the
#   saved lse); off-TPU a compiled XLA lowering of the same recompute
#   dataflow (:func:`_flash_bwd_direct`).  ``REPRO_KERNEL_BWD`` forces
#   either route, exactly like the DYAD ops.
#
# The einsum VJP survives as the oracle: ``use_kernel_bwd=False`` swaps the
# backward to autodiff of :func:`repro.kernels.ref.sdpa_ref`.
#
# Positions are ``q_off + arange(S)`` / ``k_off + arange(T)`` (scalars or
# per-batch vectors) — the contiguous-position contract every dispatch site
# in ``layers.attention`` satisfies (no-cache forward: k_off = 0;
# fresh-stream cache prefill: q_off = k_off = idx).


def _attn_positions(q_off, k_off, B: int, S: int, T: int):
    qo = jnp.asarray(q_off, jnp.int32).reshape(-1)[:, None]    # (B?|1, 1)
    ko = jnp.asarray(k_off, jnp.int32).reshape(-1)[:, None]
    return qo + jnp.arange(S), ko + jnp.arange(T)              # (B?|1, S/T)


def _flash_bwd_direct(q, k, v, o, lse, do, q_off, k_off, causal, window):
    """Compiled non-TPU lowering of the flash backward: the same
    recomputed-probability dataflow (p from the saved lse, fp32
    accumulation) as direct einsum contractions.  Materializes the score
    tensor — fine for the compiled fallback, wrong for VMEM-bound TPU."""
    f32 = jnp.float32
    B, S, K, G, h = q.shape
    T = k.shape[1]
    scale = 1.0 / float(h) ** 0.5
    s = jnp.einsum("bskgh,btkh->bskgt", q, k,
                   preferred_element_type=f32) * scale
    qp, kp = _attn_positions(q_off, k_off, B, S, T)
    m = jnp.ones((max(qp.shape[0], kp.shape[0]), S, T), bool)
    if causal:
        m = m & (kp[:, None, :] <= qp[..., :, None])
    if window is not None:
        m = m & (qp[..., :, None] - kp[:, None, :] < window)
    m = m[:, :, None, None, :]
    # lse rides in the kernel layout (B, K, S*G) -> (B, S, K, G)
    lse = lse.reshape(B, K, S, G).transpose(0, 2, 1, 3)
    p = jnp.where(m, jnp.exp(s - lse[..., None]), 0.0)
    do32 = do.astype(f32)
    delta = jnp.sum(do32 * o.astype(f32), axis=-1)             # (B,S,K,G)
    dv = jnp.einsum("bskgt,bskgh->btkh", p, do32,
                    preferred_element_type=f32)
    dp = jnp.einsum("bskgh,btkh->bskgt", do32, v,
                    preferred_element_type=f32)
    ds = p * (dp - delta[..., None]) * scale
    dq = jnp.einsum("bskgt,btkh->bskgh", ds, k,
                    preferred_element_type=f32)
    dk = jnp.einsum("bskgt,bskgh->btkh", ds, q,
                    preferred_element_type=f32)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _int_zero(x):
    """float0 cotangent for the integer offset inputs of the flash op."""
    import numpy as np
    return np.zeros(jnp.shape(x), dtype=jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _make_flash_attention(causal: bool, window, use_kernel_bwd: bool):
    @jax.custom_vjp
    def op(q, k, v, q_off, k_off):
        out, _ = flash_attn.flash_prefill(
            q, k, v, q_off, k_off, causal=causal, window=window,
            interpret=_interpret())
        return out

    def fwd(q, k, v, q_off, k_off):
        out, lse = flash_attn.flash_prefill(
            q, k, v, q_off, k_off, causal=causal, window=window,
            save_lse=True, interpret=_interpret())
        return out, (q, k, v, out, lse, q_off, k_off)

    def bwd(resids, g):
        q, k, v, o, lse, q_off, k_off = resids
        if not use_kernel_bwd:
            # einsum-VJP oracle: autodiff of the reference forward
            qp, kp = _attn_positions(q_off, k_off, q.shape[0], q.shape[1],
                                     k.shape[1])
            qp = qp if qp.shape[0] > 1 else qp[0]
            kp = kp if kp.shape[0] > 1 else kp[0]
            _, vjp = jax.vjp(
                lambda q, k, v: ref.sdpa_ref(q, k, v, qp, kp, causal=causal,
                                             window=window), q, k, v)
            dq, dk, dv = vjp(g.astype(q.dtype))
        elif _use_pallas_bwd():
            dq, dk, dv = flash_attn.flash_prefill_grads(
                q, k, v, o, lse, g.astype(q.dtype), q_off, k_off,
                causal=causal, window=window, interpret=_interpret())
        else:
            dq, dk, dv = _flash_bwd_direct(q, k, v, o, lse,
                                           g.astype(q.dtype), q_off, k_off,
                                           causal, window)
        return dq, dk, dv, _int_zero(q_off), _int_zero(k_off)

    op.defvjp(fwd, bwd)
    return op


def flash_attention(q, k, v, q_off=0, k_off=0, *, causal: bool = True,
                    window=None, use_kernel_bwd: bool = True):
    """Fused flash attention: (B,S,K,G,h) x (B,T,K,h) -> (B,S,K,G,h).

    Query/key positions are ``q_off + arange(S)`` / ``k_off + arange(T)``
    (scalar or per-batch (B,) offsets).  ``use_kernel_bwd=False`` swaps
    the backward to autodiff of the einsum oracle (``ref.sdpa_ref``)."""
    q_off = jnp.asarray(q_off, jnp.int32)
    k_off = jnp.asarray(k_off, jnp.int32)
    return _make_flash_attention(causal, window, use_kernel_bwd)(
        q, k, v, q_off, k_off)


def flash_decode(q, k, v, idx, *, window=None):
    """One-token ring-cache decode attention (inference only, no VJP).

    q: (B,1,K,G,h) or (B,K,G,h); k/v: the (B,L,K,h) post-write cache;
    ``idx``: the current token's write index (scalar or per-slot (B,)).
    See :func:`repro.kernels.flash_attn.flash_decode`."""
    return flash_attn.flash_decode(q, k, v, idx, window=window,
                                   interpret=_interpret())


def flash_decode_paged(q, pages_k, pages_v, block_table, idx, *,
                       l_real=None, window=None, scales_k=None,
                       scales_v=None):
    """One-token paged-cache decode attention (inference only, no VJP).

    q: (B,1,K,G,h) or (B,K,G,h); pages_k/pages_v: the (n_pages,P,K,h)
    shared page pool; ``block_table``: (B, n_blocks) int32 page ids (dead
    entries must point at the reserved scratch page 0); ``idx``: per-slot
    (B,) write index of the current token.  ``l_real`` bounds the logical
    length when the block-table capacity overshoots it.  ``scales_k``/
    ``scales_v`` (``(n_pages, P, K)`` fp32, together) mark the pools as
    int8-quantized; the kernel dequantizes tiles in-VMEM after the
    block-table gather.
    See :func:`repro.kernels.flash_attn.flash_decode_paged`."""
    return flash_attn.flash_decode_paged(
        q, pages_k, pages_v, block_table, idx, l_real=l_real, window=window,
        scales_k=scales_k, scales_v=scales_v, interpret=_interpret())
