"""jit'd differentiable wrapper around the fused DYAD Pallas kernel.

``dyad_mm(x, w1, w2, variant=...)`` is the public op:

* forward — builds the two strided block views (pure re-views, folded into the
  operands' layouts by XLA) and calls the fused kernel;
* backward — custom VJP in pure jnp einsums (the transposed products are plain
  bmms that XLA maps straight onto the MXU; the permutations are bijective so
  the cotangent "un-views" are exact inverses of the forward views).

On non-TPU backends the kernel runs in ``interpret=True`` mode, which executes
the kernel body in Python for bit-correct validation on CPU.

Tile sizes: the calls below pass no explicit ``block_*``, so the kernel
wrappers resolve tiles from the autotune cache per (shape, dtype, backend)
— see :mod:`repro.perf.autotune`.  Run the tuner (or construct the serve
engine with ``autotune=True``) BEFORE the first trace of a jitted caller:
the resolved tiles are baked into the trace.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dyad_mm import dyad_mm_blocks, dyad_mm_blocks_two


def _interpret() -> bool:
    """Single source of truth for the kernel execution mode — the autotuner
    and benchmarks reuse this so tuned tiles are measured the same way the
    serving hot path runs them."""
    return jax.default_backend() != "tpu"


def _split_cotangent(g, n: int, variant: str):
    """g: (..., f_out) -> (z1bar, z2bar): (..., n, d_out) per-component."""
    d_out = g.shape[-1] // n
    lead = g.shape[:-1]
    z1bar = g.reshape(*lead, n, d_out)
    if variant in ("ot", "dt"):
        z2bar = jnp.swapaxes(g.reshape(*lead, d_out, n), -1, -2)
    else:
        z2bar = z1bar
    return z1bar, z2bar


def _unview(dx1, dx2, variant: str):
    """Fold per-view input cotangents back onto the flat feature axis."""
    lead = dx1.shape[:-2]
    f_in = dx1.shape[-2] * dx1.shape[-1]
    out = dx1.reshape(*lead, f_in)
    if variant in ("it", "dt"):
        out = out + jnp.swapaxes(dx2, -1, -2).reshape(*lead, f_in)
    else:
        out = out + dx2.reshape(*lead, f_in)
    return out


@functools.lru_cache(maxsize=None)
def _make_dyad_mm(variant: str):
    @jax.custom_vjp
    def op(x, w1, w2):
        n, d_out, _ = w1.shape
        lead = x.shape[:-1]
        x2d = x.reshape(-1, x.shape[-1])
        x1, x2 = ref.block_views(x2d, n, variant)
        w1c, w2c = w1.astype(x.dtype), w2.astype(x.dtype)
        if variant == "it":
            # IT: both components share the block-contiguous OUTPUT layout,
            # so one fused accumulator suffices (the "super--CAT" path).
            z = dyad_mm_blocks(x1, x2, w1c, w2c, interpret=_interpret())
            y = z.reshape(-1, n * d_out)
        else:
            # OT/DT: component 2 writes a strided output layout; the kernel
            # emits both products and the re-view happens here (zero-copy).
            z1, z2 = dyad_mm_blocks_two(x1, x2, w1c, w2c, interpret=_interpret())
            y = ref.combine(z1, z2, variant)
        return y.reshape(*lead, n * d_out)

    def fwd(x, w1, w2):
        return op(x, w1, w2), (x, w1, w2)

    def bwd(resids, g):
        x, w1, w2 = resids
        n = w1.shape[0]
        x1, x2 = ref.block_views(x, n, variant)
        z1bar, z2bar = _split_cotangent(g, n, variant)
        dw1 = jnp.einsum("...gi,...go->goi", x1, z1bar).astype(w1.dtype)
        dw2 = jnp.einsum("...gi,...go->goi", x2, z2bar).astype(w2.dtype)
        dx1 = jnp.einsum("...go,goi->...gi", z1bar, w1.astype(g.dtype))
        dx2 = jnp.einsum("...go,goi->...gi", z2bar, w2.astype(g.dtype))
        dx = _unview(dx1, dx2, variant).astype(x.dtype)
        return dx, dw1, dw2

    op.defvjp(fwd, bwd)
    return op


def dyad_mm(x, w1, w2, *, variant: str = "it"):
    """Fused DYAD matmul: (..., f_in) -> (..., f_out), no bias."""
    return _make_dyad_mm(variant)(x, w1, w2)
