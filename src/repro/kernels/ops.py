"""jit'd differentiable wrapper around the fused DYAD Pallas kernels.

``dyad_mm(x, w1, w2, variant=...)`` is the public op:

* forward — builds the two strided block views (pure re-views, folded into the
  operands' layouts by XLA) and calls the fused forward kernel;
* backward — custom VJP routed through the fused backward dataflow
  (``use_kernel_bwd=True``, the default): on TPU the Pallas kernels
  (:func:`repro.kernels.dyad_mm.dyad_mm_dgrad` / ``dyad_mm_dgrad_two`` for
  the input cotangent, ``dyad_mm_wgrad`` for both weight cotangents, all
  with fp32 accumulator tiles); on other backends a compiled XLA lowering
  of the SAME dataflow (:func:`_bwd_direct`) — it contracts directly in the
  permuted layouts so none of the strided views (``x2``, ``z2bar``) or the
  ``dx2`` un-view are ever materialized, and accumulates in fp32 exactly
  like the kernel.  The Pallas interpreter is NOT on the non-TPU hot path:
  its grid loop re-carries every operand per step, which is right for
  bit-level validation (tests pass ``interpret=True`` explicitly) and wrong
  for throughput.  Set ``REPRO_KERNEL_BWD=pallas`` to force the Pallas
  route off-TPU (validation/timing of the true kernels), or
  ``REPRO_KERNEL_BWD=xla`` to force the compiled fallback on TPU.

The pre-kernel einsum backward survives as the oracle
(:func:`repro.kernels.ref.dyad_mm_bwd_ref`), selectable with
``use_kernel_bwd=False`` — gradient-equivalence tests pin every route
against it to fp32 tolerance.

Variant dataflow in the backward (the permutations are bijective, so the
cotangent "un-views" are exact inverses of the forward views):

* ``ot`` — both dx components land block-contiguous, so ONE fused
  accumulator computes ``dx = z1bar.w1 + z2bar.w2`` in-kernel;
* ``it``/``dt`` — component 2's dx lives in the permuted layout, so the
  kernel emits both products and the zero-copy un-view + add happens here
  (the XLA fallback instead writes component 2 directly into the permuted
  layout: ``bgo,goi->big``).

On non-TPU backends the forward kernel runs in ``interpret=True`` mode,
which executes the kernel body in Python for bit-correct validation on CPU.

Tile sizes: the kernel calls below pass no explicit ``block_*``, so the
wrappers resolve tiles from the autotune cache per (op, shape, dtype,
backend) — see :mod:`repro.perf.autotune`.  Run the tuner
(``launch/train.py --autotune``, ``launch/serve.py --autotune``, or
``ensure_tuned_for_model``) BEFORE the first trace of a jitted caller: the
resolved tiles are baked into the trace, including the ``value_and_grad``
trace of a train step.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dyad_mm import (dyad_mm_blocks, dyad_mm_blocks_two,
                                   dyad_mm_dgrad, dyad_mm_dgrad_two,
                                   dyad_mm_wgrad)


def _interpret() -> bool:
    """Single source of truth for the kernel execution mode — the autotuner
    and benchmarks reuse this so tuned tiles are measured the same way the
    serving and training hot paths run them."""
    return jax.default_backend() != "tpu"


def _use_pallas_bwd() -> bool:
    """Route the backward through the Pallas kernels?  TPU: yes (that is
    the hot path they exist for).  Elsewhere: only when forced with
    ``REPRO_KERNEL_BWD=pallas`` — the default is the compiled XLA lowering
    of the same dataflow (:func:`_bwd_direct`).  Checked at trace time."""
    forced = os.environ.get("REPRO_KERNEL_BWD", "").lower()
    if forced == "pallas":
        return True
    if forced == "xla":
        return False
    return jax.default_backend() == "tpu"


def _bwd_direct(x2d, w1, w2, g2d, variant: str):
    """Compiled non-TPU lowering of the fused kernel backward.

    Mirrors dgrad/wgrad kernel semantics — fp32 accumulation, component
    fusion — but expressed as direct-layout contractions: the BLOCKTRANS
    operand is read through the free ``(B, d, n)`` reshape (``big`` /
    ``bog`` subscripts) and component 2's dx is PRODUCED in the permuted
    layout, so unlike the einsum oracle no ``x2`` / ``z2bar`` / un-view
    copy is ever materialized.
    """
    B, f_in = x2d.shape
    n, d_out, d_in = w1.shape
    f32 = jnp.float32
    x1 = x2d.reshape(B, n, d_in)
    xr = x2d.reshape(B, d_in, n)          # x2[b,g,i] == xr[b,i,g]
    z1 = g2d.reshape(B, n, d_out)
    gr = g2d.reshape(B, d_out, n)         # z2bar[b,g,o] == gr[b,o,g]

    dw1 = jnp.einsum("bgi,bgo->goi", x1, z1, preferred_element_type=f32)
    dx1 = jnp.einsum("bgo,goi->bgi", z1, w1, preferred_element_type=f32)
    if variant == "it":
        dw2 = jnp.einsum("big,bgo->goi", xr, z1, preferred_element_type=f32)
        dx2r = jnp.einsum("bgo,goi->big", z1, w2, preferred_element_type=f32)
        dx = dx1.reshape(B, f_in) + dx2r.reshape(B, f_in)
    elif variant == "ot":
        dw2 = jnp.einsum("bgi,bog->goi", x1, gr, preferred_element_type=f32)
        dx2 = jnp.einsum("bog,goi->bgi", gr, w2, preferred_element_type=f32)
        dx = (dx1 + dx2).reshape(B, f_in)
    else:  # "dt"
        dw2 = jnp.einsum("big,bog->goi", xr, gr, preferred_element_type=f32)
        dx2r = jnp.einsum("bog,goi->big", gr, w2, preferred_element_type=f32)
        dx = dx1.reshape(B, f_in) + dx2r.reshape(B, f_in)
    return dx, dw1, dw2


@functools.lru_cache(maxsize=None)
def _make_dyad_mm(variant: str, use_kernel_bwd: bool = True):
    @jax.custom_vjp
    def op(x, w1, w2):
        n, d_out, _ = w1.shape
        lead = x.shape[:-1]
        x2d = x.reshape(-1, x.shape[-1])
        x1, x2 = ref.block_views(x2d, n, variant)
        w1c, w2c = w1.astype(x.dtype), w2.astype(x.dtype)
        if variant == "it":
            # IT: both components share the block-contiguous OUTPUT layout,
            # so one fused accumulator suffices (the "super--CAT" path).
            z = dyad_mm_blocks(x1, x2, w1c, w2c, interpret=_interpret())
            y = z.reshape(-1, n * d_out)
        else:
            # OT/DT: component 2 writes a strided output layout; the kernel
            # emits both products and the re-view happens here (zero-copy).
            z1, z2 = dyad_mm_blocks_two(x1, x2, w1c, w2c, interpret=_interpret())
            y = ref.combine(z1, z2, variant)
        return y.reshape(*lead, n * d_out)

    def fwd(x, w1, w2):
        return op(x, w1, w2), (x, w1, w2)

    def bwd_einsum(resids, g):
        x, w1, w2 = resids
        return ref.dyad_mm_bwd_ref(x, w1, w2, g, variant=variant)

    def bwd_kernel(resids, g):
        x, w1, w2 = resids
        n = w1.shape[0]
        lead = x.shape[:-1]
        f_in = x.shape[-1]
        x2d = x.reshape(-1, f_in)
        g2d = g.reshape(-1, g.shape[-1]).astype(x.dtype)
        w1c, w2c = w1.astype(x.dtype), w2.astype(x.dtype)

        if not _use_pallas_bwd():
            dx, dw1, dw2 = _bwd_direct(x2d, w1c, w2c, g2d, variant)
            return (dx.reshape(*lead, f_in).astype(x.dtype),
                    dw1.astype(w1.dtype), dw2.astype(w2.dtype))

        x1, x2 = ref.block_views(x2d, n, variant)
        z1bar, z2bar = ref.split_cotangent(g2d, n, variant)
        interpret = _interpret()
        if variant == "ot":
            # both dx components are block-contiguous: fused single-tile
            # accumulate in-kernel (the add the einsum oracle does in jnp).
            dx3 = dyad_mm_dgrad(z1bar, z2bar, w1c, w2c, interpret=interpret)
            dx = dx3.reshape(-1, f_in)
        else:
            dx1, dx2 = dyad_mm_dgrad_two(z1bar, z2bar, w1c, w2c,
                                         interpret=interpret)
            dx = ref.unview(dx1, dx2, variant)
        dw1, dw2 = dyad_mm_wgrad(x1, x2, z1bar, z2bar, out_dtype=w1.dtype,
                                 interpret=interpret)
        return (dx.reshape(*lead, f_in).astype(x.dtype), dw1,
                dw2.astype(w2.dtype))

    op.defvjp(fwd, bwd_kernel if use_kernel_bwd else bwd_einsum)
    return op


def dyad_mm(x, w1, w2, *, variant: str = "it", use_kernel_bwd: bool = True):
    """Fused DYAD matmul: (..., f_in) -> (..., f_out), no bias.

    ``use_kernel_bwd=False`` swaps the backward to the pure-einsum oracle
    (``ref.dyad_mm_bwd_ref``) — the escape hatch for debugging gradients or
    backends where the fused backward underperforms.
    """
    return _make_dyad_mm(variant, use_kernel_bwd)(x, w1, w2)
