"""Tensor-parallel dispatch of the fused Pallas kernels via shard_map.

Under an activation-sharding context the fused ops used to be abandoned:
``layers/mlp.py`` and ``layers/attention.py`` fell back to the einsum
routes the moment a mesh was active, so the large TP configs never touched
a kernel.  This module runs the EXISTING per-device grids on per-shard
operands instead — DYAD's block tensors ``(n, d_out, d_in)`` shard along
the feature-per-block axes with zero resharding, exactly the layout
``sharding/rules.py`` already places:

* ``dyad_ff_tp`` — the ff megakernel per-shard.  Up/gate weights split
  their ``d_out`` axis over ``model`` (the ``constrain_ff_hidden`` hidden
  layout), the down weight splits ``d_in``; each device runs the one-grid
  megakernel on its ``d_ff/tp`` hidden slice and holds a PARTIAL flat
  output (the OT combine is linear, so summing flat outputs is exact).
  The cross-shard reduce is a ``psum_scatter`` over the feature dim when
  it divides — a ring reduce-scatter whose first hops overlap the last
  grid steps, with the re-gather left to GSPMD at the next consumer —
  falling back to a plain ``psum`` otherwise.

* ``flash_attention_tp`` / ``flash_decode_tp`` / ``flash_decode_paged_tp``
  — the flash kernels per-shard over the KV-head axis.  GQA groups ride
  with their KV head, so each device keeps the full scalar-prefetched
  index / block-table machinery and needs NO body collective: heads are
  independent.

Every wrapper invokes its shard_map under ``autotune.tp_shards(tp)`` so
the trace-time block lookups inside the body resolve the per-shard
``|tp{N}`` cache keys, not the global-shape entries.

``REPRO_KERNEL_TP=off`` is the escape hatch back to the einsum fallbacks
(the pre-TP behavior); non-divisible shards fall back per-site and are
counted by the ``ff_tp``/``attn_tp`` route events in :mod:`repro.obs`.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops
from repro.launch.mesh import compat_shard_map
from repro.perf import autotune


def tp_enabled() -> bool:
    """``REPRO_KERNEL_TP=off`` keeps the einsum fallbacks under TP."""
    return os.environ.get("REPRO_KERNEL_TP", "").lower() != "off"


def _tp(ctx) -> int:
    return ctx.axis_size(ctx.model)


def _batch_axes(ctx, dim: int):
    """dp spec for a batch/row dim, or None when it doesn't divide."""
    return ctx.dp_spec if dim % ctx.axis_size(ctx.dp) == 0 else None


# -- ff megakernel ------------------------------------------------------------


def ff_tp_ready(params, ctx) -> bool:
    """Can the ff megakernel run per-shard under this context?  The hidden
    width per block (up's ``d_out``) must split over the model axis — the
    same divisibility ``sharding/rules.py`` requires to place the weights
    and ``constrain_ff_hidden`` requires for the hidden layout."""
    if not tp_enabled():
        return False
    tp = _tp(ctx)
    return tp == 1 or params["up"]["w1"].shape[1] % tp == 0


def dyad_ff_tp(params, x, *, act: str = "gelu", use_kernel_bwd: bool = True,
               ctx):
    """``kops.dyad_ff`` under tensor parallelism: per-shard megakernel +
    overlapped cross-shard reduce.  Differentiable — grads flow through
    shard_map to the per-shard custom VJPs (the transpose of the replicated
    row input inserts the matching psum automatically)."""
    tp = _tp(ctx)
    if tp == 1:
        return kops.dyad_ff(params, x, act=act, use_kernel_bwd=use_kernel_bwd)
    lead, f_in = x.shape[:-1], x.shape[-1]
    x2d = x.reshape(-1, f_in)
    rows = _batch_axes(ctx, x2d.shape[0])
    n, d_out = params["down"]["w1"].shape[0], params["down"]["w1"].shape[1]
    f_out = n * d_out
    scatter = f_out % tp == 0
    model = ctx.model

    # weight specs mirror sharding/rules.py: up-type (n, d_out, d_in)
    # shards axis 1 over model, down-type shards axis 2.
    names = ("gate", "up", "down") if act == "swiglu" else ("up", "down")
    weights, in_specs = [], [P(rows, None)]
    for nm in names:
        spec = P(None, None, model) if nm == "down" else P(None, model, None)
        weights += [params[nm]["w1"], params[nm]["w2"]]
        in_specs += [spec, spec]

    def body(xs, *ws):
        it = iter(ws)
        ps = {nm: {"w1": next(it), "w2": next(it)} for nm in names}
        y = kops.dyad_ff(ps, xs, act=act, use_kernel_bwd=use_kernel_bwd)
        if scatter:
            return jax.lax.psum_scatter(y, model, scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(y, model)

    with autotune.tp_shards(tp):
        y = compat_shard_map(
            body, mesh=ctx.mesh, in_specs=tuple(in_specs),
            out_specs=P(rows, model if scatter else None),
            check_vma=False)(x2d, *weights)
    return y.reshape(*lead, f_out)


def dyad_ff_quant_tp(params, x, *, act: str = "gelu", ctx):
    """``kops.dyad_ff_quant`` under tensor parallelism: the quantized
    weight-stream megakernel per-shard.  The int8/fp8 payload sidecars
    shard exactly like their fp32 originals (up/gate ``d_out`` over model,
    down ``d_in``); the per-(block, out_row) scale sidecars follow the
    payload's OUT axis — up/gate scales ``(n, d_mid)`` split over model,
    down scales ``(n, d_out)`` replicate (the down's out rows are whole
    per shard, only its contraction is split).  Forward-only, same
    overlapped psum_scatter epilogue as :func:`dyad_ff_tp`."""
    tp = _tp(ctx)
    if tp == 1:
        return kops.dyad_ff_quant(params, x, act=act)
    lead, f_in = x.shape[:-1], x.shape[-1]
    x2d = x.reshape(-1, f_in)
    rows = _batch_axes(ctx, x2d.shape[0])
    n, d_out = params["down"]["w1"].shape[0], params["down"]["w1"].shape[1]
    f_out = n * d_out
    scatter = f_out % tp == 0
    model = ctx.model

    names = ("gate", "up", "down") if act == "swiglu" else ("up", "down")
    weights, in_specs = [], [P(rows, None)]
    for nm in names:
        if nm == "down":
            w_spec, s_spec = P(None, None, model), P(None, None)
        else:
            w_spec, s_spec = P(None, model, None), P(None, model)
        weights += [params[nm]["w1_q"], params[nm]["w2_q"],
                    params[nm]["w1_s"], params[nm]["w2_s"]]
        in_specs += [w_spec, w_spec, s_spec, s_spec]

    def body(xs, *ws):
        it = iter(ws)
        ps = {nm: {"w1_q": next(it), "w2_q": next(it),
                   "w1_s": next(it), "w2_s": next(it)} for nm in names}
        y = kops.dyad_ff_quant(ps, xs, act=act)
        if scatter:
            return jax.lax.psum_scatter(y, model, scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(y, model)

    with autotune.tp_shards(tp):
        y = compat_shard_map(
            body, mesh=ctx.mesh, in_specs=tuple(in_specs),
            out_specs=P(rows, model if scatter else None),
            check_vma=False)(x2d, *weights)
    return y.reshape(*lead, f_out)


# -- flash attention ----------------------------------------------------------


def attn_tp_ready(n_kv_heads: int, ctx) -> bool:
    """Can the flash kernels run per-shard?  KV heads must split over the
    model axis (GQA groups stay whole per shard)."""
    if not tp_enabled():
        return False
    tp = _tp(ctx)
    return tp == 1 or n_kv_heads % tp == 0


def _off_spec(off, rows):
    """Spec for a scalar-or-(B,) offset/index operand."""
    return P() if off.ndim == 0 else P(rows)


def flash_attention_tp(q, k, v, q_off=0, k_off=0, *, causal: bool = True,
                       window=None, use_kernel_bwd: bool = True, ctx):
    """``kops.flash_attention`` sharded over KV heads (q axis 2, k/v axis
    2); no body collective.  q: (B,S,K,G,h); k/v: (B,T,K,h)."""
    tp = _tp(ctx)
    if tp == 1:
        return kops.flash_attention(q, k, v, q_off, k_off, causal=causal,
                                    window=window,
                                    use_kernel_bwd=use_kernel_bwd)
    q_off = jnp.asarray(q_off, jnp.int32)
    k_off = jnp.asarray(k_off, jnp.int32)
    rows = _batch_axes(ctx, q.shape[0])
    model = ctx.model
    q_spec = P(rows, None, model, None, None)
    kv_spec = P(rows, None, model, None)

    def body(qs, ks, vs, qo, ko):
        return kops.flash_attention(qs, ks, vs, qo, ko, causal=causal,
                                    window=window,
                                    use_kernel_bwd=use_kernel_bwd)

    with autotune.tp_shards(tp):
        return compat_shard_map(
            body, mesh=ctx.mesh,
            in_specs=(q_spec, kv_spec, kv_spec, _off_spec(q_off, rows),
                      _off_spec(k_off, rows)),
            out_specs=q_spec, check_vma=False)(q, k, v, q_off, k_off)


def flash_decode_tp(q, k, v, idx, *, window=None, ctx):
    """``kops.flash_decode`` sharded over KV heads.  q: (B,1,K,G,h) or
    (B,K,G,h); k/v: the (B,L,K,h) post-write ring cache."""
    tp = _tp(ctx)
    if tp == 1:
        return kops.flash_decode(q, k, v, idx, window=window)
    idx = jnp.asarray(idx, jnp.int32)
    rows = _batch_axes(ctx, q.shape[0])
    model = ctx.model
    q_spec = (P(rows, None, model, None, None) if q.ndim == 5
              else P(rows, model, None, None))
    kv_spec = P(rows, None, model, None)

    def body(qs, ks, vs, i):
        return kops.flash_decode(qs, ks, vs, i, window=window)

    with autotune.tp_shards(tp):
        return compat_shard_map(
            body, mesh=ctx.mesh,
            in_specs=(q_spec, kv_spec, kv_spec, _off_spec(idx, rows)),
            out_specs=q_spec, check_vma=False)(q, k, v, idx)


def flash_decode_paged_tp(q, pages_k, pages_v, block_table, idx, *,
                          l_real=None, window=None, scales_k=None,
                          scales_v=None, ctx):
    """``kops.flash_decode_paged`` sharded over KV heads: each device holds
    a head-slice of the WHOLE page pool (page ids are global, so the pool
    axis stays unsharded — see ``sharding/rules.cache_shardings``) and its
    full block table / scalar-prefetch machinery.  q: (B,1,K,G,h) or
    (B,K,G,h); pages: (n_pages, P, K, h); block_table: (B, n_blocks).
    Quantized pools ship ``scales_k``/``scales_v`` ``(n_pages, P, K)``
    scale pools sharded over the same KV-head axis."""
    tp = _tp(ctx)
    if tp == 1:
        return kops.flash_decode_paged(q, pages_k, pages_v, block_table,
                                       idx, l_real=l_real, window=window,
                                       scales_k=scales_k, scales_v=scales_v)
    idx = jnp.asarray(idx, jnp.int32)
    rows = _batch_axes(ctx, q.shape[0])
    model = ctx.model
    q_spec = (P(rows, None, model, None, None) if q.ndim == 5
              else P(rows, model, None, None))
    pool_spec = P(None, None, model, None)
    quant = scales_k is not None

    if quant:
        def body(qs, pk, pv, bt, i, sk, sv):
            return kops.flash_decode_paged(qs, pk, pv, bt, i, l_real=l_real,
                                           window=window, scales_k=sk,
                                           scales_v=sv)

        with autotune.tp_shards(tp):
            return compat_shard_map(
                body, mesh=ctx.mesh,
                in_specs=(q_spec, pool_spec, pool_spec, P(rows, None),
                          _off_spec(idx, rows), P(None, None, model),
                          P(None, None, model)),
                out_specs=q_spec, check_vma=False)(
                    q, pages_k, pages_v, block_table, idx, scales_k,
                    scales_v)

    def body(qs, pk, pv, bt, i):
        return kops.flash_decode_paged(qs, pk, pv, bt, i, l_real=l_real,
                                       window=window)

    with autotune.tp_shards(tp):
        return compat_shard_map(
            body, mesh=ctx.mesh,
            in_specs=(q_spec, pool_spec, pool_spec, P(rows, None),
                      _off_spec(idx, rows)),
            out_specs=q_spec, check_vma=False)(
                q, pages_k, pages_v, block_table, idx)
