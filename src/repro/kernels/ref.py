"""Pure-jnp oracle for the fused DYAD matmul kernel.

``dyad_mm_ref`` computes exactly what ``kernels.ops.dyad_mm`` computes:
the sum of the BLOCKDIAG and BLOCKTRANS contributions for a given variant,
*without* bias (bias is added by the caller).  Shapes:

    x        (..., f_in)                 f_in  = n_dyad * d_in
    w1, w2   (n_dyad, d_out, d_in)       f_out = n_dyad * d_out
    returns  (..., f_out)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_views(x, n: int, variant: str):
    """(x1, x2) per-block input views; see repro.core.dyad._block_views."""
    d_in = x.shape[-1] // n
    lead = x.shape[:-1]
    x1 = x.reshape(*lead, n, d_in)
    if variant in ("it", "dt"):
        x2 = jnp.swapaxes(x.reshape(*lead, d_in, n), -1, -2)
    else:
        x2 = x1
    return x1, x2


def combine(z1, z2, variant: str):
    lead = z1.shape[:-2]
    f_out = z1.shape[-2] * z1.shape[-1]
    y1 = z1.reshape(*lead, f_out)
    if variant in ("ot", "dt"):
        y2 = jnp.swapaxes(z2, -1, -2).reshape(*lead, f_out)
    else:
        y2 = z2.reshape(*lead, f_out)
    return y1 + y2


def dyad_mm_ref(x, w1, w2, *, variant: str = "it"):
    n = w1.shape[0]
    x1, x2 = block_views(x, n, variant)
    z1 = jnp.einsum("...gi,goi->...go", x1, w1.astype(x.dtype))
    z2 = jnp.einsum("...gi,goi->...go", x2, w2.astype(x.dtype))
    return combine(z1, z2, variant)


def split_cotangent(g, n: int, variant: str):
    """(z1bar, z2bar): per-component views of the output cotangent
    ``g: (..., f_out)`` -> ``(..., n, d_out)`` each.  The split mirrors
    the output layouts of :func:`combine`: component 1 is always
    block-contiguous; component 2 is the strided re-view for ot/dt."""
    d_out = g.shape[-1] // n
    lead = g.shape[:-1]
    z1bar = g.reshape(*lead, n, d_out)
    if variant in ("ot", "dt"):
        z2bar = jnp.swapaxes(g.reshape(*lead, d_out, n), -1, -2)
    else:
        z2bar = z1bar
    return z1bar, z2bar


def unview(dx1, dx2, variant: str):
    """Fold per-view input cotangents back onto the flat feature axis —
    the exact inverse of :func:`block_views` (the permutations are
    bijective), summing the two components."""
    lead = dx1.shape[:-2]
    f_in = dx1.shape[-2] * dx1.shape[-1]
    out = dx1.reshape(*lead, f_in)
    if variant in ("it", "dt"):
        out = out + jnp.swapaxes(dx2, -1, -2).reshape(*lead, f_in)
    else:
        out = out + dx2.reshape(*lead, f_in)
    return out


ACTS = {
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "silu": jax.nn.silu,
}


def dyad_ff_ref(x, wu1, wu2, wd1, wd2, wg1=None, wg2=None, *,
                act: str = "gelu"):
    """Pure-einsum oracle for the ff megakernel
    (:func:`repro.kernels.dyad_mm.dyad_ff_fused` via ``ops.dyad_ff``):
    up = IT in block layout, activation (``act='swiglu'`` gates with
    wg1/wg2), down = OT consuming the block-layout hidden.  Shapes:

        x          (..., f_in)            f_in  = n * d_in
        wu*, wg*   (n, d_ff_b, d_in)      hidden is (..., n, d_ff_b)
        wd*        (n, d_out, d_ff_b)     f_out = n * d_out
        returns    (..., f_out)
    """
    n = wu1.shape[0]
    x1, x2 = block_views(x, n, "it")

    def up(w1, w2):
        return (jnp.einsum("...gi,gji->...gj", x1, w1.astype(x.dtype))
                + jnp.einsum("...gi,gji->...gj", x2, w2.astype(x.dtype)))

    u = up(wu1, wu2)
    if act == "swiglu":
        h = jax.nn.silu(up(wg1, wg2)) * u
    else:
        h = ACTS[act](u)
    z1 = jnp.einsum("...gj,goj->...go", h, wd1.astype(x.dtype))
    z2 = jnp.einsum("...gj,goj->...go", h, wd2.astype(x.dtype))
    return combine(z1, z2, "ot")


def sdpa_ref(q, k, v, qpos, kpos, *, causal: bool = True, window=None):
    """Pure-einsum oracle for the flash-attention kernels
    (:mod:`repro.kernels.flash_attn` via ``ops.flash_attention``).

    q: (B, S, K, G, h); k, v: (B, T, K, h); qpos: (S,) or (B, S) absolute
    query positions; kpos: (T,) or (B, T) key positions (< 0 = invalid).
    Scores accumulate in fp32; masked probabilities are EXPLICITLY zeroed
    so a fully-masked row yields output 0 (the ``max(l, 1e-30)`` guard) —
    the exact semantics the kernels implement.  Deliberately independent
    of ``layers.attention`` so kernel tests have a second opinion.
    """
    neg = -1e30
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bskgh,btkh->bskgt", q, k,
                   preferred_element_type=jnp.float32) * scale
    qp = qpos if qpos.ndim == 2 else qpos[None, :]          # (B?, S)
    kp = kpos if kpos.ndim == 2 else kpos[None, :]          # (B?, T)
    m = kp[:, None, :] >= 0
    if causal:
        m = m & (kp[:, None, :] <= qp[..., :, None])
    if window is not None:
        m = m & (qp[..., :, None] - kp[:, None, :] < window)
    m = m[:, :, None, None, :]
    s = jnp.where(m, s, neg)
    mx = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.where(m, jnp.exp(s - mx), 0.0)
    l = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.maximum(l, 1e-30)
    return jnp.einsum("bskgt,btkh->bskgh", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def dyad_mm_bwd_ref(x, w1, w2, g, *, variant: str = "it"):
    """Pure-einsum VJP oracle for :func:`dyad_mm_ref` — what the kernel
    backward (:func:`repro.kernels.dyad_mm.dyad_mm_dgrad` /
    ``dyad_mm_wgrad``) must reproduce to fp32 tolerance.

    Returns ``(dx, dw1, dw2)`` for output cotangent ``g: (..., f_out)``.
    """
    n = w1.shape[0]
    x1, x2 = block_views(x, n, variant)
    z1bar, z2bar = split_cotangent(g, n, variant)
    dw1 = jnp.einsum("...gi,...go->goi", x1, z1bar).astype(w1.dtype)
    dw2 = jnp.einsum("...gi,...go->goi", x2, z2bar).astype(w2.dtype)
    dx1 = jnp.einsum("...go,goi->...gi", z1bar, w1.astype(g.dtype))
    dx2 = jnp.einsum("...go,goi->...gi", z2bar, w2.astype(g.dtype))
    dx = unview(dx1, dx2, variant).astype(x.dtype)
    return dx, dw1, dw2
