"""Pure-jnp oracle for the fused DYAD matmul kernel.

``dyad_mm_ref`` computes exactly what ``kernels.ops.dyad_mm`` computes:
the sum of the BLOCKDIAG and BLOCKTRANS contributions for a given variant,
*without* bias (bias is added by the caller).  Shapes:

    x        (..., f_in)                 f_in  = n_dyad * d_in
    w1, w2   (n_dyad, d_out, d_in)       f_out = n_dyad * d_out
    returns  (..., f_out)
"""
from __future__ import annotations

import jax.numpy as jnp


def block_views(x, n: int, variant: str):
    """(x1, x2) per-block input views; see repro.core.dyad._block_views."""
    d_in = x.shape[-1] // n
    lead = x.shape[:-1]
    x1 = x.reshape(*lead, n, d_in)
    if variant in ("it", "dt"):
        x2 = jnp.swapaxes(x.reshape(*lead, d_in, n), -1, -2)
    else:
        x2 = x1
    return x1, x2


def combine(z1, z2, variant: str):
    lead = z1.shape[:-2]
    f_out = z1.shape[-2] * z1.shape[-1]
    y1 = z1.reshape(*lead, f_out)
    if variant in ("ot", "dt"):
        y2 = jnp.swapaxes(z2, -1, -2).reshape(*lead, f_out)
    else:
        y2 = z2.reshape(*lead, f_out)
    return y1 + y2


def dyad_mm_ref(x, w1, w2, *, variant: str = "it"):
    n = w1.shape[0]
    x1, x2 = block_views(x, n, variant)
    z1 = jnp.einsum("...gi,goi->...go", x1, w1.astype(x.dtype))
    z2 = jnp.einsum("...gi,goi->...go", x2, w2.astype(x.dtype))
    return combine(z1, z2, variant)
