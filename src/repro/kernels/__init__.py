"""Pallas TPU kernels for the paper's compute hot-spot: the DYAD matmul.

- dyad_mm.py — pl.pallas_call kernels with explicit BlockSpec VMEM tiling.
- ops.py     — jit'd differentiable wrapper (custom_vjp).
- ref.py     — pure-jnp oracle used by tests and by the non-kernel path.
"""
from repro.kernels import ref  # noqa: F401
