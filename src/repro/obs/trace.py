"""Nestable tracing spans with Chrome-trace/Perfetto JSON export.

The tracer is a process-global singleton that is **off by default** — the
instrumented hot paths (serve engine steps, train steps, autotune sweeps,
kernel route dispatch) call :func:`span` / :func:`instant` unconditionally,
and the disabled path is a single module-global ``is None`` check returning
a shared no-op context manager (no allocation, no clock read).  The
disabled-overhead guard in ``tests/test_obs.py`` pins this.

Enabled (:func:`enable`), spans record ``time.perf_counter_ns`` enter/exit
pairs into a bounded ring buffer (``collections.deque(maxlen=capacity)``):
a long-running server can trace forever and keep the most recent window.
Nesting needs no explicit parent bookkeeping — the Chrome trace format
(``ph: "X"`` complete events) nests by time containment per thread, so
:func:`export` just emits one event per span with the recording thread's id
as ``tid``.  Load the written file in ``ui.perfetto.dev`` or
``chrome://tracing``.

Span args must be JSON-serializable scalars (the recorder stringifies
anything else at export, never in the hot path).
"""
from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Deque, Dict, List, Optional

DEFAULT_CAPACITY = 200_000


class _NullSpan:
    """The disabled tracer's span: a shared, stateless context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        """Attach args after entry (no-op when disabled)."""


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records enter/exit timestamps on the tracer clock."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        self._tracer._record(self.name, self.cat, self._t0, t1 - self._t0,
                             self.args)
        return False

    def set(self, **args) -> None:
        """Attach/overwrite args from inside the span body (e.g. a result
        computed mid-span, like the number of tokens a step emitted)."""
        self.args.update(args)


class Tracer:
    """Bounded in-memory span recorder on the monotonic clock."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self._events: Deque[tuple] = collections.deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.t_origin_ns = time.perf_counter_ns()
        self.dropped = 0          # events evicted by the ring bound

    def _record(self, name: str, cat: str, t0_ns: int, dur_ns: int,
                args: dict) -> None:
        ev = (name, cat, t0_ns, dur_ns, threading.get_ident(), args)
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)

    def span(self, name: str, cat: str = "repro", **args) -> _Span:
        return _Span(self, name, cat, args)

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        """Zero-duration marker event (route decisions, rejects, ...)."""
        self._record(name, cat, time.perf_counter_ns(), -1, args)

    def __len__(self) -> int:
        return len(self._events)

    # -- export -------------------------------------------------------------
    @staticmethod
    def _clean(args: dict) -> dict:
        out = {}
        for k, v in args.items():
            if isinstance(v, (int, float, str, bool)) or v is None:
                out[k] = v
            else:
                out[k] = str(v)
        return out

    def to_chrome_trace(self) -> dict:
        """The full Chrome-trace document (``ui.perfetto.dev`` opens it).

        Timestamps are microseconds relative to the tracer's origin; spans
        are ``ph: "X"`` complete events (Perfetto nests them by time
        containment per tid), instants are ``ph: "i"``."""
        with self._lock:
            events = list(self._events)
        out: List[dict] = []
        pid = os.getpid()
        for name, cat, t0, dur, tid, args in events:
            ev: Dict = {
                "name": name, "cat": cat, "pid": pid, "tid": tid,
                "ts": (t0 - self.t_origin_ns) / 1e3,
            }
            if dur < 0:
                ev["ph"] = "i"
                ev["s"] = "t"
            else:
                ev["ph"] = "X"
                ev["dur"] = dur / 1e3
            if args:
                ev["args"] = self._clean(args)
            out.append(ev)
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"recorder": "repro.obs", "dropped": self.dropped},
        }

    def export(self, path: str) -> str:
        doc = self.to_chrome_trace()
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        os.replace(tmp, path)
        return path


# -- the process-global tracer ------------------------------------------------

_TRACER: Optional[Tracer] = None


def enable(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    """Install (or return the existing) process-global tracer."""
    global _TRACER
    if _TRACER is None:
        _TRACER = Tracer(capacity)
    return _TRACER


def disable() -> Optional[Tracer]:
    """Remove the global tracer; returns it (for a final export)."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


def span(name: str, cat: str = "repro", **args):
    """``with obs.span("decode_step", batch=4): ...`` — a nestable span on
    the global tracer, or a shared no-op when tracing is off.  The disabled
    path is one global load and one branch."""
    t = _TRACER
    if t is None:
        return _NULL_SPAN
    return t.span(name, cat, **args)


def instant(name: str, cat: str = "repro", **args) -> None:
    t = _TRACER
    if t is not None:
        t.instant(name, cat, **args)


def export(path: str) -> Optional[str]:
    """Export the global tracer's buffer as Chrome-trace JSON (None when
    tracing is off)."""
    t = _TRACER
    if t is None:
        return None
    return t.export(path)


def verbose() -> bool:
    """Shared gate for human-readable progress lines from long-running
    internals (autotune sweeps): on when ``REPRO_OBS_VERBOSE`` is truthy OR
    the tracer is enabled (if you care enough to trace, you care enough to
    see sweep progress)."""
    env = os.environ.get("REPRO_OBS_VERBOSE", "").lower()
    if env in ("1", "true", "on", "yes"):
        return True
    if env in ("0", "false", "off", "no"):
        return False
    return _TRACER is not None
