"""Counters / gauges / histograms for serving and training telemetry.

A :class:`MetricsRegistry` is a named bag of instruments — unlike the
tracer there is no global singleton: each engine / trainer owns one, so
two engines in one process never share a TTFT histogram.  Instruments are
cheap (plain Python attribute math, no locks — the engines and trainer
mutate them from their own driver thread) and snapshot to plain dicts for
``--metrics-json`` and the periodic one-line reports.

The catalog the serve engines populate (``docs/ARCHITECTURE.md``
§Observability):

* ``ttft_s`` (histogram)         — submit -> first generated token, per request
* ``itl_s`` (histogram)          — mean inter-token latency, per request
* ``decode_step_s`` (histogram)  — one padded-batch decode step
* ``tokens_generated`` (counter), ``requests_finished`` (counter),
  ``admission_rejects`` (counter)
* ``queue_depth`` / ``active_slots`` / ``page_pool_used`` (gauges, with
  high-water marks)
* ``prefix_hits`` / ``prefix_tokens_skipped`` (counters, paged+prefix mode)

The trainer's set: ``step_time_s`` (histogram), ``tokens_per_s`` (gauge),
``loss`` (gauge), ``straggler_count`` (counter).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Union

Number = Union[int, float]


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: Number = 1) -> None:
        self.value += n


class Gauge:
    """Last-set value plus a high-water mark (peak occupancy answers the
    capacity question a last-value gauge can't)."""

    __slots__ = ("value", "max")

    def __init__(self) -> None:
        self.value = 0
        self.max = 0

    def set(self, v: Number) -> None:
        self.value = v
        if v > self.max:
            self.max = v


class Histogram:
    """Exact-sample histogram: serving runs are bounded by the request
    count, so keeping the raw observations (bounded by ``max_samples``)
    buys exact percentiles without bucket-boundary tuning."""

    __slots__ = ("samples", "count", "total", "max_samples")

    def __init__(self, max_samples: int = 100_000) -> None:
        self.samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.max_samples = max_samples

    def observe(self, v: Number) -> None:
        self.count += 1
        self.total += v
        if len(self.samples) < self.max_samples:
            self.samples.append(float(v))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the retained samples (0 if empty)."""
        if not self.samples:
            return 0.0
        s = sorted(self.samples)
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[idx]

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean": self.mean,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Named instruments, created on first touch (``m.counter("x").inc()``)."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self.t_start = time.perf_counter()

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> dict:
        """Plain-dict snapshot (JSON-ready): counters as values, gauges as
        {value, max}, histograms as count/mean/percentiles."""
        return {
            "elapsed_s": time.perf_counter() - self.t_start,
            "counters": {k: v.value for k, v in sorted(self._counters.items())},
            "gauges": {k: {"value": g.value, "max": g.max}
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._hists.items())},
        }

    def write_json(self, path: str, **extra) -> str:
        """Dump the snapshot as JSON; ``extra`` top-level sections (e.g.
        ``routes=obs.routes_snapshot()``) ride along in the same artifact."""
        doc = self.snapshot()
        doc.update(extra)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        return path


def _fmt(v: float) -> str:
    if v >= 100:
        return f"{v:.0f}"
    return f"{v:.3g}"


def format_serving_line(m: MetricsRegistry) -> str:
    """The periodic one-line serving report (and the final summary body)."""
    snap = m.snapshot()
    c, g, h = snap["counters"], snap["gauges"], snap["histograms"]
    toks = c.get("tokens_generated", 0)
    el = max(snap["elapsed_s"], 1e-9)
    ttft = h.get("ttft_s", {})
    itl = h.get("itl_s", {})
    pool = g.get("page_pool_used", {"value": 0, "max": 0})
    return (f"reqs={c.get('requests_finished', 0)} tok={toks} "
            f"tok/s={_fmt(toks / el)} "
            f"ttft_ms p50={_fmt(1e3 * ttft.get('p50', 0.0))} "
            f"p99={_fmt(1e3 * ttft.get('p99', 0.0))} "
            f"itl_ms p50={_fmt(1e3 * itl.get('p50', 0.0))} "
            f"queue={g.get('queue_depth', {}).get('value', 0)} "
            f"active={g.get('active_slots', {}).get('value', 0)} "
            f"pages={pool['value']}/{pool['max']}peak "
            f"prefix_hits={c.get('prefix_hits', 0)} "
            f"prefix_tok_skipped={c.get('prefix_tokens_skipped', 0)} "
            f"rejects={c.get('admission_rejects', 0)} "
            f"preempts={c.get('preemptions', 0)} "
            f"faulted={c.get('retired_faulted', 0)}")


def format_training_line(m: MetricsRegistry, step: int,
                         loss: Optional[float] = None,
                         extra: str = "") -> str:
    snap = m.snapshot()
    h = snap["histograms"].get("step_time_s", {})
    g = snap["gauges"]
    line = (f"step {step} "
            + (f"loss={loss:.4f} " if loss is not None else "")
            + f"tok/s={_fmt(g.get('tokens_per_s', {}).get('value', 0.0))} "
            f"step_ms p50={_fmt(1e3 * h.get('p50', 0.0))}")
    return line + (f" {extra}" if extra else "")
