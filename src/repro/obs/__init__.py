"""Runtime observability: tracing spans, metrics, route-dispatch visibility.

Three pieces (docs/ARCHITECTURE.md §Observability):

* :mod:`repro.obs.trace` — process-global tracer with nestable spans on the
  monotonic clock, a bounded ring buffer, and Chrome-trace/Perfetto JSON
  export.  Off by default; the disabled fast path is one global load.
* :mod:`repro.obs.metrics` — per-owner :class:`MetricsRegistry`
  (counters / gauges / exact-percentile histograms) behind the serving and
  training telemetry: TTFT, inter-token latency, tok/s, queue depth,
  page-pool occupancy, prefix-cache hits, step time, stragglers.
* route-dispatch events (:func:`route_event` below) — every trace-time
  kernel routing decision in :mod:`repro.kernels.ops` (fused vs split,
  flash vs xla, pallas vs xla bwd) is counted here and, when tracing,
  marked in the timeline, so a silent fallback to a slow path shows up in
  ``route_counts()`` / the exported trace instead of only in the wall time.

Consumers: ``launch/serve.py --trace/--metrics-json``,
``launch/train.py --trace``, ``benchmarks/run.py --trace``, and
``python -m repro.perf.timeline`` (replay-diff of two exported traces).
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               format_serving_line, format_training_line)
from repro.obs.trace import (Tracer, disable, enable, enabled, export,
                             get_tracer, instant, span, verbose)

# (op, route) -> count of trace-time dispatch decisions.  Process-global on
# purpose: routing is a process-level property (backend + env vars), and the
# counters must be live even when no tracer is installed.
_ROUTE_COUNTS: Dict[Tuple[str, str], int] = {}


def route_event(op: str, route: str, **args) -> None:
    """Record one trace-time kernel routing decision (cheap: dict bump +
    optional instant event)."""
    key = (op, route)
    _ROUTE_COUNTS[key] = _ROUTE_COUNTS.get(key, 0) + 1
    instant(f"route:{op}={route}", cat="route", op=op, route=route, **args)


def route_counts() -> Dict[Tuple[str, str], int]:
    """Copy of the dispatch-decision counters ({(op, route): n})."""
    return dict(_ROUTE_COUNTS)


def reset_route_counts() -> None:
    _ROUTE_COUNTS.clear()


def routes_snapshot() -> Dict[str, int]:
    """JSON-ready view of the dispatch counters (``{"op:route": n}``) —
    merged into ``--metrics-json`` exports so a config that silently loses
    a kernel route (e.g. ``ff_tp:tp_fallback`` under TP) is visible in the
    same artifact as the latency percentiles."""
    return {f"{op}:{route}": n
            for (op, route), n in sorted(_ROUTE_COUNTS.items())}


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "format_serving_line", "format_training_line",
    "Tracer", "enable", "disable", "enabled", "export", "get_tracer",
    "instant", "span", "verbose",
    "route_event", "route_counts", "reset_route_counts", "routes_snapshot",
]
