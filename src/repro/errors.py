"""Typed exception hierarchy for the serving / training / checkpoint stack.

Every raise that a caller might want to catch-and-recover from carries a
dedicated type rooted at :class:`ReproError`.  The concrete classes ALSO
subclass the builtin the pre-PR-10 code raised at the same site
(``AdmissionError`` is a ``ValueError``, ``PageExhausted`` a
``RuntimeError``, ...), so existing ``except ValueError`` callers and the
seed-era tests keep working while new code can discriminate precisely.

The resilience layer (:mod:`repro.faults`, the continuous engine's
preemption/deadline machinery, the trainer's NaN backoff, the checkpoint
retry loop) raises exclusively from this module.
"""
from __future__ import annotations


class ReproError(Exception):
    """Root of every typed error the repro stack raises deliberately."""


class AdmissionError(ReproError, ValueError):
    """A request can never be admitted: prompt + budget exceeds the cache
    length, the page pool could never hold it, or the parameters are
    malformed (``max_new < 1``).  Raised by ``submit`` before queueing —
    an admitted request never hits this."""


class DeadlineExceeded(ReproError, TimeoutError):
    """A deadline expired: ``ContinuousBatchingEngine.run(deadline_s=...)``
    overran its drain budget.  Per-request deadlines do NOT raise — they
    retire the request with ``RetireReason.DEADLINE``."""


class NumericalFault(ReproError, ArithmeticError):
    """Non-finite values survived every recovery rung: the trainer saw K
    consecutive non-finite steps with no checkpoint to roll back to, or a
    caller asked for strict numerics.  The serving engine never raises
    this — it retires the affected requests with ``RetireReason.FAULTED``
    instead."""


class CheckpointIOError(ReproError, RuntimeError):
    """A checkpoint write failed after exhausting the retry/backoff budget
    (or an async save failed and surfaced at ``wait()``)."""


class PageExhausted(ReproError, RuntimeError):
    """``PageAllocator.alloc`` found no free page (for real, or via the
    ``page_exhaustion`` fault site).  The continuous engine treats it as
    pool pressure: roll back the partial admission and retry/preempt."""


class PageAccountingError(ReproError, ValueError):
    """A page-refcount invariant was about to be violated: double release,
    retain of a free page, or a free-list page with a live refcount.
    Raising loudly here is the guard against silent KV corruption."""
