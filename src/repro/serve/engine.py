"""Batched serving engine: single-pass prefill, scan-compiled decode, and a
continuous-batching slot manager.

Three layers of API, fastest first:

* :class:`Engine` — homogeneous batches.  ``generate`` issues ONE jitted
  prefill call for the whole prompt batch (full-sequence forward with cache
  writes) and ONE jitted ``lax.scan`` call for the whole decode loop over a
  preallocated output buffer, so per-token Python dispatch disappears from
  the hot path.
* :class:`ContinuousBatchingEngine` — heterogeneous requests share one padded
  jitted step.  A :class:`SlotManager` allocates fixed cache slots, tracks
  per-slot lengths, retires sequences at EOS (or length budget) and admits
  queued requests into freed slots; the per-slot KV write index
  (``init_cache(..., per_slot=True)``) lets every slot sit at a different
  sequence position.
* :func:`prefill_tokenwise` / :meth:`Engine.generate_reference` — the seed's
  token-per-Python-iteration paths, kept as correctness oracles for tests and
  as the baseline for ``benchmarks/bench_serve_throughput.py``.

Cache contract (see :func:`repro.models.model.init_cache`): every leaf is
stacked with a leading ``n_layers`` axis; batch is axis 1.  KV caches hold
``k``/``v`` ``(n_layers, B, L, n_kv, head_dim)`` in ``cache_dtype`` plus a
write index ``idx`` (``(n_layers,)`` scalar-per-layer, or ``(n_layers, B)``
per-slot); SSM caches hold ``conv`` ``(n_layers, B, W-1, Ch)`` and the fp32
``state`` ``(n_layers, B, H, P, N)``.  Logits are always fp32
``(B, 1, vocab)``.

Paged mode (``ContinuousBatchingEngine(page_size=...)``): the per-slot dense
rings become ONE shared page pool per layer plus per-slot block tables (see
:func:`repro.layers.attention.init_paged_kv_cache`).  A :class:`PageAllocator`
owns the physical pages with refcounts; admission reserves exactly
``ceil((prompt + max_new - 1) / page_size)`` pages per request — instead of a
worst-case ``max_len`` row — so the same HBM admits strictly more concurrent
requests whenever traffic runs shorter than the worst case (no preemption:
reservation is up-front, a request can never OOM mid-flight).  Prompts can
prefill in chunks interleaved with decode steps (``prefill_chunk=``), and
``prefix_cache=True`` hashes full prompt pages so requests sharing a system
prompt retain the original pages instead of re-prefilling them.  The device
block tables / write indices are re-pushed from HOST truth before every
batch decode step, with non-decoding lanes pointed at the reserved scratch
page 0 — their garbage writes can never corrupt live pages.
``REPRO_PAGED_KV=off`` is the escape hatch back to dense rings.

Resilience (PR 10): requests carry optional **deadlines** and can be
**cancelled**; every retirement records a typed :class:`RetireReason`.
Under page-pool pressure the paged engine **preempts a victim** (youngest
non-prefix-shared decoding slot: pages released, request re-queued with its
generated-so-far tokens for a cheap re-prefill) instead of head-of-line
blocking forever.  A jit-compatible **NaN/Inf guard** on the decode logits
drives a route **demotion ladder** (quant -> fp, fused -> split, flash ->
xla, via the existing ``REPRO_KERNEL_*`` escape hatches + a re-jit) with a
same-route retry first, so a transient fault never demotes; requests whose
logits stay non-finite after the full ladder retire as ``FAULTED``.  All of
it is driven deterministically by :mod:`repro.faults`
(``REPRO_FAULT="page_exhaustion:p=0.05;nan_logits:at_step=3"``).
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import functools
import hashlib
import itertools
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults, obs
from repro.errors import (AdmissionError, DeadlineExceeded,
                          PageAccountingError, PageExhausted)
from repro.models import model
from repro.models.config import ModelCfg
from repro.sharding import ctx as shard_ctx


def _tuning_mesh_kwargs() -> dict:
    """Mesh kwargs for ``ensure_tuned_for_model``, captured from the ambient
    activation-sharding context: a TP serve tunes PER-SHARD kernel shapes
    (``|tp{N}`` cache keys), a single-device serve tunes global ones.
    Captured at engine construction so tuning stays mesh-correct even when
    ``generate()`` runs outside the ``activation_sharding`` block."""
    actx = shard_ctx.current()
    if actx is None:
        return {}
    return {"mesh": actx.mesh, "model_axis": actx.model}


def make_serve_step(cfg: ModelCfg):
    """(params, cache, tokens (B,1) int32) -> (logits (B,1,V) fp32, new_cache).

    Exactly the function the decode_* dry-run shapes lower."""
    def serve_step(params, cache, tokens):
        return model.decode_step(cfg, params, cache, tokens)
    return serve_step


def prefill(cfg: ModelCfg, params, cache, tokens, frames=None):
    """Single-pass prefill: one full-sequence forward with cache writes.

    tokens: (B, S) int32; optional ``frames`` (encdec audio) fill the
    cross-attention K/V first.  Returns (last_logits (B,1,V) fp32, cache
    positioned at S).  One jitted call per request batch — no per-token loop.
    """
    return model.prefill(cfg, params, cache, tokens, frames=frames)


_jit_decode_step = jax.jit(model.decode_step, static_argnums=0)


def prefill_tokenwise(cfg: ModelCfg, params, cache, tokens, frames=None):
    """The seed's token-per-Python-iteration prefill — S sequential
    ``decode_step`` dispatches (jitted, one call PER TOKEN).  Kept as the
    correctness oracle and benchmark baseline; use :func:`prefill` (one call
    per request batch) for serving.
    """
    if cfg.family == "encdec" and frames is not None:
        cache = model.prefill_cross(cfg, params, cache, frames)
    B, S = tokens.shape
    logits = None
    for t in range(S):
        logits, cache = _jit_decode_step(cfg, params, cache,
                                         tokens[:, t:t + 1])
    return logits, cache


def sample_token(logits, temperature: float, key=None):
    """Greedy (temperature <= 0 or no key) or temperature sampling.

    logits: (B, S, V) fp32 — only the last position is used.  Returns
    (B, 1) int32/int64 next tokens.  ``temperature`` must be a static Python
    float (it selects the sampling branch at trace time).
    """
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits[:, -1:], axis=-1)
    return jax.random.categorical(
        key, logits[:, -1] / temperature, axis=-1)[:, None]


class Engine:
    """Greedy/temperature batched generation over a persistent cache.

    ``generate`` is the compiled path: one jitted prefill per prompt shape +
    one jitted ``lax.scan`` decode per (num_new, temperature, sampled) combo.
    ``generate_reference`` is the seed Python loop (one jitted call per
    token), kept for equivalence tests and the throughput benchmark.
    """

    def __init__(self, cfg: ModelCfg, params, max_len: int,
                 cache_dtype=jnp.float32, autotune: bool = False):
        self.cfg, self.params, self.max_len = cfg, params, max_len
        self.cache_dtype = cache_dtype
        # tile tuning happens per generate() call, where the actual row
        # counts are known (prefill sees B*S rows, decode sees B) — a jit
        # trace bakes in whatever blocks the cache holds when it runs, so
        # the tuner must go first (no-op for non-Pallas configs)
        self._autotune = autotune
        self._mesh_kw = _tuning_mesh_kwargs()
        self._step = jax.jit(make_serve_step(cfg))
        self._prefill = jax.jit(functools.partial(prefill, cfg))
        self._loops: Dict[tuple, callable] = {}
        self.metrics = obs.MetricsRegistry()

    # -- compiled path ------------------------------------------------------
    def generate(self, prompt_tokens, num_new: int, *, temperature: float = 0.0,
                 key: Optional[jax.Array] = None, frames=None):
        """prompt_tokens: (B, S) int32 -> (B, num_new) generated tokens.

        Requires S + num_new - 1 <= max_len (the cache length)."""
        B, S = prompt_tokens.shape
        if S + num_new - 1 > self.max_len:
            raise ValueError(
                f"prompt {S} + {num_new} new tokens exceeds max_len "
                f"{self.max_len}")
        if self._autotune:
            from repro.perf.autotune import ensure_tuned_for_model

            # cache hits short-circuit, so repeat calls are cheap.  seq_len
            # covers the flash-prefill tiles, kv_len the flash-decode tiles
            # over the max_len cache (no-ops for non-flash configs).
            ensure_tuned_for_model(self.cfg, tokens=B * S, seq_len=S,
                                   **self._mesh_kw)          # prefill rows
            ensure_tuned_for_model(self.cfg, tokens=B, kv_len=self.max_len,
                                   **self._mesh_kw)          # decode rows
        t_start = time.perf_counter()
        cache = model.init_cache(self.cfg, B, self.max_len, self.cache_dtype)
        with obs.span("prefill", cat="serve", batch=B, prompt_len=S):
            logits, cache = self._prefill(self.params, cache, prompt_tokens,
                                          frames)
            tok = jax.block_until_ready(
                sample_token(logits, temperature, key))
        t_first = time.perf_counter()
        # batch TTFT: prompt in -> first sampled token out (per generate)
        self.metrics.histogram("ttft_s").observe(t_first - t_start)
        if num_new == 1:
            self.metrics.counter("tokens_generated").inc(B)
            self.metrics.counter("requests_finished").inc(B)
            return tok
        loop = self._decode_loop(num_new, temperature, key is not None)
        with obs.span("decode_loop", cat="serve", batch=B, num_new=num_new):
            toks, _ = loop(self.params, cache, tok,
                           key if key is not None else jax.random.PRNGKey(0))
            toks = jax.block_until_ready(toks)
        self.metrics.counter("tokens_generated").inc(B * num_new)
        self.metrics.counter("requests_finished").inc(B)
        self.metrics.histogram("itl_s").observe(
            (time.perf_counter() - t_first) / (num_new - 1))
        return toks

    def _decode_loop(self, num_new: int, temperature: float, sampled: bool):
        """Build (and memoize) the scan-compiled decode loop.

        The loop carries (token, cache) and emits into a preallocated
        (num_new, B) buffer — ONE dispatch for the whole decode, with the
        same key schedule as the reference loop (fold_in(key, i+1))."""
        sig = (num_new, float(temperature), sampled)
        if sig in self._loops:
            return self._loops[sig]
        cfg = self.cfg

        def loop(params, cache, tok0, key):
            def body(carry, i):
                tok, cache = carry
                logits, cache = model.decode_step(cfg, params, cache, tok)
                k = jax.random.fold_in(key, i + 1) if sampled else None
                nxt = sample_token(logits, temperature, k)
                return (nxt, cache), tok[:, 0]

            (_, cache), toks = jax.lax.scan(body, (tok0, cache),
                                            jnp.arange(num_new))
            return jnp.swapaxes(toks, 0, 1), cache

        self._loops[sig] = jax.jit(loop)
        return self._loops[sig]

    # -- reference path (seed implementation) -------------------------------
    def generate_reference(self, prompt_tokens, num_new: int, *,
                           temperature: float = 0.0,
                           key: Optional[jax.Array] = None, frames=None,
                           jit_prefill: bool = True):
        """The seed implementation: token-wise prefill + Python decode loop
        with per-step dispatch.  Semantically identical to :meth:`generate`;
        kept as the oracle/baseline.  ``jit_prefill=False`` reproduces the
        seed exactly (eager per-token prefill — very slow; benchmark only).
        """
        B = prompt_tokens.shape[0]
        cache = model.init_cache(self.cfg, B, self.max_len, self.cache_dtype)
        if jit_prefill:
            logits, cache = prefill_tokenwise(self.cfg, self.params, cache,
                                              prompt_tokens, frames=frames)
        else:
            if self.cfg.family == "encdec" and frames is not None:
                cache = model.prefill_cross(self.cfg, self.params, cache,
                                            frames)
            logits = None
            for t in range(prompt_tokens.shape[1]):
                logits, cache = model.decode_step(
                    self.cfg, self.params, cache, prompt_tokens[:, t:t + 1])
        out = []
        tok = sample_token(logits, temperature, key)
        for i in range(num_new):
            out.append(tok)
            logits, cache = self._step(self.params, cache, tok)
            key2 = None if key is None else jax.random.fold_in(key, i + 1)
            tok = sample_token(logits, temperature, key2)
        return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------
class RetireReason(str, enum.Enum):
    """Why a request left its slot.  ``PREEMPTED`` is transient (the request
    re-queues and later retires with a terminal reason); the rest are
    terminal.  The engine counts one ``retired_<reason>`` metric per
    terminal retirement plus a ``preemptions`` counter."""
    EOS = "eos"
    MAX_NEW = "max_new"
    DEADLINE = "deadline"
    CANCELLED = "cancelled"
    PREEMPTED = "preempted"
    FAULTED = "faulted"


@dataclasses.dataclass
class Request:
    """One generation request moving through the continuous-batching engine.

    ``tokens`` accumulates generated ids (the prompt is not echoed); the
    request is finished when EOS is sampled or ``max_new`` tokens exist.
    ``deadline_s`` is a wall-clock budget measured from submit; expiry
    retires the request with ``RetireReason.DEADLINE`` (partial output
    kept).  After a preemption, ``resume_token`` holds the last emitted
    token — re-admission prefills ``prompt + tokens[:-1]`` and seeds decode
    with it instead of re-sampling (so a preempted greedy request's output
    is identical to an undisturbed run)."""
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    deadline_s: Optional[float] = None   # seconds from submit; None = no limit
    retire_reason: Optional[RetireReason] = None
    preemptions: int = 0
    resume_token: Optional[int] = None   # set while re-queued after preemption
    admit_seq: int = -1                  # admission order (victim picking)
    # telemetry timestamps (perf_counter seconds); 0.0 = not yet reached
    t_submit: float = 0.0
    t_first: float = 0.0        # first generated token (TTFT endpoint)
    t_done: float = 0.0

    @property
    def expired(self) -> bool:
        return (self.deadline_s is not None
                and time.perf_counter() - self.t_submit > self.deadline_s)


class SlotManager:
    """Fixed-capacity slot allocator: which cache row belongs to which
    request.  ``lengths[slot]`` tracks tokens written to that cache row
    (prompt + decode writes); the engine retires a slot when it reaches the
    cache length, so a request can never overrun its row."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self.active: Dict[int, Request] = {}
        self.lengths = np.zeros((n_slots,), np.int64)   # tokens written so far

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def alloc(self, req: Request, prompt_len: int) -> int:
        slot = self._free.pop()
        req.slot = slot
        self.active[slot] = req
        self.lengths[slot] = prompt_len
        return slot

    def release(self, slot: int) -> None:
        req = self.active.pop(slot)
        req.slot = -1
        self.lengths[slot] = 0
        self._free.append(slot)


class PageAllocator:
    """Ref-counted allocator over the physical KV pages ``1 .. n_pages-1``
    (page 0 is the reserved scratch page — never handed out; dead
    block-table entries point at it).

    ``alloc`` hands out a free page at refcount 1; ``retain`` adds a
    reference (prefix sharing); ``release`` drops one and returns the page
    to the free pool when the count hits zero.  Invariants (the
    property-based tests drive them under randomized schedules): a page is
    never handed out twice while referenced, refcounts never go negative,
    and every allocated page eventually returns to the pool."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("need at least one non-scratch page")
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self.refcount = np.zeros((n_pages,), np.int32)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        if faults.active() and faults.fire("page_exhaustion"):
            raise PageExhausted("page pool exhausted (injected)")
        if not self._free:
            raise PageExhausted("page pool exhausted")
        page = self._free.pop()
        if self.refcount[page] != 0:
            # a free-list page with a live refcount means the accounting is
            # already corrupt — refuse to hand it out a second time
            raise PageAccountingError(
                f"free-list page {page} has refcount "
                f"{int(self.refcount[page])}")
        self.refcount[page] = 1
        return page

    def retain(self, page: int) -> None:
        if not 1 <= page < self.n_pages or self.refcount[page] <= 0:
            raise PageAccountingError(
                f"retain of unallocated page {page} (refcount "
                f"{int(self.refcount[page]) if 0 <= page < self.n_pages else 'oob'})")
        self.refcount[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; True when the page went back to the pool."""
        if not 1 <= page < self.n_pages or self.refcount[page] <= 0:
            raise PageAccountingError(
                f"release of unallocated page {page}: double release or "
                "stale block-table entry")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)
            return True
        return False


class ContinuousBatchingEngine:
    """Slot-based continuous batching: heterogeneous requests share ONE
    jitted padded-batch decode step.

    * ``submit`` queues a request; it is admitted as soon as a slot frees.
    * admission runs a single-request single-pass prefill (jitted per prompt
      length) and writes the resulting cache row into the request's slot —
      the per-slot KV index keeps every slot's position independent.
    * ``step`` advances ALL slots one token with one jitted call, harvests
      tokens for active slots, retires finished sequences (EOS or length
      budget) and back-fills freed slots from the queue.  Free slots ride
      along as padding — their lanes compute garbage that is never read.
    * ``run`` steps until queue and slots drain; returns {uid: tokens}.

    Greedy when ``temperature <= 0``; otherwise softmax sampling with a
    per-step folded key (shared across slots).

    Paged mode (``page_size=N``): the slot caches become a shared page pool
    with per-slot block tables; admission reserves pages for the request's
    actual length instead of a worst-case ``max_len`` row (see the module
    docstring).  ``n_pages`` bounds the pool (default: enough for every
    slot at full ``max_len`` — shrink it to trade worst-case capacity for
    HBM); ``prefill_chunk`` prefills prompts in chunks interleaved with
    decode steps; ``prefix_cache=True`` shares full prompt-prefix pages
    between requests.  Only the pure-KV families (lm / moe) support paged
    mode; ``REPRO_PAGED_KV=off`` forces dense rings.
    """

    def __init__(self, cfg: ModelCfg, params, *, n_slots: int = 8,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 temperature: float = 0.0, cache_dtype=jnp.float32,
                 seed: int = 0, autotune: bool = False,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 nan_guard: bool = True,
                 preempt: bool = True,
                 report_every_s: Optional[float] = None,
                 log_fn: Callable = print):
        if cfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                "continuous batching currently serves token-only families")
        if os.environ.get("REPRO_PAGED_KV", "").lower() in ("0", "off",
                                                            "dense"):
            page_size = None                       # escape hatch
        if page_size is not None and cfg.family not in ("lm", "moe"):
            raise NotImplementedError(
                "paged KV serves the pure-KV families (lm/moe); SSM and "
                "hybrid caches keep dense rings")
        self.paged = page_size is not None
        self.page_size = page_size
        self._autotune = autotune
        self._mesh_kw = _tuning_mesh_kwargs()
        if autotune:
            from repro.perf.autotune import ensure_tuned_for_model

            # tune for the padded decode batch before the step jit traces
            # (kv_len covers the flash-decode tiles over the slot caches);
            # prefill buckets are tuned per prompt length in _prefill_one
            ensure_tuned_for_model(cfg, tokens=max(n_slots, 1),
                                   kv_len=max_len, page_size=page_size,
                                   **self._mesh_kw)
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = n_slots, max_len
        self.eos_id, self.temperature = eos_id, float(temperature)
        self.cache_dtype = cache_dtype
        self.metrics = obs.MetricsRegistry()
        self.report_every_s = report_every_s
        self.log = log_fn
        self._last_report = time.perf_counter()
        if self.paged:
            self.n_blocks = -(-max_len // page_size)      # blocks per slot
            if n_pages is None:
                n_pages = 1 + n_slots * self.n_blocks     # + scratch page 0
            self.pages = PageAllocator(n_pages)
            self.cache = model.init_cache(cfg, n_slots, max_len, cache_dtype,
                                          page_size=page_size,
                                          n_pages=n_pages)
            # HOST truth: block tables + reserved-block counts per slot.
            # The device copies are re-pushed before every batch step.
            self._bt = np.zeros((n_slots, self.n_blocks), np.int32)
            self._nblk = np.zeros((n_slots,), np.int32)
            self._prefilling: Dict[int, int] = {}   # slot -> tokens prefilled
            self.prefill_chunk = prefill_chunk
            self.prefix_cache = bool(prefix_cache)
            self._prefix: Dict[bytes, int] = {}       # hash chain -> page id
            self._page_hash: Dict[int, bytes] = {}    # page id -> hash key
            self._chunk_fns: Dict[int, callable] = {}
            self.stats = {"prefill_chunks": 0, "prefill_tokens": 0,
                          "prefix_hits": 0, "prefix_pages_shared": 0}
        else:
            self.cache = model.init_cache(cfg, n_slots, max_len, cache_dtype,
                                          per_slot=True)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)   # last token per slot
        self.slots = SlotManager(n_slots)
        self.queue: collections.deque = collections.deque()
        self.finished: List[Request] = []
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(seed)
        self._clock = 0
        self._prefills: Dict[int, callable] = {}
        # resilience state: NaN guard + demotion ladder + victim preemption
        self.nan_guard = bool(nan_guard)
        self.preempt_enabled = bool(preempt)
        self._admit_seq = itertools.count()
        # demotion rungs in order; each entry = (name, env var, demoted
        # value).  _demote() sets the env var and re-jits, so the next
        # trace-time route decision lands one rung lower.
        self._ladder = [("quant", "REPRO_KERNEL_QUANT", "off"),
                        ("ff", "REPRO_KERNEL_FF", "split"),
                        ("attn", "REPRO_KERNEL_ATTN", "xla")]
        self.demoted: List[str] = []
        self._env_before: Dict[str, Optional[str]] = {}
        self._batch_step = jax.jit(self._make_batch_step())
        self._write_slot = jax.jit(self._write_slot_impl)

    # -- jitted pieces ------------------------------------------------------
    def _make_batch_step(self):
        cfg, temperature = self.cfg, self.temperature
        guard = self.nan_guard

        def batch_step(params, cache, tok, key, poison):
            logits, cache = model.decode_step(cfg, params, cache, tok)
            # ``poison`` is the nan_logits fault-injection flag (a traced
            # scalar, so one compilation covers clean and poisoned steps)
            logits = jnp.where(poison, jnp.float32(jnp.nan), logits)
            if guard:
                # per-lane NaN/Inf detection: one reduction over the logits
                # (tiny next to the model matmuls), checked on the HOST
                # after the harvest already blocks on this step anyway.
                bad = ~jnp.all(jnp.isfinite(logits), axis=(1, 2))
            else:
                bad = jnp.zeros((logits.shape[0],), bool)
            nxt = sample_token(logits, temperature,
                               key if temperature > 0.0 else None)
            return nxt.astype(jnp.int32), bad, cache

        return batch_step

    def _prefill_one(self, prompt_len: int):
        """Single-request prefill, jitted once per distinct prompt length
        (exact-shape compilation; length bucketing is future work)."""
        if prompt_len in self._prefills:
            return self._prefills[prompt_len]
        if self._autotune:
            from repro.perf.autotune import ensure_tuned_for_model

            # the admission prefill sees prompt_len rows; tune that bucket
            # before this trace bakes its tiles in (cache hits are cheap)
            ensure_tuned_for_model(self.cfg, tokens=prompt_len,
                                   seq_len=prompt_len, **self._mesh_kw)
        cfg, max_len, dtype = self.cfg, self.max_len, self.cache_dtype
        temperature = self.temperature

        def prefill_one(params, tokens, key):
            cache = model.init_cache(cfg, 1, max_len, dtype, per_slot=True)
            logits, cache = model.prefill(cfg, params, cache, tokens)
            tok = sample_token(logits, temperature,
                               key if temperature > 0.0 else None)
            return tok.astype(jnp.int32), cache

        self._prefills[prompt_len] = jax.jit(prefill_one)
        return self._prefills[prompt_len]

    @staticmethod
    def _write_slot_impl(batch_cache, one_cache, slot):
        """Scatter a single-request cache (batch axis 1, size 1) into row
        ``slot`` of the slot-batched cache — resets that slot's KV index."""
        return jax.tree.map(
            lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1),
            batch_cache, one_cache)

    # -- paged machinery ----------------------------------------------------
    def _chunk_fn(self, chunk_len: int):
        """Paged prefill of one prompt chunk for one slot, jitted per chunk
        length.  The chunk runs as a B=1 "view" over the SHARED page pools:
        K/V writes land directly in the slot's reserved pages (no per-slot
        dense cache, no slot-row scatter copy), while the device block
        table / write index are synthesized per call from host truth —
        ``_sync_control`` rebuilds the real device copies before every
        batch decode step, so only the pools need merging back."""
        if chunk_len in self._chunk_fns:
            return self._chunk_fns[chunk_len]
        if self._autotune:
            from repro.perf.autotune import ensure_tuned_for_model

            ensure_tuned_for_model(self.cfg, tokens=chunk_len,
                                   seq_len=chunk_len, **self._mesh_kw)
        cfg, temperature = self.cfg, self.temperature
        n_layers = self.cfg.n_layers

        def chunk(params, cache, tokens, bt_row, pos, key):
            kv = cache["kv"]
            # every pool leaf rides the view (scales_k/scales_v exist only
            # for int8-quantized pools) so chunked prefill writes quantized
            # pages exactly like the decode path
            pools = [nm for nm in ("pages_k", "pages_v", "scales_k",
                                   "scales_v") if nm in kv]
            view = {"kv": {
                **{nm: kv[nm] for nm in pools},
                "block_table": jnp.broadcast_to(
                    bt_row[None, None], (n_layers, 1) + bt_row.shape),
                "idx": jnp.full((n_layers, 1), pos, jnp.int32),
            }}
            logits, view = model.prefill(cfg, params, view, tokens)
            tok = sample_token(logits, temperature,
                               key if temperature > 0.0 else None)
            new_cache = dict(cache)
            new_cache["kv"] = dict(kv)
            for nm in pools:
                new_cache["kv"][nm] = view["kv"][nm]
            return tok.astype(jnp.int32), new_cache

        self._chunk_fns[chunk_len] = jax.jit(chunk)
        return self._chunk_fns[chunk_len]

    def _prefill_tokens(self, req: Request) -> np.ndarray:
        """The sequence this request's (re-)prefill must write: the prompt,
        plus — after a preemption — every generated token except the last
        emitted one (which seeds decode via ``resume_token`` instead)."""
        if req.resume_token is None:
            return req.prompt
        return np.concatenate(
            [req.prompt, np.asarray(req.tokens[:-1], np.int32)])

    def _advance_prefill(self, slot: int) -> None:
        """Prefill the next chunk of ``slot``'s prompt; on the last chunk,
        sample the first token and hand the slot to the decode batch.  A
        resumed (post-preemption) request re-seeds decode with its last
        emitted token instead of sampling — its output stream continues
        exactly where the preemption cut it."""
        pos = self._prefilling[slot]
        req = self.slots.active[slot]
        seq = self._prefill_tokens(req)
        S = len(seq)
        chunk = (S - pos if not self.prefill_chunk
                 else min(self.prefill_chunk, S - pos))
        self._clock += 1
        key = jax.random.fold_in(self._key, self._clock)
        fn = self._chunk_fn(chunk)
        with obs.span("prefill_chunk", cat="serve", slot=slot, pos=pos,
                      chunk=chunk, prompt_len=S,
                      resumed=req.resume_token is not None):
            tok, self.cache = fn(
                self.params, self.cache,
                jnp.asarray(seq[pos:pos + chunk])[None, :],
                jnp.asarray(self._bt[slot]), pos, key)
            if obs.enabled():
                # only the traced run pays the sync: untraced chunks stay
                # async (the decode harvest blocks once per engine step)
                tok = jax.block_until_ready(tok)
        self.stats["prefill_chunks"] += 1
        self.stats["prefill_tokens"] += chunk
        self.metrics.counter("prefill_tokens").inc(chunk)
        pos += chunk
        if pos >= S:
            del self._prefilling[slot]
            self._register_prefix(req, slot)
            if req.resume_token is not None:
                # resumed: the last emitted token seeds decode; nothing new
                # is emitted (the sampled tok is a re-derivation of it).
                self.tokens = self.tokens.at[slot, 0].set(req.resume_token)
                req.resume_token = None
                req.retire_reason = None
            else:
                self.tokens = self.tokens.at[slot].set(tok[0])
                self._emit(req, int(tok[0, 0]))
        else:
            self._prefilling[slot] = pos

    def _prefix_keys(self, prompt: np.ndarray) -> List[bytes]:
        """Rolling hash chain over the FULL pages of a prompt: key i commits
        to ``prompt[:(i+1) * page_size]``, so equal keys mean equal token
        prefixes (and therefore equal K/V page contents)."""
        h = hashlib.sha1()
        keys = []
        P = self.page_size
        for i in range(len(prompt) // P):
            h.update(np.ascontiguousarray(prompt[i * P:(i + 1) * P])
                     .tobytes())
            keys.append(h.digest())
        return keys

    def _match_prefix(self, prompt: np.ndarray):
        """Longest registered full-page prefix of ``prompt``, capped so at
        least ONE prompt token remains to prefill (the last-token logits
        that seed decode must come from this request's own forward)."""
        if not self.prefix_cache:
            return 0, []
        limit = (len(prompt) - 1) // self.page_size
        pages: List[int] = []
        for key in self._prefix_keys(prompt)[:limit]:
            pid = self._prefix.get(key)
            if pid is None:
                break
            pages.append(pid)
        return len(pages), pages

    def _register_prefix(self, req: Request, slot: int) -> None:
        """After a prompt finishes prefilling, publish its full pages for
        future sharers.  The registry does NOT hold a reference: entries
        drop when their page goes back to the pool (last sharer retires)."""
        if not self.prefix_cache:
            return
        for i, key in enumerate(self._prefix_keys(req.prompt)):
            pid = int(self._bt[slot, i])
            if key in self._prefix or pid in self._page_hash:
                continue
            self._prefix[key] = pid
            self._page_hash[pid] = key

    def _release_page(self, page: int) -> None:
        if self.pages.release(page):        # back in the pool: unpublish
            key = self._page_hash.pop(page, None)
            if key is not None:
                self._prefix.pop(key, None)

    def _release_slot_pages(self, slot: int) -> None:
        for i in range(int(self._nblk[slot])):
            self._release_page(int(self._bt[slot, i]))
        self._bt[slot] = 0
        self._nblk[slot] = 0

    def _sync_control(self) -> None:
        """Push HOST-truth block tables / write indices to the device cache.
        Decoding lanes get their true table and length; free and
        mid-prefill lanes are pointed at scratch (page 0, index 0) so their
        padding-lane decode writes can never touch a live page."""
        with obs.span("sync_control", cat="serve"):
            bt = self._bt.copy()
            idx = self.slots.lengths.astype(np.int32)
            for s in range(self.n_slots):
                if s not in self.slots.active or s in self._prefilling:
                    bt[s] = 0
                    idx[s] = 0
            n_layers = self.cfg.n_layers
            self.cache = dict(self.cache)
            self.cache["kv"] = dict(self.cache["kv"])
            self.cache["kv"]["block_table"] = jnp.asarray(
                np.broadcast_to(bt[None], (n_layers,) + bt.shape))
            self.cache["kv"]["idx"] = jnp.asarray(
                np.broadcast_to(idx[None], (n_layers,) + idx.shape))

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s request to relieve page-pool pressure: release
        its pages, remember its last emitted token, and re-queue it at the
        BACK of the queue (it yielded its capacity; it rejoins behind the
        waiters).  The redo is cheap: one re-prefill pass over
        ``prompt + generated[:-1]``, cheaper still when prefix caching
        still holds its prompt pages — and under greedy decoding the
        resumed output stream is token-identical to an undisturbed run."""
        req = self.slots.active[slot]
        with obs.span("preempt", cat="serve", uid=req.uid, slot=slot,
                      generated=len(req.tokens),
                      pages_freed=int(self._nblk[slot])):
            req.retire_reason = RetireReason.PREEMPTED
            req.preemptions += 1
            req.resume_token = req.tokens[-1]
            self._release_slot_pages(slot)
            self.slots.release(slot)
            self.queue.append(req)
            self.metrics.counter("preemptions").inc()
            obs.instant("preempted", cat="serve", uid=req.uid)
        self._update_occupancy()

    def _preempt_for(self, req: Request, pages_short: int) -> bool:
        """Free at least ``pages_short`` pages for ``req`` by preempting
        victims, youngest-admitted first (least sunk decode work).  A
        victim must be decoding (not mid-prefill, at least one token) with
        every page private (refcount 1 — releasing a prefix-shared page
        frees nothing).  Only a FRESH request (never itself preempted) may
        trigger preemption; since a fresh request admits exactly once,
        total preemptions are bounded by total submissions — resumed
        requests head-of-line block instead, so preemption cannot cycle."""
        if not self.preempt_enabled or req.preemptions:
            return False
        while self.pages.free_pages < pages_short:
            victims = [
                s for s, r in self.slots.active.items()
                if s not in self._prefilling and r.tokens
                and all(self.pages.refcount[int(self._bt[s, i])] == 1
                        for i in range(int(self._nblk[s])))]
            if not victims:
                return False
            self._preempt(max(victims,
                              key=lambda s: self.slots.active[s].admit_seq))
        return True

    def _admit_paged(self) -> None:
        """Admit queued requests while a slot AND enough pages are free.

        Reservation is up-front and exact: ``ceil((S + max_new - 1) / P)``
        pages cover every K/V write this request can make, so admission is
        the only place that can block — an admitted request never OOMs.
        Prefix-matched pages are retained (shared), not re-allocated, and
        their tokens are skipped by the prefill.  When the head request
        does not fit, the engine first tries victim preemption
        (:meth:`_preempt_for`); only when no eligible victim exists does it
        head-of-line block.  A mid-admission :class:`PageExhausted` (the
        ``page_exhaustion`` fault site, or a racing consumer) rolls the
        partial reservation back and re-queues the request at the front —
        pages never leak."""
        while self.queue and self.slots.free_slots:
            req = self.queue[0]
            seq = self._prefill_tokens(req)
            # total KV rows this request will ever hold is invariant under
            # preemption: prompt + max_new - 1 (generated tokens move from
            # "decode writes" to "prefill writes" on resume)
            rows = len(req.prompt) + req.max_new - 1
            nblk = max(1, -(-rows // self.page_size))
            m, shared = self._match_prefix(seq)
            if self.pages.free_pages < nblk - m:
                if not self._preempt_for(req, nblk - m):
                    return      # no eligible victim: head-of-line block
                # preemption may have unpublished prefix pages — re-match
                m, shared = self._match_prefix(seq)
                if self.pages.free_pages < nblk - m:
                    return
            self.queue.popleft()
            slot = self.slots.alloc(req, len(seq))
            req.admit_seq = next(self._admit_seq)
            with obs.span("admit", cat="serve", uid=req.uid, slot=slot,
                          pages=nblk, prefix_pages=m,
                          resumed=req.resume_token is not None,
                          queued=len(self.queue)):
                try:
                    for i, pid in enumerate(shared):
                        self.pages.retain(pid)
                        self._bt[slot, i] = pid
                        self._nblk[slot] = i + 1
                    for i in range(m, nblk):
                        self._bt[slot, i] = self.pages.alloc()
                        self._nblk[slot] = i + 1
                except PageExhausted:
                    # roll the partial reservation back; the request goes
                    # back to the head of the queue and retries later
                    self._release_slot_pages(slot)
                    self.slots.release(slot)
                    self.queue.appendleft(req)
                    self.metrics.counter("admission_backoffs").inc()
                    obs.instant("admit_backoff", cat="serve", uid=req.uid)
                    self._update_occupancy()
                    return
                if m:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_pages_shared"] += m
                    self.metrics.counter("prefix_hits").inc()
                    self.metrics.counter("prefix_tokens_skipped").inc(
                        m * self.page_size)
                self._update_occupancy()
                self._prefilling[slot] = m * self.page_size
                if not self.prefill_chunk:
                    # unchunked: the whole remaining prompt is one chunk, so
                    # admission completes the prefill exactly like dense mode
                    self._advance_prefill(slot)

    # -- request lifecycle --------------------------------------------------
    def submit(self, prompt, max_new: int, *,
               deadline_s: Optional[float] = None) -> int:
        """Queue a prompt ((S,) ints) for up to ``max_new`` generated tokens.
        Returns the request uid (key into :meth:`run`'s result).

        ``deadline_s`` is a wall-clock budget measured from now; when it
        expires the request retires with ``RetireReason.DEADLINE`` (keeping
        whatever it generated).  Requests that can NEVER be served raise
        :class:`AdmissionError` here, before queueing."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + max_new - 1 > self.max_len:
            self.metrics.counter("admission_rejects").inc()
            obs.instant("admission_reject", cat="serve", reason="max_len",
                        prompt_len=int(prompt.size), max_new=max_new)
            raise AdmissionError(
                f"prompt {prompt.size} + {max_new} new tokens exceeds "
                f"max_len {self.max_len}")
        if max_new < 1:
            self.metrics.counter("admission_rejects").inc()
            raise AdmissionError("max_new must be >= 1")
        if deadline_s is not None and deadline_s <= 0:
            self.metrics.counter("admission_rejects").inc()
            raise AdmissionError(f"deadline_s must be positive, "
                                 f"got {deadline_s}")
        if self.paged:
            need = max(1, -(-(prompt.size + max_new - 1) // self.page_size))
            if need > self.pages.n_pages - 1:
                self.metrics.counter("admission_rejects").inc()
                obs.instant("admission_reject", cat="serve",
                            reason="never_fits", pages_needed=need)
                raise AdmissionError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.pages.n_pages - 1}")
        req = Request(uid=next(self._uid), prompt=prompt, max_new=max_new,
                      deadline_s=deadline_s, t_submit=time.perf_counter())
        self.queue.append(req)
        self.metrics.counter("requests_submitted").inc()
        self._admit()
        self._update_occupancy()
        return req.uid

    def cancel(self, uid: int) -> bool:
        """Cancel a queued or running request: it retires immediately with
        ``RetireReason.CANCELLED``, keeping any tokens generated so far.
        Returns False when ``uid`` is unknown or already finished."""
        for i, req in enumerate(self.queue):
            if req.uid == uid:
                del self.queue[i]
                self._retire(req, RetireReason.CANCELLED)
                self._update_occupancy()
                return True
        for slot, req in list(self.slots.active.items()):
            if req.uid == uid:
                self._retire(req, RetireReason.CANCELLED)
                self._admit()
                return True
        return False

    def _check_deadlines(self) -> None:
        """Retire every queued / active request whose deadline expired.
        Runs once per engine step — a deadline is enforced to one decode
        step's granularity, which is the engine's scheduling quantum."""
        expired_q = [r for r in self.queue if r.expired]
        if expired_q:
            live = {id(r) for r in expired_q}
            self.queue = collections.deque(
                r for r in self.queue if id(r) not in live)
        for req in expired_q:
            self._retire(req, RetireReason.DEADLINE)
        for slot, req in list(self.slots.active.items()):
            if req.expired:
                self._retire(req, RetireReason.DEADLINE)
        if expired_q:
            self._update_occupancy()

    def _admit(self) -> None:
        """Move queued requests into free slots (prefill + slot write)."""
        if self.paged:
            self._admit_paged()
            return
        while self.queue and self.slots.free_slots:
            req = self.queue.popleft()
            slot = self.slots.alloc(req, len(req.prompt))
            with obs.span("admit", cat="serve", uid=req.uid, slot=slot,
                          queued=len(self.queue)):
                self._clock += 1
                key = jax.random.fold_in(self._key, self._clock)
                fn = self._prefill_one(len(req.prompt))
                with obs.span("prefill", cat="serve", slot=slot,
                              prompt_len=len(req.prompt)):
                    tok0, cache1 = fn(self.params,
                                      jnp.asarray(req.prompt)[None, :], key)
                    self.cache = self._write_slot(self.cache, cache1, slot)
                    tok0 = jax.block_until_ready(tok0)
                self.tokens = self.tokens.at[slot].set(tok0[0])
                self._emit(req, int(tok0[0, 0]))

    def _emit(self, req: Request, token: int) -> None:
        req.tokens.append(token)
        now = time.perf_counter()
        if len(req.tokens) == 1:
            req.t_first = now
            self.metrics.histogram("ttft_s").observe(now - req.t_submit)
        self.metrics.counter("tokens_generated").inc()
        if self.eos_id is not None and token == self.eos_id:
            self._retire(req, RetireReason.EOS)
        elif (len(req.tokens) >= req.max_new
              or self.slots.lengths[req.slot] >= self.max_len):  # row full
            self._retire(req, RetireReason.MAX_NEW)

    def _retire(self, req: Request, reason: RetireReason) -> None:
        """Terminal retirement: record the reason, free the slot + pages
        (when the request holds any), and move it to ``finished``.  Every
        exit path — EOS, budget, deadline, cancel, fault — funnels through
        here, so the ``retired_<reason>`` counters are exact."""
        now = time.perf_counter()
        with obs.span("retire", cat="serve", uid=req.uid, slot=req.slot,
                      reason=reason.value, n_tokens=len(req.tokens)):
            req.retire_reason = reason
            req.t_done = now
            if len(req.tokens) > 1:
                self.metrics.histogram("itl_s").observe(
                    (now - req.t_first) / (len(req.tokens) - 1))
            self.metrics.counter("requests_finished").inc()
            self.metrics.counter(f"retired_{reason.value}").inc()
            if req.slot >= 0:
                if self.paged:
                    self._prefilling.pop(req.slot, None)
                    self._release_slot_pages(req.slot)
                self.slots.release(req.slot)
                self._update_occupancy()
            self.finished.append(req)

    # -- NaN guard + demotion ladder ----------------------------------------
    def _rebuild_step(self) -> None:
        """Re-jit every route-sensitive compiled function.  Kernel routes
        are decided at trace time from the ``REPRO_KERNEL_*`` env, so a
        demotion is exactly: set the env var, drop the compiled functions,
        let the next call re-trace onto the lower route."""
        self._batch_step = jax.jit(self._make_batch_step())
        self._prefills.clear()
        if self.paged:
            self._chunk_fns.clear()

    def _demote_next(self) -> bool:
        """Walk ONE rung down the route ladder (quant -> fp, fused ->
        split, flash -> xla) and re-jit.  Returns False when every rung is
        already demoted — the caller then stops retrying and retires the
        still-bad lanes as ``FAULTED``."""
        for name, var, value in self._ladder:
            if name in self.demoted:
                continue
            self.demoted.append(name)
            if os.environ.get(var) == value:
                continue            # already on the safe route: next rung
            self._env_before.setdefault(var, os.environ.get(var))
            os.environ[var] = value
            obs.route_event("demote", name, var=var, value=value)
            self.metrics.counter("demotions").inc()
            self._rebuild_step()
            return True
        return False

    def reset_demotions(self) -> None:
        """Restore the pre-demotion kernel routes and re-jit (operator
        action after the underlying fault — e.g. corrupt quantized blocks —
        has been fixed; also test hygiene)."""
        if not self.demoted and not self._env_before:
            return
        for var, old in self._env_before.items():
            if old is None:
                os.environ.pop(var, None)
            else:
                os.environ[var] = old
        self._env_before.clear()
        self.demoted.clear()
        self._rebuild_step()

    def _decode_once(self, decoding: List[int]):
        """Run the batch decode step with NaN containment.  Protocol on a
        non-finite detection: (1) retry ONCE on the same route — the jitted
        step is pure (no donation), so old tokens/cache are intact and a
        transient fault costs one extra step, no demotion; (2) demote one
        ladder rung per further attempt and re-jit; (3) ladder exhausted —
        commit the step and let the caller retire the still-bad decoding
        lanes as ``FAULTED``.  Returns (emitted tokens, bad-lane mask)."""
        old_tokens, old_cache = self.tokens, self.cache
        attempt = 0
        while True:
            self._clock += 1
            key = jax.random.fold_in(self._key, self._clock)
            poison = bool(faults.fire("nan_logits")) if faults.active() \
                else False
            tok, bad, cache = self._batch_step(
                self.params, old_cache, old_tokens, key, poison)
            bad_h = np.asarray(bad)       # blocks on the step
            bad_slots = [s for s in decoding if bad_h[s]]
            if not bad_slots:
                break
            self.metrics.counter("nan_steps").inc()
            obs.instant("nan_detected", cat="serve", attempt=attempt,
                        slots=len(bad_slots))
            if attempt > 0 and not self._demote_next():
                obs.instant("nan_unrecovered", cat="serve",
                            slots=len(bad_slots))
                break
            attempt += 1
        self.tokens, self.cache = tok, cache
        return np.asarray(tok[:, 0]), bad_h

    def step(self) -> List[Request]:
        """One padded-batch decode step; returns requests finished this step.

        Paged mode interleaves: each mid-prefill slot advances ONE chunk
        first (a slot whose prompt completes joins the decode batch in the
        same step), then every decoding slot takes its token.  Expired
        deadlines are swept first (one-step granularity); lanes whose
        logits stay non-finite after the retry + demotion ladder retire as
        ``FAULTED``."""
        before = len(self.finished)
        self._check_deadlines()
        if faults.active():
            sp = faults.fire("slow_step")
            if sp is not None and sp.ms:
                with obs.span("slow_step_fault", cat="fault", ms=sp.ms):
                    time.sleep(sp.ms / 1000.0)
        if self.paged and self._prefilling:
            for slot in sorted(self._prefilling):
                self._advance_prefill(slot)
            self._admit()           # chunk completions may have freed slots
        decoding = [s for s in self.slots.active
                    if not (self.paged and s in self._prefilling)]
        if not decoding:
            self._admit()
            self._maybe_report()
            return self.finished[before:]
        if self.paged:
            self._sync_control()
        t0 = time.perf_counter()
        with obs.span("decode_step", cat="serve", batch=len(decoding)):
            emitted, bad = self._decode_once(decoding)
        self.metrics.histogram("decode_step_s").observe(
            time.perf_counter() - t0)
        for slot in decoding:
            req = self.slots.active[slot]
            if bad[slot]:
                # non-finite logits survived the full ladder: this lane's
                # sampled token is garbage — retire without emitting it
                self._retire(req, RetireReason.FAULTED)
                continue
            self.slots.lengths[slot] += 1
            self._emit(req, int(emitted[slot]))
        self._admit()
        self._update_occupancy()
        self._maybe_report()
        return self.finished[before:]

    # -- telemetry ----------------------------------------------------------
    def _update_occupancy(self) -> None:
        """Refresh the load gauges (queue depth, active slots, page-pool
        occupancy) — called wherever they can change, so their high-water
        marks are exact."""
        m = self.metrics
        m.gauge("queue_depth").set(len(self.queue))
        m.gauge("active_slots").set(len(self.slots.active))
        if self.paged:
            m.gauge("page_pool_used").set(
                self.pages.n_pages - 1 - self.pages.free_pages)

    def _maybe_report(self) -> None:
        if self.report_every_s is None:
            return
        now = time.perf_counter()
        if now - self._last_report >= self.report_every_s:
            self._last_report = now
            self.log(f"[serve] {obs.format_serving_line(self.metrics)}")

    def metrics_summary(self) -> dict:
        """JSON-ready snapshot of the serving metric set (the payload of
        ``launch/serve.py --metrics-json``).  When a fault schedule is
        live, the per-site check/fire tallies ride along under
        ``"faults"``, and any demoted ladder rungs under ``"demoted"``."""
        snap = self.metrics.snapshot()
        if faults.active():
            snap["faults"] = faults.snapshot()
        if self.demoted:
            snap["demoted"] = list(self.demoted)
        return snap

    def format_summary(self) -> str:
        return obs.format_serving_line(self.metrics)

    def run(self, deadline_s: Optional[float] = None) -> Dict[int, List[int]]:
        """Step until every queued/active request finishes.
        Returns {uid: generated token list} (generated tokens survive for
        every terminal reason — a deadline-retired request keeps its
        partial output).  ``deadline_s`` bounds the WHOLE drain; overrun
        raises :class:`DeadlineExceeded` with all requests still intact."""
        t0 = time.perf_counter()
        while self.slots.active or self.queue:
            if (deadline_s is not None
                    and time.perf_counter() - t0 > deadline_s):
                raise DeadlineExceeded(
                    f"run() exceeded its {deadline_s}s drain budget with "
                    f"{len(self.slots.active)} active / {len(self.queue)} "
                    "queued requests")
            self.step()
        self._update_occupancy()
        out = {r.uid: r.tokens for r in self.finished}
        self.finished = []
        return out
