"""Batched serving engine: prefill + step-wise decode over a persistent cache.

``serve_step`` (one new token against a long KV/SSM cache) is exactly what the
decode_* dry-run shapes lower.  The engine adds greedy/temperature sampling and
a simple continuous-batching slot model on top.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.config import ModelCfg


def make_serve_step(cfg: ModelCfg):
    """(params, cache, tokens(B,1)) -> (logits, new_cache)."""
    def serve_step(params, cache, tokens):
        return model.decode_step(cfg, params, cache, tokens)
    return serve_step


def prefill(cfg: ModelCfg, params, cache, tokens, frames=None):
    """Fill the cache with a prompt (teacher-forced pass with cache writes).

    Returns (last_logits (B,1,V), cache)."""
    if cfg.family == "encdec" and frames is not None:
        cache = model.prefill_cross(cfg, params, cache, frames)
    B, S = tokens.shape
    step = make_serve_step(cfg)
    logits = None
    for t in range(S):                      # token-wise; fine for tests
        logits, cache = step(params, cache, tokens[:, t:t + 1])
    return logits, cache


class Engine:
    """Greedy/temperature batched generation."""

    def __init__(self, cfg: ModelCfg, params, max_len: int,
                 cache_dtype=jnp.float32):
        self.cfg, self.params, self.max_len = cfg, params, max_len
        self.cache_dtype = cache_dtype
        self._step = jax.jit(make_serve_step(cfg))

    def generate(self, prompt_tokens, num_new: int, *, temperature: float = 0.0,
                 key: Optional[jax.Array] = None, frames=None):
        B = prompt_tokens.shape[0]
        cache = model.init_cache(self.cfg, B, self.max_len, self.cache_dtype)
        logits, cache = prefill(self.cfg, self.params, cache, prompt_tokens,
                                frames=frames)
        out = []
        tok = self._sample(logits, temperature, key, 0)
        for i in range(num_new):
            out.append(tok)
            logits, cache = self._step(self.params, cache, tok)
            key2 = None if key is None else jax.random.fold_in(key, i + 1)
            tok = self._sample(logits, temperature, key2, i + 1)
        return jnp.concatenate(out, axis=1)

    @staticmethod
    def _sample(logits, temperature, key, i):
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits[:, -1:], axis=-1)
        return jax.random.categorical(
            key, logits[:, -1] / temperature, axis=-1)[:, None]
