"""Serving: single-pass prefill + scan-compiled decode over persistent
KV/SSM caches, with continuous batching for heterogeneous requests."""
from repro.serve.engine import (  # noqa: F401
    ContinuousBatchingEngine,
    Engine,
    Request,
    SlotManager,
    make_serve_step,
    prefill,
    prefill_tokenwise,
    sample_token,
)
