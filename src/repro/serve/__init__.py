"""Serving: single-pass prefill + scan-compiled decode over persistent
KV/SSM caches, with continuous batching for heterogeneous requests and an
optional paged KV cache (page pool + block tables + prefix sharing)."""
from repro.serve.engine import (  # noqa: F401
    ContinuousBatchingEngine,
    Engine,
    PageAllocator,
    Request,
    RetireReason,
    SlotManager,
    make_serve_step,
    prefill,
    prefill_tokenwise,
    sample_token,
)
