"""Serving: prefill + batched decode over persistent KV/SSM caches."""
from repro.serve.engine import Engine, make_serve_step, prefill  # noqa: F401
