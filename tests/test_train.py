"""Optimizer correctness, schedules, accumulation, fault-tolerant loop."""
import signal
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import factory
from repro.data import SyntheticLM
from repro.models.config import ModelCfg
from repro.optim import AdamW, global_norm, schedule
from repro.train import Trainer, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)

TINY = ModelCfg(name="tiny", family="lm", n_layers=2, d_model=32,
                vocab_size=64, n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                linear=factory.LinearCfg(impl="dyad", n_dyad=4))


def test_adamw_matches_numpy_reference():
    opt = AdamW(lr=schedule.constant(0.1), b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0, clip_norm=None)
    p = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.5]])}
    st = opt.init(p)
    new_p, st, _ = opt.update(g, st, p)
    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.01 * gn * gn
    upd = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               np.asarray(p["w"]) - 0.1 * upd, rtol=1e-6)


def test_adamw_weight_decay_mask():
    """Norm scales must not be decayed; matrices must."""
    opt = AdamW(lr=schedule.constant(0.0), weight_decay=0.5, clip_norm=None)
    # lr=0 isolates the decay path: nothing should change at all
    p = {"norm": {"scale": jnp.ones((4,))}, "w": jnp.ones((4, 4))}
    g = jax.tree.map(jnp.zeros_like, p)
    st = opt.init(p)
    new_p, _, _ = opt.update(g, st, p)
    np.testing.assert_array_equal(np.asarray(new_p["norm"]["scale"]),
                                  np.ones(4))


def test_grad_clipping():
    opt = AdamW(lr=schedule.constant(1e-3), clip_norm=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st = opt.init(p)
    _, _, m = opt.update(g, st, p)
    assert float(m["grad_norm"]) > 100  # reported norm is pre-clip
    assert float(global_norm(g)) == 200.0


def test_schedules():
    f = schedule.warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(100))) < 1e-6
    g = schedule.warmup_linear_decay(2.0, 5, 50)
    assert abs(float(g(jnp.asarray(5))) - 2.0) < 1e-6


def test_grad_accum_equivalence():
    opt = AdamW(lr=schedule.constant(1e-3))
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=8)
    b = data.batch(0)
    s1 = init_train_state(TINY, opt, KEY)
    s2 = init_train_state(TINY, opt, KEY)
    ns1, m1 = jax.jit(make_train_step(TINY, opt))(s1, b)
    ns2, m2 = jax.jit(make_train_step(TINY.replace(grad_accum=4), opt))(s2, b)
    # gradient-level contract (tight): the accumulated gradient matches the
    # full-batch gradient up to fp32 reduction-order noise
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)
    # post-AdamW params (realistic): near-zero gradient elements amplify the
    # ~1e-8 reduction-order noise through update = g/(|g|+eps) by up to
    # 1/(4*eps), so bitwise-tight param comparison is not a sound contract
    for a, c in zip(jax.tree.leaves(ns1["params"]),
                    jax.tree.leaves(ns2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-4)


def test_training_reduces_loss():
    opt = AdamW(lr=schedule.warmup_cosine(3e-3, 5, 80))
    data = SyntheticLM(vocab_size=64, seq_len=32, global_batch=16)
    state = init_train_state(TINY, opt, KEY)
    step = jax.jit(make_train_step(TINY, opt))
    first = last = None
    for i in range(80):
        state, m = step(state, data.batch(i))
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 1.0, (first, last)


def test_trainer_checkpoint_resume_and_preemption():
    opt = AdamW(lr=schedule.constant(1e-3))
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=8)
    step = jax.jit(make_train_step(TINY, opt))
    with tempfile.TemporaryDirectory() as d:
        t1 = Trainer(step, init_train_state(TINY, opt, KEY), data,
                     ckpt_dir=d, ckpt_every=5, log_every=1000,
                     log_fn=lambda *_: None)
        s1, _ = t1.run(12)
        # fresh trainer resumes exactly
        t2 = Trainer(step, init_train_state(TINY, opt, KEY), data,
                     ckpt_dir=d, ckpt_every=1000, log_every=1000,
                     log_fn=lambda *_: None)
        t2.maybe_resume()
        assert t2.step == 12
        for a, b in zip(jax.tree.leaves(s1["params"]),
                        jax.tree.leaves(t2.state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # preemption mid-run: clean checkpoint and stop
        t2._on_preempt(signal.SIGTERM, None)
        t2.run(100)
        assert t2.step == 12   # didn't run further


def test_straggler_watchdog():
    opt = AdamW(lr=schedule.constant(1e-3))
    data = SyntheticLM(vocab_size=64, seq_len=8, global_batch=4)
    step_fn = jax.jit(make_train_step(TINY, opt))
    events = []
    import time as _time

    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 12:
            _time.sleep(0.5)      # inject a straggler
        return step_fn(state, batch)

    t = Trainer(slow_step, init_train_state(TINY, opt, KEY), data,
                straggler_factor=3.0, log_every=1000,
                on_straggler=lambda *a: events.append(a),
                log_fn=lambda *_: None)
    t.run(15)
    assert len(events) >= 1, "injected straggler not detected"


def test_master_weights_adamw_tracks_fp32():
    """bf16 params + fp32 master must track the pure-fp32 trajectory."""
    opt32 = AdamW(lr=schedule.constant(0.01), weight_decay=0.0, master=False)
    optm = AdamW(lr=schedule.constant(0.01), weight_decay=0.0, master=True)
    p32 = {"w": jnp.ones((8, 8), jnp.float32) * 0.5}
    pbf = {"w": p32["w"].astype(jnp.bfloat16)}
    s32, sm = opt32.init(p32), optm.init(pbf)
    key = jax.random.PRNGKey(0)
    for i in range(30):
        g = jax.random.normal(jax.random.fold_in(key, i), (8, 8)) * 0.1
        p32, s32, _ = opt32.update({"w": g}, s32, p32)
        pbf, sm, _ = optm.update({"w": g.astype(jnp.bfloat16)}, sm, pbf)
    assert float(jnp.abs(sm["master"]["w"] - p32["w"]).max()) < 5e-3
    assert pbf["w"].dtype == jnp.bfloat16
