"""End-to-end behaviour: the paper's central quality claim, in miniature.

DYAD is pretrained next to DENSE on the same learnable synthetic stream; the
paper's acceptance bar is DYAD >= 90% of DENSE (we check the loss-derived
accuracy proxy at tiny scale)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import factory
from repro.data import SyntheticLM
from repro.models.config import ModelCfg
from repro.optim import AdamW, schedule
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _pretrain(linear_cfg, steps=120, seed=0):
    cfg = ModelCfg(name="sys", family="lm", n_layers=2, d_model=64,
                   vocab_size=64, n_heads=4, n_kv_heads=4, head_dim=16,
                   d_ff=256, linear=linear_cfg)
    opt = AdamW(lr=schedule.warmup_cosine(3e-3, 10, steps))
    data = SyntheticLM(vocab_size=64, seq_len=32, global_batch=16, seed=seed)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(cfg, opt))
    loss = None
    for i in range(steps):
        state, m = step(state, data.batch(i))
        loss = float(m["loss"])
    return loss


def test_dyad_within_90pct_of_dense():
    """Paper Tables 2/3: DYAD is competitive (>=90%) with DENSE."""
    dense = _pretrain(factory.DENSE)
    dyad = _pretrain(factory.LinearCfg(impl="dyad", n_dyad=4, variant="it"))
    # compare "solvedness": distance from the random-guess floor
    floor = float(np.log(64))
    gain_dense = floor - dense
    gain_dyad = floor - dyad
    assert gain_dense > 0.5, f"dense failed to learn ({dense:.3f})"
    assert gain_dyad >= 0.9 * gain_dense, (dense, dyad)


def test_all_variants_learn():
    floor = float(np.log(64))
    for variant in ("it", "ot", "dt"):
        loss = _pretrain(factory.LinearCfg(impl="dyad", n_dyad=4,
                                           variant=variant), steps=80)
        assert floor - loss > 0.4, (variant, loss)


def test_arch_pool_is_complete():
    """The assignment's 10 architectures are all selectable."""
    assert len(configs.ARCHS) == 10
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        assert cfg.n_layers > 0 and cfg.vocab_size > 0
    # 40 cells = 10 archs x 4 shapes
    assert len(configs.SHAPES) == 4
