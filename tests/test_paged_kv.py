"""Paged KV cache: allocator/block-table properties under randomized
schedules, paged-vs-dense serving equivalence, the paged flash-decode
kernel vs the einsum oracle, prefix caching, and autotune integration."""
import functools
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.kernels import flash_attn as fa
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models import model
from repro.perf import autotune
from repro.perf.autotune import BlockCache, tune_key
from repro.serve import ContinuousBatchingEngine, PageAllocator

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def cache(tmp_path):
    """Isolated BlockCache installed as the process singleton."""
    c = BlockCache(user_path=str(tmp_path / "blocks.json"),
                   defaults_path=str(tmp_path / "defaults.json"))
    autotune.reset_cache(c)
    yield c
    autotune.reset_cache(None)


@functools.lru_cache(maxsize=None)
def _small_model():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    return cfg, model.init_params(cfg, KEY)


# -- allocator properties -----------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10 ** 6), n_pages=st.integers(2, 17))
def test_page_allocator_properties(seed, n_pages):
    """Randomized alloc/retain/release schedules: a page is never handed
    out while referenced, refcounts never go negative, and draining every
    reference returns EVERY page to the pool."""
    rng = random.Random(seed)
    pool = PageAllocator(n_pages)
    held = []                       # one entry per outstanding reference
    for _ in range(rng.randrange(1, 60)):
        op = rng.random()
        if op < 0.45 and pool.free_pages:
            page = pool.alloc()
            assert 1 <= page < n_pages          # scratch page 0 never leaves
            assert held.count(page) == 0, "page handed out while referenced"
            held.append(page)
        elif op < 0.65 and held:
            page = rng.choice(held)
            pool.retain(page)
            held.append(page)
        elif held:
            page = held.pop(rng.randrange(len(held)))
            freed = pool.release(page)
            assert freed == (page not in held)
        assert (pool.refcount >= 0).all()
        assert pool.refcount[0] == 0
        for page in set(held):
            assert pool.refcount[page] == held.count(page)
        assert pool.free_pages == n_pages - 1 - len(set(held))
    while held:
        pool.release(held.pop())
    assert pool.free_pages == n_pages - 1
    assert (pool.refcount == 0).all()


def test_page_allocator_errors():
    with pytest.raises(ValueError):
        PageAllocator(1)
    pool = PageAllocator(3)
    with pytest.raises(ValueError):
        pool.release(1)             # never allocated
    with pytest.raises(ValueError):
        pool.retain(0)              # scratch page is not allocatable
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {1, 2}
    with pytest.raises(RuntimeError):
        pool.alloc()                # exhausted
    pool.release(a)
    pool.release(b)
    with pytest.raises(ValueError):
        pool.release(b)             # double release


# -- engine block-table bookkeeping under randomized schedules ----------------


_ENGINES = {}


def _shared_engine(**kw):
    """One engine per config, reused across hypothesis examples so the jit
    traces stay warm (each example fully drains it)."""
    key = tuple(sorted(kw.items()))
    if key not in _ENGINES:
        cfg, params = _small_model()
        _ENGINES[key] = ContinuousBatchingEngine(
            cfg, params, cache_dtype=jnp.float32, **kw)
    return _ENGINES[key]


def _check_paged_invariants(eng):
    P = eng.page_size
    held = []
    for slot, req in eng.slots.active.items():
        nblk = int(eng._nblk[slot])
        S = len(req.prompt)
        # reservation is exact: every possible write covered, nothing more
        assert nblk == max(1, -(-(S + req.max_new - 1) // P))
        row = eng._bt[slot, :nblk]
        assert (row > 0).all(), "live block table points at scratch"
        assert (eng._bt[slot, nblk:] == 0).all()
        assert nblk * P >= eng.slots.lengths[slot]   # covers written length
        for pid in row:
            assert eng.pages.refcount[pid] > 0
        held.extend(row.tolist())
    if not eng.prefix_cache:
        assert len(held) == len(set(held)), "page assigned to two slots"
    assert (eng.pages.refcount >= 0).all()
    assert eng.pages.free_pages == int((eng.pages.refcount[1:] == 0).sum())


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 3))
def test_engine_page_bookkeeping_randomized(seed):
    """Randomized submit/step/retire schedules through the REAL engine:
    block-table and refcount invariants hold at every step, and draining
    the engine returns every page."""
    rng = random.Random(seed)
    eng = _shared_engine(n_slots=2, max_len=16, page_size=4,
                         n_pages=9, prefix_cache=False)
    rng2 = np.random.default_rng(seed)
    for _ in range(rng.randrange(2, 5)):
        S = rng.choice([3, 5, 8])
        prompt = rng2.integers(0, eng.cfg.vocab_size, S).astype(np.int32)
        eng.submit(prompt, rng.choice([2, 4]))
        _check_paged_invariants(eng)
        for _ in range(rng.randrange(0, 3)):
            eng.step()
            _check_paged_invariants(eng)
    while eng.slots.active or eng.queue:
        eng.step()
        _check_paged_invariants(eng)
    eng.finished = []
    assert eng.pages.free_pages == eng.pages.n_pages - 1
    assert (eng.pages.refcount == 0).all()
    assert (eng._bt == 0).all() and (eng._nblk == 0).all()


def test_paged_pool_exhaustion_blocks_admission():
    """A queued request that doesn't fit the remaining pages must wait (not
    crash, not steal) until a retirement frees them; one that can NEVER fit
    the pool is rejected at submit."""
    cfg, params = _small_model()
    # pool of 3 usable pages, page_size 4: one request of nblk=3 fills it
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=16,
                                   cache_dtype=jnp.float32, page_size=4,
                                   n_pages=4)
    rng = np.random.default_rng(0)
    u1 = eng.submit(rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 4)
    u2 = eng.submit(rng.integers(0, cfg.vocab_size, 5).astype(np.int32), 4)
    # 8+4-1 -> 3 pages reserved; 5+4-1 -> 2 more don't fit: queued
    assert len(eng.queue) == 1 and eng.slots.free_slots == 1
    with pytest.raises(ValueError):
        eng.submit(np.zeros(13, np.int32), 4)    # needs 4 pages: can't ever
    res = eng.run()
    assert len(res[u1]) == 4 and len(res[u2]) == 4
    assert eng.pages.free_pages == 3


# -- paged vs dense serving equivalence ---------------------------------------


def _run_engine(cfg, params, prompts, max_new, eos_id=None, **kw):
    eng = ContinuousBatchingEngine(cfg, params, cache_dtype=jnp.float32,
                                   eos_id=eos_id, **kw)
    uids = [eng.submit(p, mn) for p, mn in zip(prompts, max_new)]
    res = eng.run()
    return [res[u] for u in uids], eng


def test_paged_matches_dense_engine():
    """The tentpole equivalence: paged block-table serving must emit
    token-for-token what the dense per-slot rings emit, under mixed prompt
    lengths, more requests than slots (slot reuse), EOS retirement, and
    chunked prefill — greedy, bitwise."""
    cfg, params = _small_model()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (5, 9, 3, 12, 7)]
    max_new = [6, 4, 8, 3, 5]
    kw = dict(n_slots=3, max_len=16)
    want, _ = _run_engine(cfg, params, prompts, max_new, **kw)
    # an EOS the model actually emits mid-stream, to force early retirement
    eos = want[2][2]
    want_eos, _ = _run_engine(cfg, params, prompts, max_new, eos_id=eos, **kw)
    assert any(len(a) < len(b) for a, b in zip(want_eos, want))
    for label, pkw in [
        ("paged", dict(page_size=4)),
        ("paged small pool", dict(page_size=4, n_pages=9)),
        ("paged chunked", dict(page_size=4, prefill_chunk=4)),
        ("paged chunked prefix", dict(page_size=4, prefill_chunk=3,
                                      prefix_cache=True)),
    ]:
        got, eng = _run_engine(cfg, params, prompts, max_new, **kw, **pkw)
        assert got == want, label
        got, eng = _run_engine(cfg, params, prompts, max_new, eos_id=eos,
                               **kw, **pkw)
        assert got == want_eos, label
        assert eng.pages.free_pages == eng.pages.n_pages - 1, label


def test_paged_kv_env_escape_hatch(monkeypatch):
    cfg, params = _small_model()
    monkeypatch.setenv("REPRO_PAGED_KV", "off")
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=16,
                                   page_size=4)
    assert not eng.paged and "block_table" not in eng.cache["kv"]


def test_paged_rejects_stateful_families():
    cfg = configs.get("mamba2_780m", smoke=True)
    params = model.init_params(cfg, KEY)
    with pytest.raises(NotImplementedError):
        ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=16,
                                 page_size=4)


# -- paged decode kernel vs oracle --------------------------------------------


def _paged_case(P, l_real, idxs, dtype, seed=0):
    """Random pool + per-slot heterogeneous block tables (+2 spare pages so
    tables are NOT the identity layout), and the dense gathered view."""
    B, K, G, h = len(idxs), 2, 2, 16
    NB = -(-l_real // P)
    NP = 1 + B * NB + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, K, G, h), dtype)
    pk = jax.random.normal(ks[1], (NP, P, K, h), dtype)
    pv = jax.random.normal(ks[2], (NP, P, K, h), dtype)
    rng = np.random.default_rng(seed)
    bt = rng.permutation(np.arange(1, NP))[:B * NB].reshape(B, NB)
    bt = jnp.asarray(bt, jnp.int32)
    return q, pk, pv, bt, jnp.asarray(idxs, jnp.int32)


def _paged_oracle(q, pk, pv, bt, idxs, l_real, window):
    B, NB = bt.shape
    P = pk.shape[1]
    cap = NB * P
    kpos = jnp.where(jnp.arange(cap) < l_real, jnp.arange(cap), -(10 ** 9))
    outs = []
    for b in range(B):
        dk = pk[bt[b]].reshape(cap, *pk.shape[2:])[None]
        dv = pv[bt[b]].reshape(cap, *pv.shape[2:])[None]
        outs.append(ref.sdpa_ref(
            q[b:b + 1].astype(jnp.float32), dk.astype(jnp.float32),
            dv.astype(jnp.float32), jnp.array([int(idxs[b])]), kpos,
            causal=True, window=window))
    return jnp.concatenate(outs, axis=0)


@pytest.mark.parametrize("P,l_real,idxs,window,dtype", [
    (4, 16, [3, 15], None, jnp.float32),     # dividing pages, mixed fill
    (8, 37, [5, 36, 20], None, jnp.float32),  # P does not divide l_real
    (4, 12, [11], 5, jnp.float32),            # sliding window
    (16, 16, [0, 7], None, jnp.bfloat16),     # single page; idx=0 edge
])
def test_paged_decode_vs_oracle(P, l_real, idxs, window, dtype):
    """Kernel vs einsum oracle over the GATHERED dense view: heterogeneous
    (permuted) block tables, capacity overshooting l_real, windows, bf16."""
    q, pk, pv, bt, idx = _paged_case(P, l_real, idxs, dtype)
    want = _paged_oracle(q, pk, pv, bt, idx, l_real, window)
    got = fa.flash_decode_paged(q, pk, pv, bt, idx, l_real=l_real,
                                window=window, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_paged_decode_tile_invariance():
    """Key-tile choice changes only the schedule — and tiles are clamped to
    divisors of the page size, so none may span a page boundary."""
    q, pk, pv, bt, idx = _paged_case(8, 32, [3, 30], jnp.float32)
    outs = [fa.flash_decode_paged(q, pk, pv, bt, idx, block_k=bk,
                                  interpret=True)
            for bk in (2, 8, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5)


def test_paged_decode_scratch_garbage_isolated():
    """Dead block-table entries point at scratch page 0; poisoning scratch
    (and every unreferenced page) with huge values must not perturb the
    output — masked probabilities are exact zeros."""
    P, l_real, idxs = 4, 16, [2]
    q, pk, pv, bt, idx = _paged_case(P, l_real, idxs, jnp.float32)
    want = fa.flash_decode_paged(q, pk, pv, bt, idx, interpret=True)
    live = set(np.asarray(bt).ravel().tolist())
    poison = np.asarray(pk).copy()
    for page in range(pk.shape[0]):
        if page not in live:
            poison[page] = 1e30
    # also poison live pages BEYOND the write index's block
    bt_host = np.asarray(bt)
    for blk in range(int(idxs[0]) // P + 1, bt.shape[1]):
        poison[bt_host[0, blk]] = 1e30
    got = fa.flash_decode_paged(q, jnp.asarray(poison), pv, bt, idx,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_model_decode_paged_vs_dense_bitwise():
    """Through the real model: a paged cache (block tables covering max_len
    exactly) decodes BITWISE identically to the dense per-slot cache."""
    cfg, params = _small_model()
    B, S, M, P = 2, 6, 16, 4
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    cd = model.init_cache(cfg, B, M, dtype=jnp.float32, per_slot=True)
    ld, cd = model.prefill(cfg, params, cd, toks)
    NB = M // P
    cp = model.init_cache(cfg, B, M, dtype=jnp.float32, page_size=P,
                          n_pages=1 + B * NB)
    bt = 1 + np.arange(B * NB, dtype=np.int32).reshape(B, NB)
    cp["kv"]["block_table"] = jnp.broadcast_to(
        jnp.asarray(bt), cp["kv"]["block_table"].shape)
    lp, cp = model.prefill(cfg, params, cp, toks)
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
    tok = jnp.argmax(ld[:, -1:], axis=-1)
    for _ in range(3):
        dd, cd = model.decode_step(cfg, params, cd, tok)
        dp, cp = model.decode_step(cfg, params, cp, tok)
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(dp))
        tok = jnp.argmax(dd[:, -1:], axis=-1)


# -- prefix caching -----------------------------------------------------------


def test_prefix_cache_skips_shared_prefill_and_frees_late():
    """Two requests sharing a 2-page system prompt: the second's shared
    pages are retained (its prefill skips them), outputs are unchanged,
    and the shared pages return to the pool only when the LAST referencing
    slot retires."""
    cfg, params = _small_model()
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    p1 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 3)
                         .astype(np.int32)])
    p2 = np.concatenate([shared, rng.integers(0, cfg.vocab_size, 5)
                         .astype(np.int32)])
    kw = dict(n_slots=2, max_len=20)
    want, _ = _run_engine(cfg, params, [p1, p2], [2, 6], **kw)

    eng = ContinuousBatchingEngine(cfg, params, cache_dtype=jnp.float32,
                                   page_size=4, prefix_cache=True, **kw)
    u1 = eng.submit(p1, 2)
    u2 = eng.submit(p2, 6)
    s1 = next(s for s, r in eng.slots.active.items() if r.uid == u1)
    s2 = next(s for s, r in eng.slots.active.items() if r.uid == u2)
    # the second request shares the first's two prefix pages (refcount 2)
    shared_pages = eng._bt[s1, :2].copy()
    np.testing.assert_array_equal(eng._bt[s2, :2], shared_pages)
    assert all(eng.pages.refcount[p] == 2 for p in shared_pages)
    # and its prefill dispatched only the unshared tail
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_pages_shared"] == 2
    assert eng.stats["prefill_chunks"] == 2
    assert eng.stats["prefill_tokens"] == len(p1) + (len(p2) - 8)

    # run until the first request retires: shared pages must stay live
    res = {}
    while u1 not in res:
        res.update({r.uid: r.tokens for r in eng.step()})
    assert u2 not in res
    assert all(eng.pages.refcount[p] == 1 for p in shared_pages)
    assert all(p in eng._page_hash for p in shared_pages)  # still published
    while eng.slots.active or eng.queue:
        res.update({r.uid: r.tokens for r in eng.step()})
    assert [res[u1], res[u2]] == want
    assert eng.pages.free_pages == eng.pages.n_pages - 1
    assert not eng._prefix and not eng._page_hash


# -- autotune integration -----------------------------------------------------


def test_paged_tune_key_includes_page_size():
    base = tune_key("flash_decode_paged", 2, 2, 16, 32, d_mid=2, d_page=8)
    assert "|p8" in base
    assert base != tune_key("flash_decode_paged", 2, 2, 16, 32, d_mid=2,
                            d_page=16)


def test_paged_tiles_resolved_at_trace_time(cache, monkeypatch):
    """Acceptance spy: tuned flash_decode_paged tiles (keyed WITH the page
    size) are consulted at trace time of a jitted paged decode."""
    from repro.perf import autotune as at

    B, K, G, h, P, NB = 2, 2, 2, 8, 8, 4
    tuned = {"block_b": 1, "block_o": 128, "block_k": 256}
    cache.put(tune_key("flash_decode_paged", B, K, h, NB * P, d_mid=G,
                       d_page=P), tuned, us=1.0)
    seen = {}
    real = at.get_tuned_blocks

    def spy(op, *a, **kw):
        out = real(op, *a, **kw)
        seen[op] = dict(out)
        return out

    monkeypatch.setattr(at, "get_tuned_blocks", spy)
    q = jnp.zeros((B, 1, K, G, h))
    pool = jnp.zeros((1 + B * NB, P, K, h))
    bt = jnp.zeros((B, NB), jnp.int32)
    idx = jnp.zeros((B,), jnp.int32)
    jax.jit(lambda *a: kops.flash_decode_paged(*a)).lower(
        q, pool, pool, bt, idx)
    assert seen["flash_decode_paged"] == tuned


def test_autotune_sweeps_paged_decode(cache):
    blocks, us = autotune.autotune_dyad(
        "flash_decode_paged", 2, 2, 16, 32, d_mid=2, d_page=8, iters=1,
        candidates=[{"block_b": 1, "block_o": 128, "block_k": 8},
                    {"block_b": 1, "block_o": 128, "block_k": 128}])
    assert blocks["block_k"] in (8, 128) and us > 0
    with pytest.raises(ValueError):
        autotune.autotune_dyad("flash_decode_paged", 2, 2, 16, 32, d_mid=2,
                               iters=1,
                               candidates=[{"block_b": 1, "block_o": 128,
                                            "block_k": 8}])


def test_ensure_tuned_covers_paged(cache, monkeypatch):
    from repro.perf.autotune import ensure_tuned_for_model

    cfg, _ = _small_model()
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    tuned = ensure_tuned_for_model(cfg, tokens=2, iters=1, kv_len=32,
                                   page_size=8)
    paged = [k for k in tuned if k.startswith("flash_decode_paged")]
    assert paged and all("|p8" in k for k in paged)
    # page_size swaps the decode op: the dense flash_decode key is absent
    assert not any(k.startswith("flash_decode|") for k in tuned)
