"""Performance subsystem: autotune cache, bench records, regression gate."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf import autotune, compare
from repro.perf.autotune import (BlockCache, DEFAULT_BLOCKS, autotune_dyad,
                                 candidate_blocks, get_tuned_blocks,
                                 tune_key, vmem_estimate)
from repro.perf.record import (BenchResult, Recorder, current_recorder,
                               load_bench, recording)
from repro.perf.registry import available_suites, register, run_suite


@pytest.fixture
def cache(tmp_path):
    """Isolated BlockCache installed as the process singleton."""
    c = BlockCache(user_path=str(tmp_path / "blocks.json"),
                   defaults_path=str(tmp_path / "defaults.json"))
    autotune.reset_cache(c)
    yield c
    autotune.reset_cache(None)


# -- BenchResult / Recorder ---------------------------------------------------


def test_bench_result_round_trip():
    r = BenchResult(name="ff_fwd", us_per_call=123.456, suite="ff_timing",
                    shape=(2048, 768), dtype="float32",
                    metrics={"ratio": 2.1, "flops": 1e9, "verdict": "PASS"})
    r2 = BenchResult.from_dict(json.loads(json.dumps(r.to_dict())))
    assert r2.name == r.name
    assert r2.shape == (2048, 768)
    assert r2.metrics == r.metrics
    assert abs(r2.us_per_call - r.us_per_call) < 1e-3


def test_bench_result_rejects_malformed():
    with pytest.raises(ValueError):
        BenchResult.from_dict({"us_per_call": 1.0})    # no name


def test_recorder_writes_and_loads(tmp_path):
    rec = Recorder("unit", out_dir=str(tmp_path))
    rec.add("b_cell", 20.0, shape=(4, 4), tok_s=100)
    rec.add("a_cell", 10.0)
    path = rec.write()
    assert os.path.basename(path) == "BENCH_unit.json"
    doc = load_bench(path)
    assert doc["suite"] == "unit"
    assert [r.name for r in doc["results"]] == ["a_cell", "b_cell"]  # sorted
    assert doc["results"][1].metrics["tok_s"] == 100


def test_recording_context_routes_emit(tmp_path):
    from benchmarks.common import emit

    assert current_recorder() is None
    with recording("ctx", str(tmp_path)) as rec:
        emit("x", 1.5, ratio=2.0)
        emit("y", 2.5, "legacy=3.5;tag=str")     # legacy derived string
    assert current_recorder() is None
    by = {r.name: r for r in rec.results}
    assert by["x"].metrics["ratio"] == 2.0
    assert by["y"].metrics["legacy"] == 3.5
    assert by["y"].metrics["tag"] == "str"


def test_registry_runs_suite(tmp_path):
    from benchmarks.common import emit

    @register("unit_suite")
    def _suite():
        emit("one_cell", 42.0, ratio=1.0)

    assert "unit_suite" in available_suites()
    rec = run_suite("unit_suite", out_dir=str(tmp_path))
    assert os.path.exists(rec.path)
    assert rec.results[0].name == "one_cell"


# -- autotune cache -----------------------------------------------------------


def test_cache_miss_returns_default(cache):
    assert cache.get(tune_key("dyad_mm_blocks", 8, 2, 64, 64)) is None
    blocks = get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64)
    assert blocks == DEFAULT_BLOCKS


def test_cache_put_then_hit(cache):
    key = tune_key("dyad_mm_blocks", 8, 2, 64, 64)
    tuned = {"block_b": 8, "block_o": 64, "block_k": 64}
    cache.put(key, tuned, us=12.3)
    assert get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64) == tuned
    # persisted: a fresh cache over the same file sees it
    fresh = BlockCache(user_path=cache.user_path,
                       defaults_path=cache.defaults_path)
    assert fresh.get(key) == tuned
    # B is bucketed: B=7 and B=8 share an entry
    assert get_tuned_blocks("dyad_mm_blocks", 7, 2, 64, 64) == tuned


def test_cache_corrupt_file_recovery(cache):
    os.makedirs(os.path.dirname(cache.user_path), exist_ok=True)
    with open(cache.user_path, "w") as f:
        f.write("{not json!")
    with pytest.warns(UserWarning, match="corrupt"):
        assert cache.get(tune_key("dyad_mm_blocks", 8, 2, 64, 64)) is None
    # put() recovers: rewrites a valid file on top of the corrupt one
    key = tune_key("dyad_mm_blocks", 8, 2, 64, 64)
    cache.put(key, DEFAULT_BLOCKS, us=1.0)
    fresh = BlockCache(user_path=cache.user_path,
                       defaults_path=cache.defaults_path)
    assert fresh.get(key) == DEFAULT_BLOCKS


def test_cache_ignores_malformed_entry(cache):
    key = tune_key("dyad_mm_blocks", 8, 2, 64, 64)
    cache.user[key] = {"blocks": {"block_b": "big"}}   # wrong types
    assert cache.get(key) is None


def test_candidate_blocks_respect_vmem_budget():
    cands = candidate_blocks(4096, 4, 4096, 4096)
    assert cands, "sweep must produce candidates"
    assert any(c == DEFAULT_BLOCKS for c in cands)
    for c in cands:
        assert vmem_estimate(c["block_b"], c["block_o"], c["block_k"],
                             "float32") <= autotune.VMEM_BUDGET_BYTES


def test_autotune_sweep_caches_and_short_circuits(cache):
    cands = [DEFAULT_BLOCKS, {"block_b": 16, "block_o": 32, "block_k": 32}]
    blocks, us = autotune_dyad("dyad_mm_blocks", 16, 2, 32, 32,
                               candidates=cands, iters=1, warmup=0,
                               cache=cache)
    assert blocks in cands and us > 0
    # second call is a cache hit: passing impossible candidates proves the
    # sweep didn't run again
    blocks2, _ = autotune_dyad("dyad_mm_blocks", 16, 2, 32, 32,
                               candidates=[], iters=1, cache=cache)
    assert blocks2 == blocks


def test_tuned_blocks_picked_up_by_kernel(cache):
    """End-to-end: a cache entry changes what dyad_mm_blocks resolves and
    the kernel still computes the exact product with those tiles."""
    from repro.kernels.dyad_mm import dyad_mm_blocks, resolve_blocks

    B, n, d_in, d_out = 16, 2, 64, 64
    tuned = {"block_b": 8, "block_o": 32, "block_k": 16}
    cache.put(tune_key("dyad_mm_blocks", B, n, d_in, d_out), tuned, us=1.0)
    assert resolve_blocks("dyad_mm_blocks", B, n, d_in, d_out,
                          jnp.float32) == (8, 32, 16)
    # explicit arguments beat the cache
    assert resolve_blocks("dyad_mm_blocks", B, n, d_in, d_out, jnp.float32,
                          block_o=64) == (8, 64, 16)

    k = jax.random.PRNGKey(0)
    x1 = jax.random.normal(k, (B, n, d_in))
    x2 = jax.random.normal(jax.random.fold_in(k, 1), (B, n, d_in))
    w1 = jax.random.normal(jax.random.fold_in(k, 2), (n, d_out, d_in))
    w2 = jax.random.normal(jax.random.fold_in(k, 3), (n, d_out, d_in))
    want = (jnp.einsum("bgk,gok->bgo", x1, w1)
            + jnp.einsum("bgk,gok->bgo", x2, w2))
    got = dyad_mm_blocks(x1, x2, w1, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-5)


# -- autotune: backward op keys -----------------------------------------------


@pytest.mark.parametrize("op", ["dyad_mm_dgrad", "dyad_mm_dgrad_two",
                                "dyad_mm_wgrad"])
def test_autotune_bwd_op_sweep_caches_and_short_circuits(op, cache):
    cands = [DEFAULT_BLOCKS, {"block_b": 16, "block_o": 32, "block_k": 32}]
    blocks, us = autotune_dyad(op, 16, 2, 32, 32, candidates=cands,
                               iters=1, warmup=0, cache=cache)
    assert blocks in cands and us > 0
    entry = cache.get_entry(tune_key(op, 16, 2, 32, 32))
    assert entry is not None and entry["op"] == op
    # cache hit short-circuits: impossible candidates prove no re-sweep
    blocks2, _ = autotune_dyad(op, 16, 2, 32, 32, candidates=[],
                               iters=1, cache=cache)
    assert blocks2 == blocks


def test_bwd_op_keys_are_distinct_from_fwd(cache):
    """dgrad/wgrad tiles must never collide with the forward's: the same
    shape tunes per OP."""
    keys = {tune_key(op, 32, 4, 64, 128)
            for op in ("dyad_mm_blocks", "dyad_mm_dgrad", "dyad_mm_wgrad")}
    assert len(keys) == 3
    cache.put(tune_key("dyad_mm_dgrad", 32, 4, 64, 128),
              {"block_b": 8, "block_o": 64, "block_k": 128})
    assert get_tuned_blocks("dyad_mm_blocks", 32, 4, 64, 128) == DEFAULT_BLOCKS
    assert get_tuned_blocks("dyad_mm_dgrad", 32, 4, 64, 128)["block_o"] == 64


def test_tp_shard_keys_are_distinct_from_single_device(cache):
    """A per-shard shape tuned under tensor parallelism must never collide
    with a single-device entry for the same dims: the ambient tp_shards
    count suffixes the key (|tpN), and tp=1 keys keep the legacy spelling
    so every committed cache entry stays valid."""
    from repro.perf.autotune import tp_shards

    base = tune_key("dyad_ff_fused", 256, 4, 64, 64, d_mid=128)
    assert "|tp" not in base                       # legacy spelling intact
    with tp_shards(2):
        k2 = tune_key("dyad_ff_fused", 256, 4, 64, 64, d_mid=128)
    with tp_shards(4):
        k4 = tune_key("dyad_ff_fused", 256, 4, 64, 64, d_mid=128)
    assert len({base, k2, k4}) == 3 and "|tp2|" in k2 and "|tp4|" in k4
    # explicit tp= overrides the ambient count; tp=1 is the no-suffix case
    assert tune_key("dyad_ff_fused", 256, 4, 64, 64, d_mid=128, tp=1) == base
    with tp_shards(8):
        assert tune_key("dyad_ff_fused", 256, 4, 64, 64,
                        d_mid=128, tp=2) == k2
    # lookups route through the same ambient tag: a tp2 entry must be
    # invisible to single-device lookups of the same shape (and vice versa)
    cache.put(k2, {"block_b": 8, "block_o": 64, "block_k": 128})
    assert get_tuned_blocks("dyad_ff_fused", 256, 4, 64, 64,
                            d_mid=128) != {"block_b": 8, "block_o": 64,
                                           "block_k": 128}
    with tp_shards(2):
        assert get_tuned_blocks("dyad_ff_fused", 256, 4, 64, 64,
                                d_mid=128)["block_o"] == 64


def test_bwd_cache_corrupt_file_recovery(cache):
    """Corrupt user cache: bwd key lookups degrade to defaults, and the
    next put() rewrites a valid file containing the bwd entry."""
    os.makedirs(os.path.dirname(cache.user_path), exist_ok=True)
    with open(cache.user_path, "w") as f:
        f.write("{broken")
    with pytest.warns(UserWarning, match="corrupt"):
        assert get_tuned_blocks("dyad_mm_wgrad", 8, 2, 64, 64) == DEFAULT_BLOCKS
    key = tune_key("dyad_mm_wgrad", 8, 2, 64, 64)
    tuned = {"block_b": 8, "block_o": 64, "block_k": 64}
    cache.put(key, tuned, us=3.0)
    fresh = BlockCache(user_path=cache.user_path,
                       defaults_path=cache.defaults_path)
    assert fresh.get(key) == tuned


def test_ensure_tuned_include_bwd(cache):
    """include_bwd=True tunes the variant's dgrad op + wgrad alongside the
    forward for every model dyad shape."""
    from repro import configs
    from repro.perf.autotune import ensure_tuned_for_model

    lin = configs.linear_cfg("dyad_it_4_kernel")
    cfg = configs.get("qwen3_0_6b", smoke=True, linear=lin)
    tuned = ensure_tuned_for_model(cfg, tokens=16, iters=1, include_bwd=True)
    ops_seen = {k.split("|")[0] for k in tuned}
    assert ops_seen == {"dyad_mm_blocks", "dyad_mm_dgrad_two",
                        "dyad_mm_wgrad"}
    # every entry landed in the cache
    for k in tuned:
        assert cache.get(k) is not None


def test_tuned_bwd_tiles_resolved_in_value_and_grad_trace(cache, monkeypatch):
    """Tuned dgrad/wgrad tiles are consulted AT TRACE TIME of a jitted
    value_and_grad over the kernel-routed op (pallas route forced so the
    backward actually resolves tiles off-TPU)."""
    from repro.kernels import ops as kops
    from repro.perf import autotune as at

    B, n, d_in, d_out = 16, 2, 64, 64
    tuned = {"block_b": 8, "block_o": 32, "block_k": 32}
    for op in ("dyad_mm_dgrad_two", "dyad_mm_wgrad"):
        cache.put(tune_key(op, B, n, d_in, d_out), tuned, us=1.0)

    seen = {}
    real = at.get_tuned_blocks

    def spy(op, *a, **kw):
        out = real(op, *a, **kw)
        seen[op] = dict(out)
        return out

    monkeypatch.setattr(at, "get_tuned_blocks", spy)
    monkeypatch.setenv("REPRO_KERNEL_BWD", "pallas")

    x = jax.random.normal(jax.random.PRNGKey(0), (B, n * d_in))
    w = jax.random.normal(jax.random.PRNGKey(1), (n, d_out, d_in))

    def loss(x, w1, w2):
        return (kops.dyad_mm(x, w1, w2, variant="it") ** 2).sum()

    # trace (no execution needed): tile resolution happens here
    jax.jit(jax.value_and_grad(loss)).lower(x, w, w + 1)
    assert seen["dyad_mm_dgrad_two"] == tuned
    assert seen["dyad_mm_wgrad"] == tuned
    assert "dyad_mm_blocks" in seen        # forward resolved too


# -- autotune: trace-time memo ------------------------------------------------


def test_get_tuned_blocks_memoized(cache):
    """Repeated trace-time lookups hit the in-process memo instead of
    re-walking the JSON-backed cache layers."""
    before = autotune.memo_counts()
    blocks = get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64)
    mid = autotune.memo_counts()
    assert mid["misses"] == before["misses"] + 1
    for _ in range(5):
        assert get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64) == blocks
    after = autotune.memo_counts()
    assert after["hits"] >= mid["hits"] + 5
    assert after["misses"] == mid["misses"]
    # the memo hands out copies: mutating a result must not poison it
    got = get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64)
    got["block_b"] = -1
    assert get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64)["block_b"] > 0


def test_get_tuned_blocks_memo_invalidated_by_put(cache):
    """put() must invalidate the memo — freshly tuned tiles have to reach
    the very next trace."""
    key = tune_key("dyad_mm_blocks", 8, 2, 64, 64)
    assert get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64) == DEFAULT_BLOCKS
    tuned = {"block_b": 8, "block_o": 64, "block_k": 64}
    cache.put(key, tuned, us=1.0)
    assert get_tuned_blocks("dyad_mm_blocks", 8, 2, 64, 64) == tuned


# -- autotune: ff megakernel op keys ------------------------------------------


def test_tune_key_carries_d_mid(cache):
    k_ff = tune_key("dyad_ff_fused", 32, 4, 192, 192, d_mid=768)
    assert "|j768|" in k_ff
    assert k_ff != tune_key("dyad_ff_fused", 32, 4, 192, 192, d_mid=384)
    # single-matmul keys are unchanged by the new field
    assert "|j" not in tune_key("dyad_mm_blocks", 32, 4, 192, 192)


def test_ff_defaults_and_block_j_round_trip(cache):
    ff = get_tuned_blocks("dyad_ff_fused", 8, 2, 64, 64, d_mid=128)
    assert ff == autotune.DEFAULT_FF_BLOCKS and "block_j" in ff
    key = tune_key("dyad_ff_fused", 8, 2, 64, 64, d_mid=128)
    tuned = {"block_b": 8, "block_o": 64, "block_k": 64, "block_j": 128}
    cache.put(key, tuned, us=1.0)
    assert get_tuned_blocks("dyad_ff_fused", 8, 2, 64, 64,
                            d_mid=128) == tuned
    # an entry written before the j axis existed degrades to the default j
    cache.put(key, {"block_b": 8, "block_o": 64, "block_k": 64}, us=1.0)
    got = get_tuned_blocks("dyad_ff_fused", 8, 2, 64, 64, d_mid=128)
    assert got["block_j"] == autotune.DEFAULT_FF_BLOCKS["block_j"]
    assert got["block_b"] == 8


def test_candidate_blocks_ff_respect_vmem_budget():
    for gated in (False, True):
        cands = autotune.candidate_blocks_ff(4096, 4, 1024, 1024, 4096,
                                             gated=gated)
        assert cands
        for c in cands:
            assert autotune.vmem_estimate_ff(
                c["block_b"], c["block_o"], c["block_k"], c["block_j"],
                "float32", gated=gated) <= autotune.VMEM_BUDGET_BYTES
    # the gate's extra weight stream + second hidden accumulator must COST:
    # same tiles estimate strictly higher when gated
    assert (autotune.vmem_estimate_ff(256, 256, 512, 512, "float32", True)
            > autotune.vmem_estimate_ff(256, 256, 512, 512, "float32",
                                        False))


@pytest.mark.parametrize("op", ["dyad_ff_fused", "dyad_ff_fused_swiglu"])
def test_autotune_ff_sweep_caches_and_short_circuits(op, cache):
    cands = [dict(autotune.DEFAULT_FF_BLOCKS),
             {"block_b": 16, "block_o": 32, "block_k": 32, "block_j": 16}]
    blocks, us = autotune_dyad(op, 16, 2, 32, 32, candidates=cands,
                               iters=1, warmup=0, cache=cache, d_mid=48)
    assert blocks in cands and us > 0
    entry = cache.get_entry(tune_key(op, 16, 2, 32, 32, d_mid=48))
    assert entry is not None and entry["op"] == op
    blocks2, _ = autotune_dyad(op, 16, 2, 32, 32, candidates=[],
                               iters=1, cache=cache, d_mid=48)
    assert blocks2 == blocks


def test_autotune_ff_requires_d_mid(cache):
    with pytest.raises(ValueError, match="d_mid"):
        autotune_dyad("dyad_ff_fused", 16, 2, 32, 32, cache=cache)


def test_ensure_tuned_covers_ff_megakernel(cache):
    """A fuse_ff_kernel config tunes the ff op (+ the down dgrad the
    megakernel VJP composes) alongside the per-matmul ops."""
    from repro import configs
    from repro.perf.autotune import ensure_tuned_for_model

    lin = configs.linear_cfg("dyad_it_4_kernel_ffused")
    cfg = configs.get("opt125m", smoke=True, linear=lin, mlp_bias=False)
    tuned = ensure_tuned_for_model(cfg, tokens=16, iters=1, include_bwd=True)
    ops_seen = {k.split("|")[0] for k in tuned}
    assert "dyad_ff_fused" in ops_seen            # opt125m act == relu
    assert "dyad_mm_dgrad" in ops_seen            # OT down dgrad
    for k in tuned:
        assert cache.get(k) is not None
    # a BIASED ff never dispatches the megakernel (mlp._ff_kernel_ready),
    # so the sweep must skip it too — no minutes burned on an unused op
    cfg_b = configs.get("opt125m", smoke=True, linear=lin)   # mlp_bias=True
    tuned_b = ensure_tuned_for_model(cfg_b, tokens=16, iters=1)
    assert not any(k.startswith("dyad_ff_fused") for k in tuned_b)
    # without the flag the ff op is not tuned either
    cfg2 = configs.get("opt125m", smoke=True,
                       linear=configs.linear_cfg("dyad_it_4_kernel"))
    tuned2 = ensure_tuned_for_model(cfg2, tokens=16, iters=1)
    assert not any(k.startswith("dyad_ff_fused") for k in tuned2)


def test_tuned_ff_tiles_resolved_in_trace(cache, monkeypatch):
    """The megakernel resolves its 4-axis tiles from the cache at trace
    time of a jitted fuse_ff_kernel mlp forward."""
    import jax
    from repro.core import factory
    from repro.layers import mlp as mlp_lib
    from repro.perf import autotune as at

    seen = {}
    real = at.get_tuned_blocks

    def spy(op, *a, **kw):
        out = real(op, *a, **kw)
        seen[op] = dict(out)
        return out

    monkeypatch.setattr(at, "get_tuned_blocks", spy)
    lc = factory.LinearCfg(impl="dyad", n_dyad=2, variant="it",
                           use_kernel=True, fuse_ff_kernel=True)
    p = mlp_lib.init_mlp(jax.random.PRNGKey(0), 32, 64, lc, act="gelu")
    x = jax.jit(lambda p, x: mlp_lib.apply_mlp(p, x, lc, act="gelu")).lower(
        p, jax.ShapeDtypeStruct((8, 32), jnp.float32))
    assert "block_j" in seen["dyad_ff_fused"]


# -- compare / regression gate ------------------------------------------------


def _results(**us_by_name):
    return [BenchResult(name=k, us_per_call=v) for k, v in us_by_name.items()]


def test_compare_flags_regression():
    rows = compare.compare_runs(_results(a=200.0, b=200.0),
                                _results(a=200.0, b=300.0), tol=0.25)
    by = {r.name: r for r in rows}
    assert not by["a"].regressed
    assert by["b"].regressed and by["b"].status == "REGRESSED"
    assert compare.summarize(rows)["regressed"] == 1


def test_compare_within_tolerance_and_noise_floor():
    rows = compare.compare_runs(_results(a=200.0, tiny=10.0, small=100.0),
                                _results(a=240.0, tiny=40.0, small=140.0),
                                tol=0.25)
    by = {r.name: r for r in rows}
    assert not by["a"].regressed            # 20% < 25% tol
    assert not by["tiny"].regressed         # current below the noise floor
    assert not by["small"].regressed        # delta 40us below the floor


def test_compare_tiny_baseline_can_still_regress():
    """A sub-floor baseline must not immunize a cell: 30us -> 5000us is a
    real regression even though the baseline is under the noise floor."""
    rows = compare.compare_runs(_results(k=30.0), _results(k=5000.0))
    assert rows[0].regressed


def test_compare_new_and_removed_never_fail():
    rows = compare.compare_runs(_results(old=100.0), _results(new=900.0))
    assert {r.status for r in rows} == {"REMOVED", "NEW"}
    assert compare.summarize(rows)["regressed"] == 0


def test_compare_roofline_annotation():
    cur = [BenchResult(name="a", us_per_call=1000.0,
                       metrics={"flops": 1e9, "bytes": 1e6})]
    rows = compare.compare_runs([], cur)
    assert rows[0].gflops == pytest.approx(1e9 / 1000.0 / 1e3)
    assert rows[0].intensity == pytest.approx(1000.0)
    assert rows[0].roofline_frac is not None
    assert "GF/s" in compare.format_table(rows)


def test_check_cli_passes_on_identical(tmp_path):
    """python -m repro.perf.check against a committed baseline == current."""
    repo = tmp_path / "r"
    repo.mkdir()
    env = {**os.environ, "GIT_AUTHOR_NAME": "t", "GIT_AUTHOR_EMAIL": "t@t",
           "GIT_COMMITTER_NAME": "t", "GIT_COMMITTER_EMAIL": "t@t"}

    def git(*a):
        subprocess.run(["git", *a], cwd=repo, check=True, env=env,
                       capture_output=True)

    git("init", "-q")
    rec = Recorder("gate", out_dir=str(repo))
    rec.add("cell", 100.0)
    rec.write()
    git("add", "-A")
    git("commit", "-qm", "baseline")

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.perf.check"], cwd=repo,
        env={**env, "PYTHONPATH": src + os.pathsep
             + os.environ.get("PYTHONPATH", "")},
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "PERF GATE: PASS" in out.stdout

    # regress the current file 2x -> gate fails
    rec2 = Recorder("gate", out_dir=str(repo))
    rec2.add("cell", 250.0)
    rec2.write()
    out = subprocess.run(
        [sys.executable, "-m", "repro.perf.check"], cwd=repo,
        env={**env, "PYTHONPATH": src + os.pathsep
             + os.environ.get("PYTHONPATH", "")},
        capture_output=True, text=True)
    assert out.returncode == 1
    assert "PERF GATE: FAIL" in out.stdout
