"""REQUIRED per-arch smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (the full configs
are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.optim import AdamW, schedule
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
OPT = AdamW(lr=schedule.constant(1e-3))


def _batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            KEY, (B, cfg.n_patches, cfg.frontend_dim))
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(
            KEY, (B, cfg.n_frames, cfg.frontend_dim))
    return b


@pytest.mark.parametrize("arch", configs.ARCHS + configs.PAPER_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get(arch, smoke=True)
    batch = _batch(cfg)
    state = init_train_state(cfg, OPT, KEY)

    logits, aux = model.forward(cfg, state["params"], batch)
    S_text = batch["tokens"].shape[1]
    assert logits.shape == (2, S_text, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN/inf logits"

    step = jax.jit(make_train_step(cfg, OPT))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert moved, f"{arch}: no parameter update"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get(arch, smoke=True)
    state_params = model.init_params(cfg, KEY)
    cache = model.init_cache(cfg, 2, 16, dtype=jnp.float32)
    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (2, cfg.n_frames, cfg.frontend_dim))
        cache = model.prefill_cross(cfg, state_params, cache, frames)
    tok = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    logits, new_cache = model.decode_step(cfg, state_params, cache, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "mamba2_780m", "hymba_1_5b"])
def test_smoke_dense_vs_dyad_both_run(arch):
    """The drop-in claim: same arch runs with dense and every dyad variant."""
    for lin in ["dense", "dyad_it_4", "dyad_ot_4", "dyad_dt_4", "dyad_it_8",
                "dyad_it_4_cat"]:
        cfg = configs.get(arch, smoke=True, linear=configs.linear_cfg(lin))
        params = model.init_params(cfg, KEY)
        logits, _ = model.forward(cfg, params, _batch(cfg))
        assert np.isfinite(np.asarray(logits)).all(), f"{arch}/{lin}"


def test_full_configs_match_assignment():
    """Pin the exact published numbers from the assignment table."""
    c = configs.get("qwen3_0_6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 1024, 16, 8, 3072, 151936)
    assert c.qk_norm
    c = configs.get("llama3_405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (126, 16384, 128, 8, 53248, 128256)
    c = configs.get("qwen2_moe_a2_7b")
    assert (c.n_experts, c.top_k, c.expert_d_ff, c.n_shared) == (60, 4, 1408, 4)
    c = configs.get("llama4_maverick_400b_a17b")
    assert (c.n_experts, c.top_k) == (128, 1)
    c = configs.get("mamba2_780m")
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = configs.get("hymba_1_5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.ssm_state) == (32, 1600, 25, 5, 5504, 16)
    c = configs.get("whisper_medium")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.vocab_size) == (
        24, 24, 1024, 51865)
    c = configs.get("phi3_vision_4_2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (
        32, 3072, 32, 8192, 32064)
    c = configs.get("phi3_medium_14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (
        40, 5120, 40, 10, 17920)
    c = configs.get("qwen2_5_32b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (
        64, 5120, 27648, 152064)
    assert c.qkv_bias


def test_long_500k_applicability_rule():
    shape = configs.SHAPES["long_500k"]
    runnable = [a for a in configs.ARCHS
                if configs.cell_runnable(configs.get(a), shape)[0]]
    assert sorted(runnable) == ["hymba_1_5b", "mamba2_780m"]
