"""Minimal deterministic stand-in for ``hypothesis`` (see conftest.py).

The container image does not ship ``hypothesis`` and the tier-1 suite must
collect (and meaningfully run) without optional deps.  This stub implements
the tiny subset the tests use — ``given``, ``settings``, and the
``integers`` / ``sampled_from`` strategies — by enumerating a deterministic
sample of input combinations (seeded PRNG, capped example count) instead of
random property search.  ``pip install hypothesis`` (declared in
pyproject.toml) replaces it transparently with the real library.
"""
from __future__ import annotations

import itertools
import random

_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy is just a deterministic list of candidate values."""

    def __init__(self, values):
        self.values = list(values)


def integers(min_value: int, max_value: int) -> _Strategy:
    """Boundary values plus a few seeded interior points."""
    rng = random.Random((min_value, max_value).__hash__())
    vals = {min_value, max_value, (min_value + max_value) // 2}
    span = max_value - min_value
    if span > 4:
        vals.update(min_value + rng.randrange(span) for _ in range(3))
    return _Strategy(sorted(vals))


def sampled_from(seq) -> _Strategy:
    return _Strategy(seq)


def settings(*args, **kwargs):
    """Accepted and ignored (decorator passthrough)."""
    if args and callable(args[0]):
        return args[0]
    return lambda f: f


def given(**strategies):
    """Run the test over a deterministic cross-product sample (capped)."""
    names = sorted(strategies)

    def deco(f):
        grids = [strategies[n].values for n in names]
        combos = list(itertools.islice(itertools.product(*grids),
                                       _MAX_EXAMPLES * 50))
        rng = random.Random(0)
        if len(combos) > _MAX_EXAMPLES:
            combos = rng.sample(combos, _MAX_EXAMPLES)

        # NOTE: deliberately no functools.wraps — it would copy __wrapped__
        # and pytest would then see the strategy parameters as fixtures.
        def wrapper():
            for combo in combos:
                f(**dict(zip(names, combo)))
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        return wrapper

    return deco
