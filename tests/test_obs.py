"""Observability subsystem: tracer round-trip, disabled-path overhead,
metric primitives, engine/trainer telemetry invariants, route-dispatch
counters, and the timeline replay-diff."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, obs
from repro.data import SyntheticLM
from repro.models import model
from repro.obs.trace import Tracer
from repro.optim import AdamW, schedule
from repro.perf import timeline
from repro.serve import ContinuousBatchingEngine, Engine
from repro.train import Trainer, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def tracer():
    """A fresh process-global tracer, removed again afterwards (the rest of
    the suite must keep running with tracing off)."""
    obs.disable()
    t = obs.enable()
    yield t
    obs.disable()


def _small_model():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    return cfg, model.init_params(cfg, KEY)


# -- tracer ------------------------------------------------------------------


def test_trace_export_roundtrip_and_nesting(tracer, tmp_path):
    """Spans export as valid Chrome-trace JSON; a child span's interval is
    time-contained in its parent's (how Perfetto reconstructs nesting)."""
    with obs.span("outer", cat="test", batch=4):
        time.sleep(0.002)
        with obs.span("inner", cat="test", arr=np.arange(3)) as sp:
            sp.set(result=7)
            time.sleep(0.002)
        time.sleep(0.002)
    obs.instant("marker", cat="test", reason="x")

    path = str(tmp_path / "t.json")
    obs.export(path)
    doc = json.load(open(path))
    assert "traceEvents" in doc and doc["displayTimeUnit"] == "ms"
    ev = {e["name"]: e for e in doc["traceEvents"]}
    outer, inner, mark = ev["outer"], ev["inner"], ev["marker"]
    assert outer["ph"] == "X" and inner["ph"] == "X"
    # containment: inner starts after outer and ends before it
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["dur"] >= inner["dur"]
    assert mark["ph"] == "i" and mark["s"] == "t"
    # args survive; non-scalars are stringified at export, mid-span set()
    # updates land
    assert outer["args"]["batch"] == 4
    assert inner["args"]["result"] == 7
    assert isinstance(inner["args"]["arr"], str)
    json.dumps(doc)  # fully serializable


def test_trace_ring_buffer_bounded():
    t = Tracer(capacity=10)
    for i in range(25):
        t.instant(f"e{i}")
    assert len(t) == 10
    assert t.dropped == 15
    names = [e["name"] for e in t.to_chrome_trace()["traceEvents"]]
    assert names == [f"e{i}" for i in range(15, 25)]  # newest kept


def test_disabled_tracer_is_shared_noop_and_cheap():
    """Tracing off: span() must return the one shared null span (no
    allocation, no clock read) — the instrumented hot paths rely on it."""
    obs.disable()
    assert not obs.enabled()
    s1 = obs.span("a", cat="serve", batch=4)
    s2 = obs.span("b")
    assert s1 is s2
    with s1 as s:
        s.set(anything=1)   # no-op, no error
    t0 = time.perf_counter()
    for _ in range(100_000):
        with obs.span("hot", batch=1):
            pass
    dt = time.perf_counter() - t0
    assert dt < 1.0, f"disabled span too slow: {dt:.3f}s / 100k"


def test_verbose_gate(tracer, monkeypatch):
    monkeypatch.setenv("REPRO_OBS_VERBOSE", "0")
    assert not obs.verbose()          # explicit off wins over enabled tracer
    monkeypatch.setenv("REPRO_OBS_VERBOSE", "1")
    assert obs.verbose()
    monkeypatch.delenv("REPRO_OBS_VERBOSE")
    assert obs.verbose()              # tracer enabled implies verbose
    obs.disable()
    assert not obs.verbose()


# -- metrics -----------------------------------------------------------------


def test_metric_primitives():
    m = obs.MetricsRegistry()
    m.counter("c").inc()
    m.counter("c").inc(4)
    assert m.counter("c").value == 5
    g = m.gauge("g")
    g.set(3)
    g.set(7)
    g.set(2)
    assert g.value == 2 and g.max == 7
    h = m.histogram("h")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.count == 100 and h.mean == pytest.approx(50.5)
    assert h.percentile(50) == pytest.approx(50.0, abs=1)
    assert h.percentile(99) == pytest.approx(99.0, abs=1)
    assert h.summary()["p90"] == pytest.approx(90.0, abs=1)


def test_registry_snapshot_json(tmp_path):
    m = obs.MetricsRegistry()
    m.counter("tokens_generated").inc(10)
    m.gauge("queue_depth").set(3)
    m.histogram("ttft_s").observe(0.25)
    path = str(tmp_path / "m.json")
    m.write_json(path)
    snap = json.load(open(path))
    assert snap["counters"]["tokens_generated"] == 10
    assert snap["gauges"]["queue_depth"] == {"value": 3, "max": 3}
    assert snap["histograms"]["ttft_s"]["count"] == 1
    line = obs.format_serving_line(m)
    assert "tok=10" in line and "ttft_ms" in line


# -- engine telemetry invariants ---------------------------------------------


def test_continuous_engine_metric_invariants():
    """Mixed-length run through slot retirement: every finished request has
    a TTFT sample, token counts match outputs, queue/active drain to 0."""
    cfg, p = _small_model()
    cbe = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=24)
    prompts = jax.random.randint(KEY, (5, 4), 0, cfg.vocab_size)
    uids = [cbe.submit(np.asarray(prompts[i]), 3 + i % 3) for i in range(5)]
    results = cbe.run()
    snap = cbe.metrics_summary()
    c, g, h = snap["counters"], snap["gauges"], snap["histograms"]
    assert c["requests_submitted"] == 5
    assert c["requests_finished"] == 5
    assert h["ttft_s"]["count"] == 5          # every request reached a token
    assert h["ttft_s"]["p50"] > 0
    assert c["tokens_generated"] == sum(len(results[u]) for u in uids)
    assert h["decode_step_s"]["count"] >= 1
    assert g["queue_depth"]["value"] == 0
    assert g["active_slots"]["value"] == 0
    assert g["active_slots"]["max"] == 2      # both slots were busy at peak
    assert "itl_s" in h                       # multi-token requests observed
    assert obs.format_serving_line(cbe.metrics).startswith("reqs=5 ")


def test_paged_engine_page_pool_and_prefix_metrics():
    """Paged + prefix mode: pool occupancy returns to zero after drain (with
    a positive high-water mark) and shared-prefix admissions are counted."""
    cfg, p = _small_model()
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
             for n in (3, 5)]
    eng = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=20,
                                   cache_dtype=jnp.float32, page_size=4,
                                   prefix_cache=True)
    for tail in tails:
        eng.submit(np.concatenate([shared, tail]), 3)
    eng.run()
    snap = eng.metrics_summary()
    c, g = snap["counters"], snap["gauges"]
    assert c["prefix_hits"] == 1
    assert c["prefix_tokens_skipped"] == 8    # two shared 4-token pages
    assert g["page_pool_used"]["max"] > 0
    assert g["page_pool_used"]["value"] == 0  # all pages back after drain
    # first prompt prefills fully; the second's shared 8 tokens are skipped
    assert c["prefill_tokens"] == (len(shared) + len(tails[0])) + len(tails[1])


def test_admission_reject_counted():
    cfg, p = _small_model()
    cbe = ContinuousBatchingEngine(cfg, p, n_slots=1, max_len=8)
    with pytest.raises(ValueError):
        cbe.submit(np.zeros(9, np.int32), 4)
    assert cbe.metrics_summary()["counters"]["admission_rejects"] == 1


def test_continuous_engine_trace_spans(tracer, tmp_path):
    """The engine's step phases all land in the exported trace."""
    cfg, p = _small_model()
    cbe = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=16)
    prompts = jax.random.randint(KEY, (3, 4), 0, cfg.vocab_size)
    for i in range(3):
        cbe.submit(np.asarray(prompts[i]), 3)
    cbe.run()
    path = str(tmp_path / "serve.json")
    obs.export(path)
    names = {e["name"] for e in json.load(open(path))["traceEvents"]}
    assert {"admit", "prefill", "decode_step", "retire"} <= names


def test_batch_engine_metrics():
    cfg, p = _small_model()
    eng = Engine(cfg, p, max_len=16)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    eng.generate(prompts, 6)
    snap = eng.metrics.snapshot()
    assert snap["counters"]["tokens_generated"] == 12
    assert snap["counters"]["requests_finished"] == 2
    assert snap["histograms"]["ttft_s"]["count"] == 1
    assert snap["histograms"]["itl_s"]["count"] == 1


# -- trainer telemetry -------------------------------------------------------


def test_trainer_metrics_and_log_line(capsys):
    tiny = configs.get("opt125m", smoke=True)
    opt = AdamW(lr=schedule.constant(1e-3))
    data = SyntheticLM(vocab_size=tiny.vocab_size, seq_len=8, global_batch=4)
    step = jax.jit(make_train_step(tiny, opt))
    lines = []
    t = Trainer(step, init_train_state(tiny, opt, KEY), data, log_every=3,
                log_fn=lambda s: lines.append(s))
    t.run(6)
    snap = t.metrics.snapshot()
    assert snap["histograms"]["step_time_s"]["count"] == 6
    assert snap["counters"]["tokens_trained"] == 6 * 4 * 8
    assert snap["gauges"]["tokens_per_s"]["value"] > 0
    assert snap["gauges"]["loss"]["value"] > 0
    # the periodic log line carries throughput + running-median step time
    assert any("tok/s=" in ln and "step_ms_med=" in ln for ln in lines)


# -- route-dispatch counters --------------------------------------------------


def test_route_counts_and_trace_instants(tracer, tmp_path):
    obs.reset_route_counts()
    obs.route_event("ff", "fused")
    obs.route_event("ff", "fused")
    obs.route_event("attn", "xla")
    assert obs.route_counts() == {("ff", "fused"): 2, ("attn", "xla"): 1}
    path = str(tmp_path / "r.json")
    obs.export(path)
    names = [e["name"] for e in json.load(open(path))["traceEvents"]]
    assert names.count("route:ff=fused") == 2
    obs.reset_route_counts()
    assert obs.route_counts() == {}


def test_engine_records_attn_route():
    """Building a decode step makes the attention routing decision visible."""
    obs.reset_route_counts()
    cfg, p = _small_model()
    eng = Engine(cfg, p, max_len=16)
    prompts = jax.random.randint(KEY, (1, 4), 0, cfg.vocab_size)
    eng.generate(prompts, 2)
    counts = obs.route_counts()
    assert any(op == "attn" for op, _ in counts), counts


# -- timeline replay-diff ------------------------------------------------------


def _trace_doc(spans):
    """Chrome-trace doc from [(name, ts_us, dur_us), ...]."""
    return {"traceEvents": [
        {"name": n, "cat": "t", "ph": "X", "pid": 1, "tid": 1,
         "ts": ts, "dur": dur} for n, ts, dur in spans]}


def test_timeline_localizes_injected_slowdown(tmp_path, capsys):
    base = _trace_doc([("decode_step", i * 100, 80) for i in range(10)]
                      + [("prefill", 0, 500), ("sync", 0, 40)])
    cur = _trace_doc([("decode_step", i * 100, 800) for i in range(10)]
                     + [("prefill", 0, 500), ("sync", 0, 40)])
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    json.dump(base, open(a, "w"))
    json.dump(cur, open(b, "w"))
    rows = timeline.diff_timelines(timeline.load_timeline(a),
                                   timeline.load_timeline(b))
    assert rows[0].name == "decode_step"          # top row IS the culprit
    assert rows[0].mean_ratio == pytest.approx(10.0)
    bad = timeline.attribute(rows)
    assert [r.name for r in bad] == ["decode_step"]
    # CLI: prints the localization and gates with --fail-on-regress
    rc = timeline.main([a, b, "--fail-on-regress"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "REGRESSION localized to span 'decode_step'" in out


def test_timeline_self_diff_is_clean(tmp_path, capsys):
    doc = _trace_doc([("x", 0, 100), ("y", 100, 300)])
    a = str(tmp_path / "a.json")
    json.dump(doc, open(a, "w"))
    assert timeline.main([a, a, "--fail-on-regress"]) == 0
    assert "no span regressed" in capsys.readouterr().out


def test_timeline_loads_bench_documents(tmp_path):
    """A committed BENCH_*.json diffs against a trace via us_per_call."""
    bench = {"suite": "smoke", "results": [
        {"name": "ff dense", "us_per_call": 120.0},
        {"name": "ff dyad", "us_per_call": 60.0}]}
    p = str(tmp_path / "BENCH_smoke.json")
    json.dump(bench, open(p, "w"))
    stats = timeline.load_timeline(p)
    assert stats["ff dyad"].total_us == 60.0
    assert stats["ff dense"].count == 1
    with pytest.raises(ValueError):
        q = str(tmp_path / "junk.json")
        json.dump({"nope": 1}, open(q, "w"))
        timeline.load_timeline(q)


def test_timeline_json_report(tmp_path):
    a = str(tmp_path / "a.json")
    json.dump(_trace_doc([("x", 0, 100)]), open(a, "w"))
    out = str(tmp_path / "diff.json")
    timeline.main([a, a, "--json", out])
    doc = json.load(open(out))
    assert doc["rows"][0]["name"] == "x"
    assert doc["rows"][0]["regressed"] is False


# -- perf.check --json ---------------------------------------------------------


def test_check_json_report(tmp_path, monkeypatch, capsys):
    """--json writes a machine-readable verdict (no-baseline case: pass,
    per-file report with baseline=None)."""
    from repro.perf import check
    bench = {"suite": "smoke", "results": [
        {"name": "cell", "us_per_call": 10.0}]}
    p = str(tmp_path / "BENCH_smoke.json")
    json.dump(bench, open(p, "w"))
    monkeypatch.chdir(tmp_path)   # not a git repo -> no committed baseline
    out = str(tmp_path / "report.json")
    rc = check.main([p, "--json", out])
    assert rc == 0
    doc = json.load(open(out))
    assert doc["pass"] is True
    assert doc["regressed_cells"] == []
    assert doc["files"][0]["suite"] == "smoke"
    assert doc["files"][0]["baseline"] is None
