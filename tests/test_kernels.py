"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps, fwd + bwd, in
interpret mode (executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dyad
from repro.kernels import ops, ref
from repro.kernels.dyad_mm import (dyad_mm_blocks, dyad_mm_blocks_two,
                                   dyad_mm_dgrad, dyad_mm_dgrad_two,
                                   dyad_mm_wgrad, plan_tiles)

KEY = jax.random.PRNGKey(0)

SHAPES = [
    # (f_in, f_out, n_dyad, batch)
    (16, 16, 4, 8),
    (32, 64, 4, 16),
    (24, 32, 4, 6),
    (64, 32, 8, 5),
    (12, 20, 2, 3),
    (128, 128, 4, 32),
]


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
@pytest.mark.parametrize("f_in,f_out,n,B", SHAPES)
def test_kernel_matches_ref(variant, f_in, f_out, n, B):
    spec = dyad.DyadSpec(n_dyad=n, variant=variant)
    p = dyad.init(KEY, f_in, f_out, spec, bias=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, f_in))
    y_ref = ref.dyad_mm_ref(x, p["w1"], p["w2"], variant=variant)
    y_ker = ops.dyad_mm(x, p["w1"], p["w2"], variant=variant)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_kernel_dtypes(dtype, tol):
    spec = dyad.DyadSpec(n_dyad=4)
    p = dyad.init(KEY, 32, 32, spec, bias=False, dtype=jnp.float32)
    x = jax.random.normal(KEY, (8, 32)).astype(dtype)
    y_ref = ref.dyad_mm_ref(x, p["w1"], p["w2"], variant="it")
    y_ker = ops.dyad_mm(x, p["w1"], p["w2"], variant="it")
    assert y_ker.dtype == dtype
    np.testing.assert_allclose(np.asarray(y_ker, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
def test_kernel_gradients(variant):
    spec = dyad.DyadSpec(n_dyad=4, variant=variant)
    p = dyad.init(KEY, 16, 24, spec, bias=False)
    x = jax.random.normal(KEY, (6, 16))
    f_r = lambda x, w1, w2: (ref.dyad_mm_ref(x, w1, w2, variant=variant) ** 2).sum()
    f_k = lambda x, w1, w2: (ops.dyad_mm(x, w1, w2, variant=variant) ** 2).sum()
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    for a, b in zip(gr, gk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4,
                                   atol=2e-4)


def test_kernel_block_tilings():
    """Sweep BlockSpec tilings: result must be invariant to tiling choice."""
    x1 = jax.random.normal(KEY, (16, 4, 32))
    x2 = jax.random.normal(jax.random.PRNGKey(1), (16, 4, 32))
    w1 = jax.random.normal(jax.random.PRNGKey(2), (4, 24, 32))
    w2 = jax.random.normal(jax.random.PRNGKey(3), (4, 24, 32))
    base = dyad_mm_blocks(x1, x2, w1, w2, interpret=True)
    for bb, bo, bk in [(4, 8, 8), (16, 24, 32), (8, 12, 16), (2, 6, 4)]:
        out = dyad_mm_blocks(x1, x2, w1, w2, block_b=bb, block_o=bo,
                             block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-5, atol=1e-5)
    z1, z2 = dyad_mm_blocks_two(x1, x2, w1, w2, block_b=8, block_o=12,
                                block_k=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(z1 + z2), np.asarray(base), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,n,d_in,d_out", [
    (10, 2, 33, 17),          # odd k, prime o
    (13, 3, 7, 5),            # everything prime
    (64, 2, 129, 130),        # just-past-128 feature dims
])
def test_kernel_degenerate_dims_exact(B, n, d_in, d_out):
    """Prime/odd dims used to collapse _largest_divisor to 1-wide tiles
    (catastrophic grid); the tile planner now pads instead — results must
    stay exact (zero padding contributes zero products)."""
    x1 = jax.random.normal(KEY, (B, n, d_in))
    x2 = jax.random.normal(jax.random.PRNGKey(1), (B, n, d_in))
    w1 = jax.random.normal(jax.random.PRNGKey(2), (n, d_out, d_in))
    w2 = jax.random.normal(jax.random.PRNGKey(3), (n, d_out, d_in))
    want = (jnp.einsum("bgk,gok->bgo", x1, w1)
            + jnp.einsum("bgk,gok->bgo", x2, w2))
    got = dyad_mm_blocks(x1, x2, w1, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    z1, z2 = dyad_mm_blocks_two(x1, x2, w1, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(z1 + z2), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_plan_tiles_never_degenerate():
    """Tiles stay at lane/sublane granularity even for prime dims > block,
    and the grid never explodes to per-element steps."""
    plan = plan_tiles(521, 1031, 1031, 256, 256, 512)   # all prime
    assert plan.bB >= 8 and plan.bO >= 128 and plan.bK >= 128
    assert plan.padded_b % plan.bB == 0
    assert plan.padded_o % plan.bO == 0
    assert plan.padded_k % plan.bK == 0
    assert plan.grid_steps <= 64
    # healthy dims are untouched: no padding, exact divisors
    plan = plan_tiles(64, 384, 512, 256, 256, 512)
    assert (plan.padded_b, plan.padded_o, plan.padded_k) == (64, 384, 512)
    assert (plan.bB, plan.bO, plan.bK) == (64, 192, 512)


def test_kernel_multi_dim_leading():
    """ops.dyad_mm flattens arbitrary leading dims."""
    spec = dyad.DyadSpec(n_dyad=4, variant="it", use_kernel=True)
    p = dyad.init(KEY, 16, 16, spec, bias=True)
    x = jax.random.normal(KEY, (2, 3, 5, 16))
    y = dyad.apply(p, x, spec)
    y_ref = dyad.apply(p, x, dyad.DyadSpec(n_dyad=4, variant="it"))
    assert y.shape == (2, 3, 5, 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=2e-5)


# -- fused backward kernels ---------------------------------------------------


BWD_SHAPES = [
    # (B, n, d_in, d_out): healthy, odd/prime (exercising plan_tiles
    # padding), and just-past-lane dims
    (16, 4, 32, 24),
    (10, 2, 33, 17),
    (13, 3, 7, 5),
    (64, 2, 129, 130),
]


@pytest.mark.parametrize("B,n,d_in,d_out", BWD_SHAPES)
def test_dgrad_kernels_match_einsum(B, n, d_in, d_out):
    z1 = jax.random.normal(KEY, (B, n, d_out))
    z2 = jax.random.normal(jax.random.PRNGKey(1), (B, n, d_out))
    w1 = jax.random.normal(jax.random.PRNGKey(2), (n, d_out, d_in))
    w2 = jax.random.normal(jax.random.PRNGKey(3), (n, d_out, d_in))
    want = (jnp.einsum("bgo,goi->bgi", z1, w1)
            + jnp.einsum("bgo,goi->bgi", z2, w2))
    got = dyad_mm_dgrad(z1, z2, w1, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    d1, d2 = dyad_mm_dgrad_two(z1, z2, w1, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(d1 + d2), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,n,d_in,d_out", BWD_SHAPES)
def test_wgrad_kernel_matches_einsum(B, n, d_in, d_out):
    x1 = jax.random.normal(KEY, (B, n, d_in))
    x2 = jax.random.normal(jax.random.PRNGKey(1), (B, n, d_in))
    z1 = jax.random.normal(jax.random.PRNGKey(2), (B, n, d_out))
    z2 = jax.random.normal(jax.random.PRNGKey(3), (B, n, d_out))
    dw1, dw2 = dyad_mm_wgrad(x1, x2, z1, z2, interpret=True)
    np.testing.assert_allclose(
        np.asarray(dw1), np.asarray(jnp.einsum("bgi,bgo->goi", x1, z1)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(dw2), np.asarray(jnp.einsum("bgi,bgo->goi", x2, z2)),
        rtol=1e-5, atol=1e-5)


def test_wgrad_out_dtype_fp32_accumulation():
    """bf16 inputs accumulate in fp32 and cast ONCE at the end — dw in the
    requested out_dtype must match the fp32 reference to fp32-ish
    tolerance, far tighter than a bf16-accumulated product chain."""
    B, n, d_in, d_out = 64, 2, 32, 32
    x1 = jax.random.normal(KEY, (B, n, d_in))
    z1 = jax.random.normal(jax.random.PRNGKey(1), (B, n, d_out))
    want = jnp.einsum("bgi,bgo->goi", x1, z1)
    dw1, _ = dyad_mm_wgrad(x1.astype(jnp.bfloat16), x1.astype(jnp.bfloat16),
                           z1.astype(jnp.bfloat16), z1.astype(jnp.bfloat16),
                           out_dtype=jnp.float32, interpret=True)
    assert dw1.dtype == jnp.float32
    # the only error is the bf16 INPUT rounding, not accumulation ordering
    np.testing.assert_allclose(np.asarray(dw1), np.asarray(want),
                               rtol=5e-2, atol=1e-1)


def _grad_pair(variant, dtype, f_in=16, f_out=24, B=6, use_kernel_bwd=True):
    spec = dyad.DyadSpec(n_dyad=4, variant=variant)
    p = dyad.init(KEY, f_in, f_out, spec, bias=False)
    x = jax.random.normal(KEY, (B, f_in)).astype(dtype)
    f_k = lambda x, w1, w2: (ops.dyad_mm(
        x, w1, w2, variant=variant, use_kernel_bwd=use_kernel_bwd) ** 2).sum()
    f_e = lambda x, w1, w2: (ops.dyad_mm(
        x, w1, w2, variant=variant, use_kernel_bwd=False) ** 2).sum()
    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    ge = jax.grad(f_e, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    return gk, ge


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_kernel_bwd_matches_einsum_oracle(variant, dtype, tol):
    """use_kernel_bwd=True (default route) vs the einsum-VJP oracle."""
    gk, ge = _grad_pair(variant, dtype)
    for a, b in zip(gk, ge):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_pallas_bwd_matches_einsum_oracle(variant, dtype, tol, monkeypatch):
    """REPRO_KERNEL_BWD=pallas forces the true dgrad/wgrad kernels through
    the VJP off-TPU (interpret mode) — still oracle-exact."""
    monkeypatch.setenv("REPRO_KERNEL_BWD", "pallas")
    gk, ge = _grad_pair(variant, dtype)
    for a, b in zip(gk, ge):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
@pytest.mark.parametrize("f_in,f_out,B", [(33, 21, 10), (35, 25, 13)])
def test_pallas_bwd_odd_dims_exact(variant, f_in, f_out, B, monkeypatch):
    """Odd/prime per-block dims route the bwd kernels through plan_tiles
    zero-padding — gradients stay exact (padding contributes nothing)."""
    monkeypatch.setenv("REPRO_KERNEL_BWD", "pallas")
    spec = dyad.DyadSpec(n_dyad=1, variant=variant)
    p = dyad.init(KEY, f_in, f_out, spec, bias=False)
    x = jax.random.normal(KEY, (B, f_in))
    f_k = lambda x, w1, w2: (ops.dyad_mm(x, w1, w2, variant=variant) ** 2).sum()
    f_e = lambda x, w1, w2: (ops.dyad_mm(x, w1, w2, variant=variant,
                                         use_kernel_bwd=False) ** 2).sum()
    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    ge = jax.grad(f_e, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    for a, b in zip(gk, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("route", ["xla", "pallas"])
def test_bwd_mixed_weight_dtypes(route, monkeypatch):
    """dw* cotangents must come back in each weight's OWN dtype on every
    route (custom_vjp enforces primal/cotangent aval agreement)."""
    monkeypatch.setenv("REPRO_KERNEL_BWD", route)
    x = jax.random.normal(KEY, (6, 16))
    spec = dyad.DyadSpec(n_dyad=4)
    p = dyad.init(KEY, 16, 24, spec, bias=False)
    w1, w2 = p["w1"], p["w2"].astype(jnp.bfloat16)
    g = jax.grad(lambda x, w1, w2: (ops.dyad_mm(x, w1, w2) ** 2).sum(),
                 argnums=(1, 2))(x, w1, w2)
    assert g[0].dtype == jnp.float32 and g[1].dtype == jnp.bfloat16


def test_grad_through_full_dyad_ff_block():
    """End-to-end jax.grad through a DYAD up/relu/down ff block: the
    kernel-routed spec (fwd + fused bwd) must match the plain jnp spec."""
    spec_k = dyad.DyadSpec(n_dyad=4, variant="it", use_kernel=True)
    spec_j = dyad.DyadSpec(n_dyad=4, variant="it")
    p = {"up": dyad.init(KEY, 16, 32, spec_k),
         "down": dyad.init(jax.random.PRNGKey(1), 32, 16, spec_k)}
    x = jax.random.normal(KEY, (8, 16))

    def loss(p, x, spec):
        h = jax.nn.relu(dyad.apply(p["up"], x, spec))
        return (dyad.apply(p["down"], h, spec) ** 2).mean()

    gk = jax.jit(jax.grad(lambda p, x: loss(p, x, spec_k)))(p, x)
    gj = jax.jit(jax.grad(lambda p, x: loss(p, x, spec_j)))(p, x)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gj)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
