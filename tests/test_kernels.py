"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps, fwd + bwd, in
interpret mode (executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dyad
from repro.kernels import ops, ref
from repro.kernels.dyad_mm import (dyad_mm_blocks, dyad_mm_blocks_two,
                                   plan_tiles)

KEY = jax.random.PRNGKey(0)

SHAPES = [
    # (f_in, f_out, n_dyad, batch)
    (16, 16, 4, 8),
    (32, 64, 4, 16),
    (24, 32, 4, 6),
    (64, 32, 8, 5),
    (12, 20, 2, 3),
    (128, 128, 4, 32),
]


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
@pytest.mark.parametrize("f_in,f_out,n,B", SHAPES)
def test_kernel_matches_ref(variant, f_in, f_out, n, B):
    spec = dyad.DyadSpec(n_dyad=n, variant=variant)
    p = dyad.init(KEY, f_in, f_out, spec, bias=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, f_in))
    y_ref = ref.dyad_mm_ref(x, p["w1"], p["w2"], variant=variant)
    y_ker = ops.dyad_mm(x, p["w1"], p["w2"], variant=variant)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_kernel_dtypes(dtype, tol):
    spec = dyad.DyadSpec(n_dyad=4)
    p = dyad.init(KEY, 32, 32, spec, bias=False, dtype=jnp.float32)
    x = jax.random.normal(KEY, (8, 32)).astype(dtype)
    y_ref = ref.dyad_mm_ref(x, p["w1"], p["w2"], variant="it")
    y_ker = ops.dyad_mm(x, p["w1"], p["w2"], variant="it")
    assert y_ker.dtype == dtype
    np.testing.assert_allclose(np.asarray(y_ker, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol,
                               atol=tol)


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
def test_kernel_gradients(variant):
    spec = dyad.DyadSpec(n_dyad=4, variant=variant)
    p = dyad.init(KEY, 16, 24, spec, bias=False)
    x = jax.random.normal(KEY, (6, 16))
    f_r = lambda x, w1, w2: (ref.dyad_mm_ref(x, w1, w2, variant=variant) ** 2).sum()
    f_k = lambda x, w1, w2: (ops.dyad_mm(x, w1, w2, variant=variant) ** 2).sum()
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    gk = jax.grad(f_k, argnums=(0, 1, 2))(x, p["w1"], p["w2"])
    for a, b in zip(gr, gk):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=2e-4,
                                   atol=2e-4)


def test_kernel_block_tilings():
    """Sweep BlockSpec tilings: result must be invariant to tiling choice."""
    x1 = jax.random.normal(KEY, (16, 4, 32))
    x2 = jax.random.normal(jax.random.PRNGKey(1), (16, 4, 32))
    w1 = jax.random.normal(jax.random.PRNGKey(2), (4, 24, 32))
    w2 = jax.random.normal(jax.random.PRNGKey(3), (4, 24, 32))
    base = dyad_mm_blocks(x1, x2, w1, w2, interpret=True)
    for bb, bo, bk in [(4, 8, 8), (16, 24, 32), (8, 12, 16), (2, 6, 4)]:
        out = dyad_mm_blocks(x1, x2, w1, w2, block_b=bb, block_o=bo,
                             block_k=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-5, atol=1e-5)
    z1, z2 = dyad_mm_blocks_two(x1, x2, w1, w2, block_b=8, block_o=12,
                                block_k=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(z1 + z2), np.asarray(base), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,n,d_in,d_out", [
    (10, 2, 33, 17),          # odd k, prime o
    (13, 3, 7, 5),            # everything prime
    (64, 2, 129, 130),        # just-past-128 feature dims
])
def test_kernel_degenerate_dims_exact(B, n, d_in, d_out):
    """Prime/odd dims used to collapse _largest_divisor to 1-wide tiles
    (catastrophic grid); the tile planner now pads instead — results must
    stay exact (zero padding contributes zero products)."""
    x1 = jax.random.normal(KEY, (B, n, d_in))
    x2 = jax.random.normal(jax.random.PRNGKey(1), (B, n, d_in))
    w1 = jax.random.normal(jax.random.PRNGKey(2), (n, d_out, d_in))
    w2 = jax.random.normal(jax.random.PRNGKey(3), (n, d_out, d_in))
    want = (jnp.einsum("bgk,gok->bgo", x1, w1)
            + jnp.einsum("bgk,gok->bgo", x2, w2))
    got = dyad_mm_blocks(x1, x2, w1, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    z1, z2 = dyad_mm_blocks_two(x1, x2, w1, w2, interpret=True)
    np.testing.assert_allclose(np.asarray(z1 + z2), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_plan_tiles_never_degenerate():
    """Tiles stay at lane/sublane granularity even for prime dims > block,
    and the grid never explodes to per-element steps."""
    plan = plan_tiles(521, 1031, 1031, 256, 256, 512)   # all prime
    assert plan.bB >= 8 and plan.bO >= 128 and plan.bK >= 128
    assert plan.padded_b % plan.bB == 0
    assert plan.padded_o % plan.bO == 0
    assert plan.padded_k % plan.bK == 0
    assert plan.grid_steps <= 64
    # healthy dims are untouched: no padding, exact divisors
    plan = plan_tiles(64, 384, 512, 256, 256, 512)
    assert (plan.padded_b, plan.padded_o, plan.padded_k) == (64, 384, 512)
    assert (plan.bB, plan.bO, plan.bK) == (64, 192, 512)


def test_kernel_multi_dim_leading():
    """ops.dyad_mm flattens arbitrary leading dims."""
    spec = dyad.DyadSpec(n_dyad=4, variant="it", use_kernel=True)
    p = dyad.init(KEY, 16, 16, spec, bias=True)
    x = jax.random.normal(KEY, (2, 3, 5, 16))
    y = dyad.apply(p, x, spec)
    y_ref = dyad.apply(p, x, dyad.DyadSpec(n_dyad=4, variant="it"))
    assert y.shape == (2, 3, 5, 16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5,
                               atol=2e-5)
