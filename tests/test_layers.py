"""Layer substrate: attention paths, MoE dispatch, Mamba2 SSD duality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import factory
from repro.layers import attention as attn
from repro.layers import moe, norms, ssm
from repro.layers.rotary import apply_rope

KEY = jax.random.PRNGKey(0)
DENSE = factory.DENSE
DYAD = factory.LinearCfg(impl="dyad", n_dyad=4, scope="all")


@pytest.mark.parametrize("lc", [DENSE, DYAD], ids=["dense", "dyad"])
def test_attention_chunked_equals_naive(lc):
    p = attn.init_attention(KEY, 64, 8, 4, 16, lc, qk_norm=True, qkv_bias=True)
    x = jax.random.normal(KEY, (2, 12, 64))
    y, _ = attn.attention(p, x, n_heads=8, n_kv=4, head_dim=16, lin_cfg=lc)
    y2, _ = attn.attention(p, x, n_heads=8, n_kv=4, head_dim=16, lin_cfg=lc,
                           chunk=5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("window", [None, 4])
def test_attention_decode_matches_full(window):
    p = attn.init_attention(KEY, 32, 4, 2, 8, DENSE)
    x = jax.random.normal(KEY, (2, 10, 32))
    y, _ = attn.attention(p, x, n_heads=4, n_kv=2, head_dim=8, lin_cfg=DENSE,
                          window=window)
    # ring cache sized to the window when windowed
    L = window if window else 10
    cache = attn.init_kv_cache(2, L, 2, 8, dtype=jnp.float32)
    outs = []
    for t in range(10):
        o, cache = attn.attention(p, x[:, t:t + 1], n_heads=4, n_kv=2,
                                  head_dim=8, lin_cfg=DENSE, window=window,
                                  cache=cache)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=1e-3, atol=1e-3)


def test_cross_attention_shapes():
    p = attn.init_attention(KEY, 32, 4, 4, 8, DENSE)
    x = jax.random.normal(KEY, (2, 6, 32))
    enc = jax.random.normal(KEY, (2, 9, 32))
    y, _ = attn.attention(p, x, n_heads=4, n_kv=4, head_dim=8, lin_cfg=DENSE,
                          rope_theta=None, causal=False, kv_input=enc,
                          positions=jnp.arange(6))
    assert y.shape == (2, 6, 32)
    assert np.isfinite(np.asarray(y)).all()


def test_rope_relative_shift_invariance():
    """RoPE: q.k depends only on relative distance."""
    q = jax.random.normal(KEY, (1, 4, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 2, 16))
    def score(offset):
        qr = apply_rope(q, offset + jnp.arange(4))
        kr = apply_rope(k, offset + jnp.arange(4))
        return jnp.einsum("bshd,bthd->bhst", qr, kr)
    np.testing.assert_allclose(np.asarray(score(0)), np.asarray(score(100)),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_topk_and_balances():
    mp = moe.init_moe(KEY, 32, 64, 6, 2, DENSE, n_experts_padded=8)
    x = jax.random.normal(KEY, (4, 16, 32))
    w, idx, probs = moe._route(mp, x, 6, 2)
    assert idx.shape == (4, 16, 2)
    assert int(idx.max()) < 6, "padded experts must never be routed to"
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    y, aux = moe.apply_moe(mp, x, DENSE, n_experts=6, top_k=2)
    assert y.shape == x.shape and float(aux) >= 1.0 - 1e-3


def test_moe_capacity_drops_tokens():
    mp = moe.init_moe(KEY, 16, 32, 4, 1, DENSE)
    x = jax.random.normal(KEY, (1, 8, 16))
    y_small, _ = moe.apply_moe(mp, x, DENSE, n_experts=4, top_k=1,
                               capacity_factor=0.25)
    y_big, _ = moe.apply_moe(mp, x, DENSE, n_experts=4, top_k=1,
                             capacity_factor=8.0)
    assert not np.allclose(np.asarray(y_small), np.asarray(y_big))


def test_moe_chunk_invariance_when_capacity_unbinding():
    mp = moe.init_moe(KEY, 32, 64, 6, 2, DYAD, n_shared=1, n_experts_padded=8)
    x = jax.random.normal(KEY, (2, 8, 32))
    y, _ = moe.apply_moe(mp, x, DYAD, n_experts=6, top_k=2,
                         capacity_factor=8.0)
    y_c, _ = moe.apply_moe(mp, x, DYAD, n_experts=6, top_k=2,
                           capacity_factor=8.0, chunk=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_c), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("lc", [DENSE, DYAD], ids=["dense", "dyad"])
def test_ssd_chunked_equals_recurrent(lc):
    """The SSD dual form must equal the sequential recurrence — the
    correctness heart of the Mamba2 implementation."""
    sp = ssm.init_ssm(KEY, 32, lc, d_state=16, head_dim=8, expand=2)
    x = jax.random.normal(KEY, (2, 8, 32)) * 0.5
    y = ssm.apply_ssm(sp, x, lc, d_state=16, head_dim=8, chunk=4)
    cache = ssm.init_ssm_cache(2, 32, d_state=16, head_dim=8, expand=2)
    outs = []
    for t in range(8):
        o, cache = ssm.ssm_decode_step(sp, x[:, t:t + 1], cache, lc,
                                       d_state=16, head_dim=8)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=3e-3, atol=3e-3)


def test_ssd_chunk_size_invariance():
    sp = ssm.init_ssm(KEY, 32, DENSE, d_state=16, head_dim=8)
    x = jax.random.normal(KEY, (1, 12, 32)) * 0.5
    y2 = ssm.apply_ssm(sp, x, DENSE, d_state=16, head_dim=8, chunk=2)
    y6 = ssm.apply_ssm(sp, x, DENSE, d_state=16, head_dim=8, chunk=6)
    y12 = ssm.apply_ssm(sp, x, DENSE, d_state=16, head_dim=8, chunk=12)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y6), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y12), rtol=1e-3,
                               atol=1e-3)


def test_norms_fp32_accumulation_dtype():
    p = norms.init_rmsnorm(16)
    x = jax.random.normal(KEY, (2, 16)).astype(jnp.bfloat16)
    y = norms.rmsnorm(p, x)
    assert y.dtype == jnp.bfloat16
    p2 = norms.init_layernorm(16)
    y2 = norms.layernorm(p2, x)
    assert y2.dtype == jnp.bfloat16


def test_fused_dyad_mlp_matches_variant_mix():
    """Beyond-paper fused ff (up=IT, down=OT, 3-D hidden) must equal the
    unfused mixed-variant computation exactly (paper Future Work §4.i)."""
    from repro.core import dyad
    from repro.layers import mlp as mlp_lib
    lc = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it", fuse_mlp=True)
    p = mlp_lib.init_mlp(KEY, 32, 64, lc, act="swiglu")
    x = jax.random.normal(KEY, (2, 5, 32))
    y_fused = mlp_lib.apply_mlp(p, x, lc, act="swiglu")
    spec_it = dyad.DyadSpec(n_dyad=4, variant="it")
    spec_ot = dyad.DyadSpec(n_dyad=4, variant="ot")
    h = (jax.nn.silu(dyad.apply(p["gate"], x, spec_it))
         * dyad.apply(p["up"], x, spec_it))
    y_ref = dyad.apply(p["down"], h, spec_ot)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
