"""DYAD algebra: the 3-D tensor computation must equal multiplication by the
reconstructed structured matrix, for every variant — the paper's core claim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dyad, factory, linear

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
@pytest.mark.parametrize("f_in,f_out,n", [(12, 8, 4), (16, 16, 4), (24, 16, 8),
                                          (6, 9, 3), (8, 8, 1)])
def test_apply_matches_dense_oracle(variant, f_in, f_out, n):
    spec = dyad.DyadSpec(n_dyad=n, variant=variant)
    p = dyad.init(KEY, f_in, f_out, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (5, f_in))
    y = dyad.apply(p, x, spec)
    W = dyad.to_dense(p, spec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W.T + p["b"]),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
def test_cat_path_identical(variant):
    spec = dyad.DyadSpec(n_dyad=4, variant=variant)
    p = dyad.init(KEY, 16, 24, spec)
    x = jax.random.normal(KEY, (3, 7, 16))   # arbitrary leading dims
    y0 = dyad.apply(p, x, spec)
    y1 = dyad.apply(p, x, dyad.DyadSpec(n_dyad=4, variant=variant, cat=True))
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 2, 3, 4, 8]),
    d_in=st.integers(1, 6),
    d_out=st.integers(1, 6),
    batch=st.integers(1, 4),
    variant=st.sampled_from(["it", "ot", "dt"]),
)
def test_property_oracle_equivalence(n, d_in, d_out, batch, variant):
    f_in, f_out = n * d_in, n * d_out
    spec = dyad.DyadSpec(n_dyad=n, variant=variant)
    p = dyad.init(jax.random.PRNGKey(n * 131 + d_in), f_in, f_out, spec,
                  bias=False)
    x = jax.random.normal(jax.random.PRNGKey(batch), (batch, f_in))
    y = dyad.apply(p, x, spec)
    W = dyad.to_dense(p, spec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ W.T), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([2, 4]), d=st.integers(1, 4),
       variant=st.sampled_from(["it", "ot", "dt"]))
def test_property_linearity(n, d, variant):
    """DYAD is a linear map: f(ax + by) == a f(x) + b f(y)."""
    f = n * d * 2
    spec = dyad.DyadSpec(n_dyad=n, variant=variant)
    p = dyad.init(KEY, f, f, spec, bias=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, f))
    y = jax.random.normal(jax.random.PRNGKey(4), (2, f))
    lhs = dyad.apply(p, 2.0 * x - 3.0 * y, spec)
    rhs = 2.0 * dyad.apply(p, x, spec) - 3.0 * dyad.apply(p, y, spec)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4,
                               atol=1e-4)


def test_param_and_flop_reduction():
    """The paper's complexity claim: n_dyad/2 x fewer params and FLOPs."""
    f = 1024
    for n in (4, 8):
        dn = dyad.param_count(f, f, n, bias=False)
        de = linear.param_count(f, f, bias=False)
        assert de / dn == n / 2
        assert linear.flops(32, f, f) / dyad.flops(32, f, f, n) == n / 2


def test_sparsity_pattern_of_oracle():
    """to_dense must be near-sparse: 2/n_dyad density (minus overlap)."""
    n = 4
    spec = dyad.DyadSpec(n_dyad=n, variant="it")
    p = dyad.init(KEY, 16, 16, spec, bias=False)
    W = np.asarray(dyad.to_dense(p, spec))
    density = (W != 0).mean()
    assert density <= 2.0 / n + 1e-6


def test_resolve_n_dyad():
    assert dyad.resolve_n_dyad(1024, 4096, 4) == 4
    assert dyad.resolve_n_dyad(7, 6, 4) == 1      # paper App 5.1: no divisor
    assert dyad.resolve_n_dyad(12, 18, 8) == 6
    assert dyad.resolve_n_dyad(16, 16, 16) == 16


def test_init_matches_paper():
    """uniform(-k, k), k = 1/sqrt(f_in) (paper §2.3 code)."""
    spec = dyad.DyadSpec(n_dyad=4)
    p = dyad.init(KEY, 256, 256, spec)
    k = 1.0 / np.sqrt(256)
    for leaf in (p["w1"], p["w2"], p["b"]):
        a = np.asarray(leaf)
        assert a.max() <= k and a.min() >= -k
    assert abs(np.asarray(p["w1"]).std() - k / np.sqrt(3)) < 0.1 * k


def test_factory_scope_dispatch():
    dy = factory.LinearCfg(impl="dyad", n_dyad=4, scope="ff")
    assert dy.dyad_at("ff") and not dy.dyad_at("attn")
    all_ = dy.replace(scope="all")
    assert all_.dyad_at("attn") and all_.dyad_at("head")
    p_ff = factory.init(KEY, 16, 16, dy, site="ff")
    p_at = factory.init(KEY, 16, 16, dy, site="attn")
    assert "w1" in p_ff and "w" in p_at


def test_dyad_gradients_match_dense_oracle():
    spec = dyad.DyadSpec(n_dyad=4, variant="it")
    p = dyad.init(KEY, 16, 16, spec, bias=False)
    x = jax.random.normal(KEY, (4, 16))

    def f_dyad(p_):
        return (dyad.apply(p_, x, spec) ** 2).sum()

    def f_dense(p_):
        return ((x @ dyad.to_dense(p_, spec).T) ** 2).sum()

    g1 = jax.grad(f_dyad)(p)
    g2 = jax.grad(f_dense)(p)
    for k in ("w1", "w2"):
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   rtol=1e-4, atol=1e-4)
