"""Model-level invariants across families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import factory
from repro.models import model
from repro.models.config import ModelCfg

KEY = jax.random.PRNGKey(0)
DYAD = factory.LinearCfg(impl="dyad", n_dyad=4, scope="ff")
TINY = dict(n_layers=2, d_model=32, vocab_size=61, n_heads=4, n_kv_heads=2,
            head_dim=8, d_ff=64, linear=DYAD)

CFGS = [
    ModelCfg(name="lm", family="lm", qk_norm=True, **TINY),
    ModelCfg(name="ssm", family="ssm", ssm_state=16, ssm_head_dim=8,
             ssd_chunk=4, pos_embed="none",
             **{**TINY, "n_heads": 0, "n_kv_heads": 0, "d_ff": 0}),
    ModelCfg(name="hyb", family="hybrid", ssm_state=16, ssm_head_dim=8,
             ssd_chunk=4, window=4, **TINY),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_teacher_forced_equals_autoregressive(cfg):
    p = model.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 8), 0, 61)
    full, _ = model.forward(cfg, p, {"tokens": toks})
    cache = model.init_cache(cfg, 2, 8, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lo, cache = model.decode_step(cfg, p, cache, toks[:, t:t + 1])
        outs.append(lo)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=2e-3)


def test_iota_embed_equals_take():
    cfg = ModelCfg(name="a", family="lm", **TINY)
    cfg_iota = cfg.replace(iota_embed=True)
    p = model.init_params(cfg, KEY)
    b = {"tokens": jax.random.randint(KEY, (2, 8), 0, 61)}
    y1, _ = model.forward(cfg, p, b)
    y2, _ = model.forward(cfg_iota, p, b)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


def test_remat_matches_no_remat():
    cfg = ModelCfg(name="a", family="lm", **TINY)
    p = model.init_params(cfg, KEY)
    b = {"tokens": jax.random.randint(KEY, (2, 8), 0, 61),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 61)}
    g1 = jax.grad(lambda q: model.loss_fn(cfg, q, b)[0])(p)
    g2 = jax.grad(
        lambda q: model.loss_fn(cfg.replace(remat=True), q, b)[0])(p)
    for a, c in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-4,
                                   atol=1e-5)


def test_label_masking():
    cfg = ModelCfg(name="a", family="lm", **TINY)
    p = model.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 8), 0, 61)
    l_full, _ = model.loss_fn(cfg, p, {"tokens": toks, "labels": toks})
    masked = toks.at[:, ::2].set(-1)
    l_mask, m = model.loss_fn(cfg, p, {"tokens": toks, "labels": masked})
    assert not np.isclose(float(l_full), float(l_mask))
    assert np.isfinite(float(l_mask))


def test_vlm_patch_positions_and_loss_alignment():
    cfg = ModelCfg(name="v", family="vlm", n_patches=3, frontend_dim=12,
                   **TINY)
    p = model.init_params(cfg, KEY)
    b = {"tokens": jax.random.randint(KEY, (2, 8), 0, 61),
         "labels": jax.random.randint(KEY, (2, 8), 0, 61),
         "patches": jax.random.normal(KEY, (2, 3, 12))}
    logits, _ = model.forward(cfg, p, b)
    assert logits.shape == (2, 8, 61)   # text positions only
    loss, _ = model.loss_fn(cfg, p, b)
    assert np.isfinite(float(loss))


def test_encdec_cross_prefill_matches_forward():
    cfg = ModelCfg(name="ed", family="encdec", n_enc_layers=2, n_frames=5,
                   frontend_dim=12, norm="layernorm", act="gelu",
                   pos_embed="learned", max_position=64, **TINY)
    p = model.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 6), 0, 61)
    frames = jax.random.normal(KEY, (2, 5, 12))
    full, _ = model.forward(cfg, p, {"tokens": toks, "frames": frames})
    cache = model.init_cache(cfg, 2, 6, dtype=jnp.float32)
    cache = model.prefill_cross(cfg, p, cache, frames)
    outs = []
    for t in range(6):
        lo, cache = model.decode_step(cfg, p, cache, toks[:, t:t + 1])
        outs.append(lo)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               atol=2e-3)


def test_param_count_dyad_vs_dense():
    cfg_dyad = ModelCfg(name="a", family="lm", **TINY)
    cfg_dense = cfg_dyad.replace(linear=factory.DENSE)
    p_dyad = model.init_params(cfg_dyad, KEY)
    p_dense = model.init_params(cfg_dense, KEY)
    assert model.param_count(p_dyad) < model.param_count(p_dense)
    assert (model.non_embedding_param_count(p_dyad)
            < model.non_embedding_param_count(p_dense))
