"""Data pipeline invariants: determinism, resumability, elastic resharding."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticLM


def test_deterministic_per_step():
    d = SyntheticLM(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    b1, b2 = d.batch(5), d.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab_size=97, seq_len=16, global_batch=4)
    b = d.batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_stream_is_learnable_structure():
    """With p_copy=0.8 most transitions follow the fixed permutation."""
    d = SyntheticLM(vocab_size=50, seq_len=64, global_batch=8, p_copy=0.8)
    b = d.batch(0)
    perm = np.asarray(d._perm())
    toks = np.asarray(b["tokens"])
    labels = np.asarray(b["labels"])
    frac = (labels == perm[toks]).mean()
    assert 0.7 < frac < 0.95


@settings(max_examples=10, deadline=None)
@given(step=st.integers(0, 100), shards=st.sampled_from([1, 2, 4]))
def test_resharding_exactness(step, shards):
    """Different shard counts slice the SAME global stream."""
    whole = SyntheticLM(vocab_size=64, seq_len=8, global_batch=8,
                        num_shards=1)
    parts = [SyntheticLM(vocab_size=64, seq_len=8, global_batch=8,
                         shard=s, num_shards=shards).batch(step)
             for s in range(shards)]
    # per-shard batches must be deterministic and shard-distinct
    if shards > 1:
        assert not np.array_equal(np.asarray(parts[0]["tokens"]),
                                  np.asarray(parts[1]["tokens"]))
    for p in parts:
        assert p["tokens"].shape == (8 // shards, 8)


def test_classification_stream():
    from repro.data import SyntheticClassification
    d = SyntheticClassification(n_classes=10, dim=32, batch=64)
    b = d.batch_at(0)
    assert b["x"].shape == (64, 32)
    assert int(b["labels"].max()) < 10
    # same class -> nearby points (clusters are separable)
    x = np.asarray(b["x"]); y = np.asarray(b["labels"])
    same = np.linalg.norm(x[y == y[0]] - x[y == y[0]].mean(0), axis=1).mean()
    assert same < np.linalg.norm(x - x.mean(0), axis=1).mean()
