"""Loop-aware HLO statistics parser: the roofline's source of truth."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_stats


def test_scan_flops_multiplied_by_trip_count():
    """A scan of N matmuls must report ~N matmuls of FLOPs (cost_analysis
    reports ~1 — the bug this parser exists to fix)."""
    N, B, D = 10, 64, 128

    def body(c, w):
        return jnp.tanh(c @ w), None

    def f(x, w):
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((B, D), jnp.float32),
        jax.ShapeDtypeStruct((N, D, D), jnp.float32)).compile()
    stats = hlo_stats.module_stats(compiled.as_text(), 1)
    expect = N * 2 * B * D * D
    assert 0.9 * expect <= stats["flops"] <= 1.3 * expect, stats["flops"]

    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < 0.3 * expect   # documents the underlying problem


def test_loop_free_module_matches_cost_analysis():
    def f(a, b):
        return (a @ b).sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile()
    stats = hlo_stats.module_stats(compiled.as_text(), 1)
    expect = 2 * 32 * 64 * 16
    assert abs(stats["flops"] - expect) / expect < 0.05


def test_collective_parsing_synthetic():
    text = """HloModule test

%cond.1 (arg: (s32[], f32[8,8])) -> pred[] {
  %c = s32[] constant(5)
  %gte = s32[] get-tuple-element(%arg), index=0
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body.1 (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %gte1 = f32[8,8]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[8,8]{1,0} all-reduce(%gte1), replica_groups=[2,4]<=[8], to_apply=%sum
  ROOT %t = (s32[], f32[8,8]) tuple(%gte1, %ar)
}

ENTRY %main.1 (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %ag = f32[16,8]{1,0} all-gather(%p0), replica_groups=[4,2]<=[8], dimensions={0}
  %init = (s32[], f32[8,8]) tuple(%c0, %p0)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    stats = hlo_stats.module_stats(text, 8)
    # all-gather: 16*8*4 bytes * (2-1)/2 = 256;
    # all-reduce in 5-trip loop: 2*(8*8*4)*(4-1)/4 * 5 = 1920
    assert stats["collectives_by_op"]["all-gather"] == 16 * 8 * 4 * 0.5
    assert stats["collectives_by_op"]["all-reduce"] == 2 * 256 * 0.75 * 5
    assert stats["collective_count"] == 6


def test_bytes_accounting_nonzero_and_sane():
    def f(a, b):
        return jnp.tanh(a @ b)

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    stats = hlo_stats.module_stats(compiled.as_text(), 1)
    lo = 3 * 128 * 128 * 4            # two reads + one write
    assert lo <= stats["bytes"] <= 6 * lo
