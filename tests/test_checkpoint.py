"""Checkpoint manager: roundtrip, atomicity, GC, template addressing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, place


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32),
                  "d": [jnp.ones(3), jnp.zeros(())]}}


def test_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, async_save=False)
        m.save(7, t)
        step, r = m.restore(t)
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_n_gc():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep_n=2, async_save=False)
        for s in (1, 2, 3, 4):
            m.save(s, t)
        assert m.all_steps() == [3, 4]


def test_async_save_then_restore():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, async_save=True)
        m.save(1, t)
        m.wait()
        assert m.latest_step() == 1


def test_tmp_dirs_ignored():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, async_save=False)
        m.save(3, t)
        os.makedirs(os.path.join(d, "ckpt_9.tmp"))   # simulated crashed save
        assert m.latest_step() == 3


def test_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, async_save=False)
        m.save(1, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            m.restore({"a": jnp.ones((3, 3))})


def test_restore_newest_complete():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, async_save=False, keep_n=0)
        m.save(1, {"a": jnp.ones(2)})
        m.save(5, {"a": jnp.full(2, 5.0)})
        step, r = m.restore({"a": jnp.zeros(2)})
        assert step == 5 and float(r["a"][0]) == 5.0


def test_place_single_sharding():
    """Elastic restore path: host arrays -> device placement."""
    t = {"a": np.ones((4, 4)), "b": np.zeros(3)}
    placed = place(t, jax.devices()[0])
    assert all(isinstance(x, jax.Array) for x in jax.tree.leaves(placed))
