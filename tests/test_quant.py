"""Quantized DYAD serving: the repro.quant codec contract, the quantized
mm/ff Pallas kernels vs the fp oracles (through plan_tiles padding at
odd/prime dims), the int8 paged-KV decode path, dispatch/fallback routing
(sidecar presence x REPRO_KERNEL_QUANT x TP context), and the autotune
key/vmem plumbing — all in interpret mode."""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, obs, quant
from repro.core import factory
from repro.kernels import dyad_mm, ops, ref
from repro.layers import attention as attn_lib
from repro.layers import mlp as mlp_lib
from repro.models import model
from repro.perf import autotune
from repro.perf.autotune import tune_key
from repro.serve import ContinuousBatchingEngine

KEY = jax.random.PRNGKey(0)

QDTYPES = ["int8"] + (["fp8"] if quant.supports_fp8() else [])


def _w(i, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


def _dq(wq, ws):
    return quant.dequant(wq, ws, axis=-1)


# -- codec contract -----------------------------------------------------------


@pytest.mark.parametrize("dtype", QDTYPES)
def test_per_block_scale_exactness(dtype):
    """The scale contract: one fp32 scale per (block, out_row) over the
    contracted axis, scale = max|w| / qmax + eps, payload within half a
    step of the original (int8) — and every payload value representable."""
    w = _w(1, (3, 17, 29))
    wq, ws = quant.quantize_dyad_weight(w, dtype)
    assert wq.shape == w.shape and ws.shape == (3, 17)
    assert ws.dtype == jnp.float32
    qmax = 127.0 if dtype == "int8" else 448.0
    want = np.max(np.abs(np.asarray(w)), axis=-1) / qmax + 1e-12
    np.testing.assert_allclose(np.asarray(ws), want, rtol=1e-6)
    err = np.abs(np.asarray(_dq(wq, ws)) - np.asarray(w))
    if dtype == "int8":
        # round-to-nearest: at most half a quantization step per element
        assert np.all(err <= 0.5 * np.asarray(ws)[..., None] + 1e-7)
    else:
        assert np.max(err / np.asarray(ws)[..., None]) < 32.0  # fp8 mantissa


def test_quantize_dyad_weight_shape_guard():
    with pytest.raises(ValueError, match="DYAD"):
        quant.quantize_dyad_weight(_w(1, (8, 8)))
    with pytest.raises(ValueError, match="unknown quantization dtype"):
        quant.resolve_dtype("int4")


def test_quantize_params_sidecars_and_stacked():
    """quantize_params adds w1_q/w1_s/w2_q/w2_s SIDECARS (originals
    retained) to every DYAD module — including layer-STACKED 4-D weights,
    whose scales keep the leading layer axis for lax.scan slicing."""
    lc = factory.LinearCfg(impl="dyad", n_dyad=2, variant="it")
    p = mlp_lib.init_mlp(KEY, 16, 32, lc, act="gelu")
    q = quant.quantize_params(p, "int8")
    assert quant.ff_quantized(q) and not quant.ff_quantized(p)
    np.testing.assert_array_equal(np.asarray(q["up"]["w1"]),
                                  np.asarray(p["up"]["w1"]))
    assert q["up"]["w1_q"].dtype == jnp.int8
    stacked = {"mlp": {"up": {"w1": _w(1, (3, 2, 8, 8)),
                              "w2": _w(2, (3, 2, 8, 8))}}}
    qs = quant.quantize_params(stacked)
    assert qs["mlp"]["up"]["w1_s"].shape == (3, 2, 8)
    # per-layer slices match independently-quantized layers
    lone_q, lone_s = quant.quantize_dyad_weight(stacked["mlp"]["up"]["w1"][1])
    np.testing.assert_array_equal(np.asarray(qs["mlp"]["up"]["w1_q"][1]),
                                  np.asarray(lone_q))
    np.testing.assert_allclose(np.asarray(qs["mlp"]["up"]["w1_s"][1]),
                               np.asarray(lone_s), rtol=1e-6)


def test_compress_reexports_shared_codec():
    """The gradient compressor's codec IS repro.quant's (satellite:
    single implementation)."""
    from repro.optim import compress

    assert compress._quant_int8 is quant.quant_int8
    assert compress._dequant_int8 is quant.dequant_int8


# -- quantized mm kernels vs oracle -------------------------------------------

# (B, n, d_in, d_out): healthy, odd/prime through plan_tiles padding
MM_SHAPES = [(16, 4, 32, 32), (9, 3, 70, 130), (7, 2, 129, 67)]


@pytest.mark.parametrize("variant", ["it", "ot", "dt"])
@pytest.mark.parametrize("B,n,d_in,d_out", MM_SHAPES)
def test_quant_mm_matches_dequant_oracle(variant, B, n, d_in, d_out):
    """The in-kernel epilogue-multiply dequant must equal running the
    einsum oracle on EXPLICITLY dequantized weights — the scale is
    constant along the contraction, so the factorization is exact."""
    w1, w2 = _w(1, (n, d_out, d_in)), _w(2, (n, d_out, d_in))
    w1q, s1 = quant.quantize_dyad_weight(w1)
    w2q, s2 = quant.quantize_dyad_weight(w2)
    x = _w(3, (B, n * d_in))
    want = ref.dyad_mm_ref(x, _dq(w1q, s1), _dq(w2q, s2), variant=variant)
    got = ops.dyad_mm_quant(x, w1q, w2q, s1, s2, variant=variant)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", QDTYPES)
@pytest.mark.parametrize("act", ["gelu", "swiglu"])
def test_quant_ff_megakernel_matches_dequant_oracle(act, dtype):
    n, d_in, d_ffb, d_out = 2, 24, 37, 24        # odd hidden: j padding
    gated = act == "swiglu"
    names = ("wg1", "wg2", "wu1", "wu2") if gated else ("wu1", "wu2")
    ws = {nm: _w(i, (n, d_ffb, d_in)) for i, nm in enumerate(names)}
    ws["wd1"], ws["wd2"] = _w(7, (n, d_out, d_ffb)), _w(8, (n, d_out, d_ffb))
    qs = {nm: quant.quantize_dyad_weight(w, dtype) for nm, w in ws.items()}
    x = _w(9, (6, n * d_in))
    dq = {nm: _dq(*qs[nm]) for nm in qs}
    want = ref.dyad_ff_ref(x, dq["wu1"], dq["wu2"], dq["wd1"], dq["wd2"],
                           dq.get("wg1"), dq.get("wg2"), act=act)
    x1, x2 = ref.block_views(x, n, "it")
    gate_kw = {}
    if gated:
        gate_kw = dict(wg1=qs["wg1"][0], wg2=qs["wg2"][0],
                       sg1=qs["wg1"][1], sg2=qs["wg2"][1])
    z1, z2 = dyad_mm.dyad_ff_fused_q(
        x1, x2, qs["wu1"][0], qs["wu2"][0], qs["wd1"][0], qs["wd2"][0],
        qs["wu1"][1], qs["wu2"][1], qs["wd1"][1], qs["wd2"][1],
        act=act, interpret=True, **gate_kw)
    got = ref.combine(z1, z2, "ot")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_quant_ff_fused_vs_split_route(monkeypatch):
    """REPRO_KERNEL_FF=split composes the quantized mm kernels (up, XLA
    act, down) — same numbers as the quantized megakernel route."""
    lc = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                           use_kernel=True, fuse_ff_kernel=True,
                           quant="int8")
    p = quant.quantize_params(mlp_lib.init_mlp(KEY, 32, 64, lc, act="gelu"))
    x = _w(1, (8, 32))
    monkeypatch.setenv("REPRO_KERNEL_FF", "fused")
    y_fused = ops.dyad_ff_quant(p, x, act="gelu")
    monkeypatch.setenv("REPRO_KERNEL_FF", "split")
    y_split = ops.dyad_ff_quant(p, x, act="gelu")
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_split),
                               rtol=2e-5, atol=2e-5)


def test_quant_bf16_activations():
    """bf16 activation dataflow is unchanged: int8 payloads (<= 127) cast
    exactly to bf16 inside the kernel, the fp32 scale rides the epilogue."""
    lc = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                           use_kernel=True, fuse_ff_kernel=True,
                           quant="int8")
    p = quant.quantize_params(mlp_lib.init_mlp(KEY, 32, 64, lc, act="gelu"))
    x = _w(1, (8, 32)).astype(jnp.bfloat16)
    y = ops.dyad_ff_quant(p, x, act="gelu")
    assert y.dtype == jnp.bfloat16
    want = ref.dyad_ff_ref(
        x.astype(jnp.float32), _dq(p["up"]["w1_q"], p["up"]["w1_s"]),
        _dq(p["up"]["w2_q"], p["up"]["w2_s"]),
        _dq(p["down"]["w1_q"], p["down"]["w1_s"]),
        _dq(p["down"]["w2_q"], p["down"]["w2_s"]), act="gelu")
    scale = max(float(np.max(np.abs(np.asarray(want)))), 1.0)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(want),
                               rtol=5e-2, atol=5e-2 * scale)


# -- dispatch & fallback map --------------------------------------------------


def _quant_lc(**kw):
    return factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                             use_kernel=True, fuse_ff_kernel=True,
                             quant="int8", **kw)


def test_apply_mlp_quant_dispatch_and_fallbacks(monkeypatch):
    """The routing contract: quant cfg + sidecars -> quantized kernels;
    missing sidecars (training params) -> fp megakernel, SAME numbers as
    no-quant cfg; REPRO_KERNEL_QUANT=off -> BIT-identical fp route."""
    lc = _quant_lc()
    p_fp = mlp_lib.init_mlp(KEY, 32, 64, lc, act="gelu")
    p_q = quant.quantize_params(p_fp)
    x = _w(1, (2, 5, 32))

    obs.reset_route_counts()
    assert mlp_lib._ff_quant_ready(p_q, lc, "gelu")
    assert obs.routes_snapshot() == {"ff_quant:int8": 1}
    y_q = mlp_lib.apply_mlp(p_q, x, lc, act="gelu")
    y_fp = mlp_lib.apply_mlp(p_fp, x, lc.replace(quant=None), act="gelu")
    # int8 weights: close to fp, not equal (proves the quant route ran)
    scale = max(float(np.max(np.abs(np.asarray(y_fp)))), 1.0)
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_fp),
                               rtol=2e-2, atol=2e-2 * scale)
    assert np.max(np.abs(np.asarray(y_q) - np.asarray(y_fp))) > 0

    # no sidecars -> fp fallback, identical to the unquantized cfg
    obs.reset_route_counts()
    assert not mlp_lib._ff_quant_ready(p_fp, lc, "gelu")
    assert obs.routes_snapshot() == {"ff_quant:fp_fallback": 1}
    np.testing.assert_array_equal(
        np.asarray(mlp_lib.apply_mlp(p_fp, x, lc, act="gelu")),
        np.asarray(y_fp))

    # escape hatch: sidecars PRESENT but env off -> bit-identical fp route
    monkeypatch.setenv("REPRO_KERNEL_QUANT", "off")
    obs.reset_route_counts()
    assert not mlp_lib._ff_quant_ready(p_q, lc, "gelu")
    assert obs.routes_snapshot() == {"ff_quant:off": 1}
    np.testing.assert_array_equal(
        np.asarray(mlp_lib.apply_mlp(p_q, x, lc, act="gelu")),
        np.asarray(y_fp))


def test_quant_dispatch_under_sharding_ctx():
    """A sharding context keeps the quant route live (single-device mesh:
    the TP wrapper's tp==1 path delegates straight to the kernel — same
    numbers as the uncontexted dispatch)."""
    from jax.sharding import Mesh
    from repro.kernels import tp as ktp
    from repro.sharding import ctx as shard_ctx

    lc = _quant_lc()
    p = quant.quantize_params(mlp_lib.init_mlp(KEY, 32, 64, lc, act="gelu"))
    x = _w(1, (8, 32))
    y_plain = mlp_lib.apply_mlp(p, x, lc, act="gelu")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
        obs.reset_route_counts()
        assert mlp_lib._ff_quant_ready(p, lc, "gelu")
        assert obs.routes_snapshot() == {"ff_quant:int8": 1}
        ctx = shard_ctx.current()
        y_tp = ktp.dyad_ff_quant_tp(p, x, act="gelu", ctx=ctx)
    np.testing.assert_array_equal(np.asarray(y_tp), np.asarray(y_plain))


def test_factory_apply_quant_single_mm():
    """Non-ff scope: factory.apply streams a single quantized dyad_mm when
    the module carries sidecars (counted under mm_quant)."""
    lc = factory.LinearCfg(impl="dyad", n_dyad=4, variant="ot",
                           use_kernel=True, quant="int8")
    p = quant.quantize_params(
        factory.init(KEY, 32, 48, lc, site="ff", bias=False))
    x = _w(1, (6, 32))
    obs.reset_route_counts()
    y = factory.apply(p, x, lc, site="ff")
    assert obs.routes_snapshot()["mm_quant:int8"] == 1
    want = ref.dyad_mm_ref(x, _dq(p["w1_q"], p["w1_s"]),
                           _dq(p["w2_q"], p["w2_s"]), variant="ot")
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_linear_cfg_quant_spec_tokens():
    assert configs.linear_cfg("dyad_it_4_kernel_ffused_w8").quant == "int8"
    assert configs.linear_cfg("dyad_it_4_kernel_ffused_wfp8").quant == "fp8"
    assert configs.linear_cfg("dyad_it_4_kernel_ffused").quant is None


# -- int8 paged KV ------------------------------------------------------------


def test_paged_kv_cache_quant_layout():
    c = attn_lib.init_paged_kv_cache(2, 32, 2, 16, page_size=8, n_pages=9,
                                     quant="int8")
    assert c["pages_k"].dtype == jnp.int8
    assert c["scales_k"].shape == (9, 8, 2)
    assert c["scales_k"].dtype == jnp.float32
    with pytest.raises(ValueError, match="int8"):
        attn_lib.init_paged_kv_cache(2, 32, 2, 16, page_size=8, n_pages=9,
                                     quant="fp8")
    # unquantized layout unchanged
    d = attn_lib.init_paged_kv_cache(2, 32, 2, 16, page_size=8, n_pages=9)
    assert "scales_k" not in d and d["pages_k"].dtype == jnp.bfloat16


def test_quant_paged_decode_kernel_vs_dequant_oracle():
    """The in-kernel per-token-row dequant (scores scaled per key column,
    probabilities scaled per row before PV) vs the same kernel on
    explicitly dequantized pools."""
    from repro.kernels import flash_attn as fa

    rng = np.random.default_rng(0)
    B, K, G, h, P, NB = 3, 2, 2, 64, 8, 5
    NP = 1 + B * NB
    q = jnp.asarray(rng.normal(size=(B, K, G, h)), jnp.float32)
    bt = np.arange(1, NP, dtype=np.int32).reshape(B, NB)
    idx = np.array([13, 37, 29], np.int32)
    kq, ks = quant.quantize_kv_rows(
        jnp.asarray(rng.normal(size=(NP, P, K, h)), jnp.float32))
    vq, vs = quant.quantize_kv_rows(
        jnp.asarray(rng.normal(size=(NP, P, K, h)), jnp.float32))
    for window in (None, 7):
        o_q = fa.flash_decode_paged(
            q, kq, vq, jnp.asarray(bt), jnp.asarray(idx), scales_k=ks,
            scales_v=vs, window=window, interpret=True)
        o_f = fa.flash_decode_paged(
            q, _dq(kq, ks).astype(jnp.float32), _dq(vq, vs).astype(
                jnp.float32), jnp.asarray(bt), jnp.asarray(idx),
            window=window, interpret=True)
        np.testing.assert_allclose(np.asarray(o_q), np.asarray(o_f),
                                   rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError, match="together"):
        fa.flash_decode_paged(q, kq, vq, jnp.asarray(bt), jnp.asarray(idx),
                              scales_k=ks, interpret=True)


@functools.lru_cache(maxsize=None)
def _small_model():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    return cfg, model.init_params(cfg, KEY)


def _engine_tokens(cfg, params, **kw):
    eng = ContinuousBatchingEngine(cfg, params, n_slots=2, max_len=24,
                                   page_size=4, **kw)
    rng = np.random.default_rng(7)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, size=(s,)), 5)
            for s in (9, 6)]
    out = eng.run()
    return [out[u] for u in uids]


def test_int8_kv_token_match_real_model(monkeypatch):
    """Greedy decode on the real smoke model: int8 paged KV (flash decode
    kernel dequantizing in-VMEM) must reproduce the fp cache's tokens."""
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    cfg, params = _small_model()
    obs.reset_route_counts()
    got = _engine_tokens(cfg.replace(kv_quant="int8"), params)
    assert obs.routes_snapshot().get("kv_quant:int8", 0) >= 1
    want = _engine_tokens(cfg, params)
    assert got == want


def test_kv_quant_dense_view_fallback():
    """Without the flash route (einsum oracle path) the quantized pool is
    dequantized in XLA after the dense-view gather — tokens still match
    the fp cache."""
    cfg, params = _small_model()
    got = _engine_tokens(cfg.replace(kv_quant="int8"), params)
    want = _engine_tokens(cfg, params)
    assert got == want


def test_kv_quant_env_escape_hatch(monkeypatch):
    """REPRO_KERNEL_QUANT=off keeps the paged pools in the engine dtype:
    no scale leaves, bit-identical to a config without kv_quant."""
    monkeypatch.setenv("REPRO_KERNEL_QUANT", "off")
    cfg, params = _small_model()
    eng = ContinuousBatchingEngine(cfg.replace(kv_quant="int8"), params,
                                   n_slots=2, max_len=16, page_size=4)
    assert "scales_k" not in eng.cache["kv"]
    assert eng.cache["kv"]["pages_k"].dtype != jnp.int8


# -- autotune plumbing --------------------------------------------------------


def test_quant_tune_keys_distinct():
    """_w8 op keys carry the PAYLOAD dtype — int8 and fp8 sweeps must not
    collide with each other or with the bf16 kernel's entries."""
    base = tune_key("dyad_ff_fused", 32, 4, 8, 8, "bfloat16", d_mid=16)
    k8 = tune_key("dyad_ff_fused_w8", 32, 4, 8, 8, "int8", d_mid=16)
    kf8 = tune_key("dyad_ff_fused_w8", 32, 4, 8, 8, "float8_e4m3fn",
                   d_mid=16)
    assert len({base, k8, kf8}) == 3
    assert "int8" in k8 and "float8_e4m3fn" in kf8


def test_dtype_bytes_fp8_and_unknown():
    assert autotune._dtype_bytes("float8_e4m3fn") == 1
    assert autotune._dtype_bytes("int8") == 1
    assert autotune._dtype_bytes("bfloat16") == 2
    with pytest.raises(ValueError, match="unknown dtype"):
        autotune._dtype_bytes("float4_e2m1")


def test_vmem_estimate_quant_weights_cheaper():
    """Quantized weight streams price at payload bytes (+ fp32 scale
    tiles): the estimate must drop vs the same tiles at bf16 weights."""
    full = autotune.vmem_estimate_ff(64, 128, 128, 256, "bfloat16")
    q = autotune.vmem_estimate_ff(64, 128, 128, 256, "bfloat16",
                                  w_dtype="int8")
    assert q < full
    fullm = autotune.vmem_estimate(64, 128, 128, "bfloat16")
    qm = autotune.vmem_estimate(64, 128, 128, "bfloat16", w_dtype="int8")
    assert qm < fullm


def test_autotune_quant_op_runs(tmp_path):
    """autotune_dyad on a _w8 op quantizes its sweep weights and lands a
    cache entry under the payload-dtype key."""
    from repro.perf.autotune import BlockCache

    c = BlockCache(user_path=str(tmp_path / "b.json"),
                   defaults_path=str(tmp_path / "d.json"))
    autotune.reset_cache(c)
    try:
        best, _ = autotune.autotune_dyad(
            "dyad_mm_blocks_w8", 8, 2, 16, 16, dtype="int8", iters=1,
            candidates=[{"block_b": 8, "block_o": 128, "block_k": 128}])
        assert best == {"block_b": 8, "block_o": 128, "block_k": 128}
        key = tune_key("dyad_mm_blocks_w8", 8, 2, 16, 16, "int8")
        assert c.get(key) is not None
    finally:
        autotune.reset_cache(None)


def test_ensure_tuned_covers_quant_ops(tmp_path, monkeypatch):
    """A quant-configured model tunes the _w8 twins of its mm and ff ops."""
    from repro.perf.autotune import BlockCache, ensure_tuned_for_model

    c = BlockCache(user_path=str(tmp_path / "b.json"),
                   defaults_path=str(tmp_path / "d.json"))
    autotune.reset_cache(c)
    try:
        cfg, _ = _small_model()
        cfg = cfg.replace(linear=configs.linear_cfg(
            "dyad_it_4_kernel_ffused_w8"))
        tuned = ensure_tuned_for_model(cfg, tokens=4, iters=1)
        w8 = [k for k in tuned if "_w8|" in k]
        assert any(k.startswith("dyad_ff_fused") for k in w8)
        assert all("|int8|" in k for k in w8)
        # escape hatch: env off tunes NO quant twins
        monkeypatch.setenv("REPRO_KERNEL_QUANT", "off")
        c2 = BlockCache(user_path=str(tmp_path / "b2.json"),
                        defaults_path=str(tmp_path / "d2.json"))
        autotune.reset_cache(c2)
        tuned = ensure_tuned_for_model(cfg, tokens=4, iters=1)
        assert not any("_w8|" in k for k in tuned)
    finally:
        autotune.reset_cache(None)
