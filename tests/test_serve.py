"""Serving engine: prefill/decode equivalence, greedy determinism."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model
from repro.serve import Engine, make_serve_step, prefill

KEY = jax.random.PRNGKey(0)


def test_prefill_then_decode_matches_forward():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    cache = model.init_cache(cfg, 2, 8, dtype=jnp.float32)
    last, cache = prefill(cfg, p, cache, toks)
    full, _ = model.forward(cfg, p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-3)


def test_greedy_generation_deterministic():
    cfg = configs.get("opt125m", smoke=True)
    p = model.init_params(cfg, KEY)
    eng = Engine(cfg, p, max_len=24)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    a = eng.generate(prompts, 8)
    b = eng.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 8)


def test_serve_step_signature_decode_cells():
    """The exact function the decode dry-run cells lower."""
    cfg = configs.get("mamba2_780m", smoke=True)
    p = model.init_params(cfg, KEY)
    step = jax.jit(make_serve_step(cfg))
    cache = model.init_cache(cfg, 2, 16, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = step(p, cache, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
