"""Serving engine: prefill/decode equivalence, greedy determinism, scan
decode vs Python loop, and continuous-batching slot lifecycle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import (ContinuousBatchingEngine, Engine, make_serve_step,
                         prefill, prefill_tokenwise)

KEY = jax.random.PRNGKey(0)


def test_prefill_then_decode_matches_forward():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    cache = model.init_cache(cfg, 2, 8, dtype=jnp.float32)
    last, cache = prefill(cfg, p, cache, toks)
    full, _ = model.forward(cfg, p, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "mamba2_780m", "hymba_1_5b"])
def test_single_pass_prefill_matches_tokenwise(arch):
    """The tentpole equivalence: ONE full-sequence forward with cache writes
    must reproduce the seed's token-wise loop — logits AND every cache leaf
    (KV contents, write indices, SSM conv tail + recurrent state)."""
    cfg = configs.get(arch, smoke=True)
    p = model.init_params(cfg, KEY)
    B, S, M = 2, 6, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    c_ref = model.init_cache(cfg, B, M, dtype=jnp.float32)
    lo_ref, c_ref = prefill_tokenwise(cfg, p, c_ref, toks)
    c_new = model.init_cache(cfg, B, M, dtype=jnp.float32)
    lo_new, c_new = prefill(cfg, p, c_new, toks)
    np.testing.assert_allclose(np.asarray(lo_new[:, -1]),
                               np.asarray(lo_ref[:, -1]), atol=3e-3)
    for a, b in zip(jax.tree.leaves(c_ref), jax.tree.leaves(c_new)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-3, rtol=1e-2)
    # decode continues identically from either cache
    tok = jnp.argmax(lo_new[:, -1:], axis=-1)
    d_ref, _ = model.decode_step(cfg, p, c_ref, tok)
    d_new, _ = model.decode_step(cfg, p, c_new, tok)
    np.testing.assert_allclose(np.asarray(d_new), np.asarray(d_ref), atol=3e-3)


def test_greedy_generation_deterministic():
    cfg = configs.get("opt125m", smoke=True)
    p = model.init_params(cfg, KEY)
    eng = Engine(cfg, p, max_len=24)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    a = eng.generate(prompts, 8)
    b = eng.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 8)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_scan_decode_matches_python_loop(temperature):
    """The jitted lax.scan decode must emit exactly what the seed Python
    loop emits (same key schedule, greedy and sampled)."""
    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    eng = Engine(cfg, p, max_len=24)
    prompts = jax.random.randint(KEY, (2, 5), 0, cfg.vocab_size)
    key = KEY if temperature > 0 else None
    a = eng.generate(prompts, 10, temperature=temperature, key=key)
    b = eng.generate_reference(prompts, 10, temperature=temperature, key=key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_step_signature_decode_cells():
    """The exact function the decode dry-run cells lower."""
    cfg = configs.get("mamba2_780m", smoke=True)
    p = model.init_params(cfg, KEY)
    step = jax.jit(make_serve_step(cfg))
    cache = model.init_cache(cfg, 2, 16, dtype=jnp.float32)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = step(p, cache, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_windowed_prompt_longer_than_window():
    """Single-pass prefill with prompt > sliding window: attention must
    attend the full in-flight K/V and persist only the last `window` tokens
    at their ring slots — matching the seed's token-wise ring writes."""
    cfg = configs.get("hymba_1_5b", smoke=True)
    assert cfg.window is not None
    p = model.init_params(cfg, KEY)
    eng = Engine(cfg, p, max_len=32)
    prompts = jax.random.randint(KEY, (2, cfg.window + 4), 0, cfg.vocab_size)
    a = eng.generate(prompts, 6)
    b = eng.generate_reference(prompts, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # per-slot (continuous batching) variant of the same ring layout
    cbe = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=32)
    uids = [cbe.submit(np.asarray(prompts[i]), 6) for i in range(2)]
    res = cbe.run()
    for i, u in enumerate(uids):
        np.testing.assert_array_equal(np.asarray(res[u]), np.asarray(a[i]))


def test_continuous_batching_matches_engine():
    """Heterogeneous requests through the shared padded step must produce the
    same greedy tokens as independent batched generation."""
    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    eng = Engine(cfg, p, max_len=32)
    cbe = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=32)
    pa = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab_size)
    pb = jax.random.randint(jax.random.PRNGKey(2), (1, 7), 0, cfg.vocab_size)
    ua = cbe.submit(np.asarray(pa[0]), 6)
    ub = cbe.submit(np.asarray(pb[0]), 6)
    res = cbe.run()
    np.testing.assert_array_equal(np.asarray(res[ua]),
                                  np.asarray(eng.generate(pa, 6)[0]))
    np.testing.assert_array_equal(np.asarray(res[ub]),
                                  np.asarray(eng.generate(pb, 6)[0]))


def test_slot_retirement_frees_capacity():
    """More requests than slots: finished sequences must retire and queued
    requests must be admitted into the freed slots until the queue drains."""
    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    cbe = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=24)
    prompts = jax.random.randint(KEY, (5, 4), 0, cfg.vocab_size)
    uids = [cbe.submit(np.asarray(prompts[i]), 3 + i % 3) for i in range(5)]
    assert cbe.slots.free_slots == 0 and len(cbe.queue) == 3
    max_active = 0
    results = {}
    while cbe.slots.active or cbe.queue:
        max_active = max(max_active, len(cbe.slots.active))
        for req in cbe.step():
            results[req.uid] = req.tokens
    results.update({r.uid: r.tokens for r in cbe.finished})
    assert max_active <= 2
    assert set(results) == set(uids)
    for i, u in enumerate(uids):
        assert len(results[u]) == 3 + i % 3
    # all slots returned to the pool
    assert cbe.slots.free_slots == 2 and not cbe.slots.active


def test_eos_retires_early():
    """A sampled EOS must end the request before its length budget."""
    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    prompts = jax.random.randint(KEY, (1, 4), 0, cfg.vocab_size)
    eng = Engine(cfg, p, max_len=32)
    greedy = np.asarray(eng.generate(prompts, 8)[0])
    eos = int(greedy[2])              # a token the model will greedily emit
    first_hit = int(np.flatnonzero(greedy == eos)[0])
    cbe = ContinuousBatchingEngine(cfg, p, n_slots=1, max_len=32, eos_id=eos)
    uid = cbe.submit(np.asarray(prompts[0]), 8)
    res = cbe.run()
    assert res[uid][-1] == eos
    # stopped at the first EOS occurrence, not the 8-token budget
    assert len(res[uid]) == first_hit + 1 < 8
