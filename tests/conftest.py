"""Tier-1 test configuration.

The suite must collect on a bare container (jax + pytest only).  The real
``hypothesis`` is a declared dev dependency (``pip install -e ".[test]"``,
what CI runs); when it is missing locally, the deterministic fallback stub
from ``tests/_hypothesis_stub.py`` is installed under the ``hypothesis`` /
``hypothesis.strategies`` module names BEFORE test modules import it.
Set ``REPRO_REQUIRE_HYPOTHESIS=1`` (CI does) to fail loudly instead of
falling back — the stub can never silently mask a broken install there.
"""
import importlib.util
import os
import sys


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        if os.environ.get("REPRO_REQUIRE_HYPOTHESIS"):
            raise RuntimeError(
                "REPRO_REQUIRE_HYPOTHESIS is set but hypothesis is not "
                "installed — run `pip install -e \".[test]\"`")
    import types

    # load relative to this file — works for both `python -m pytest` and a
    # bare `pytest` (where the repo root is not on sys.path)
    spec = importlib.util.spec_from_file_location(
        "_hypothesis_stub",
        os.path.join(os.path.dirname(__file__), "_hypothesis_stub.py"))
    stub = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(stub)

    mod = types.ModuleType("hypothesis")
    mod.given = stub.given
    mod.settings = stub.settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = stub.integers
    strategies.sampled_from = stub.sampled_from
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


_install_hypothesis_stub()


import pytest  # noqa: E402


@pytest.fixture(autouse=True)
def _isolated_autotune_cache(tmp_path):
    """Keep kernel tile resolution reproducible: never let the developer's
    ~/.cache/repro_perf (or the packaged defaults) leak tile choices into
    tests.  Tests that exercise the cache install their own (test_perf)."""
    from repro.perf import autotune

    autotune.reset_cache(autotune.BlockCache(
        user_path=str(tmp_path / "autotune-blocks.json"),
        defaults_path=str(tmp_path / "autotune-defaults.json")))
    yield
    autotune.reset_cache(None)
