"""Flash-attention kernels: oracle equivalence across GQA/mask/dtype/odd
shapes, ring-cache decode, backward routes, dispatch gating, and autotune
integration (trace-time tile resolution)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attn as fa
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.layers import attention as attn_lib
from repro.perf import autotune
from repro.perf.autotune import BlockCache, tune_key

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def cache(tmp_path):
    """Isolated BlockCache installed as the process singleton."""
    c = BlockCache(user_path=str(tmp_path / "blocks.json"),
                   defaults_path=str(tmp_path / "defaults.json"))
    autotune.reset_cache(c)
    yield c
    autotune.reset_cache(None)


def _rand(B, S, T, K, G, h, dtype=jnp.float32, key=KEY):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, K, G, h), dtype)
    k = jax.random.normal(ks[1], (B, T, K, h), dtype)
    v = jax.random.normal(ks[2], (B, T, K, h), dtype)
    return q, k, v, ks[3]


def _ring_kpos(idx, L):
    j = jnp.arange(L)
    kpos = idx - (idx - j) % L
    return jnp.where(kpos >= 0, kpos, -(10 ** 9))


# -- forward vs oracle --------------------------------------------------------


@pytest.mark.parametrize("K,G", [(2, 1), (2, 2), (1, 4)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 7),
                                           (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefill_vs_oracle(K, G, causal, window, dtype):
    """Kernel vs the einsum oracle across GQA ratios x masks x dtypes, at
    a prime S=T so both grid axes go through tile padding."""
    S = T = 37
    q, k, v, _ = _rand(2, S, T, K, G, 16, dtype)
    want = ref.sdpa_ref(q, k, v, jnp.arange(S), jnp.arange(T),
                        causal=causal, window=window)
    got, _ = fa.flash_prefill(q, k, v, causal=causal, window=window,
                              block_q=16, block_k=128, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_prefill_tile_invariance():
    """Different tile choices change only the schedule, never the values."""
    q, k, v, _ = _rand(1, 64, 64, 2, 2, 32)
    outs = [fa.flash_prefill(q, k, v, causal=True, window=9, block_q=bq,
                             block_k=bk, interpret=True)[0]
            for bq, bk in [(8, 128), (32, 128), (64, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5)


def test_prefill_offsets():
    """Contiguous positions from nonzero q/k offsets (the fresh-stream
    cache-prefill contract: q_off = k_off = idx)."""
    S = T = 24
    q, k, v, _ = _rand(2, S, T, 2, 2, 16)
    for qo, ko in [(5, 0), (7, 7)]:
        want = ref.sdpa_ref(q, k, v, qo + jnp.arange(S), ko + jnp.arange(T),
                            causal=True, window=6)
        got, _ = fa.flash_prefill(q, k, v, qo, ko, causal=True, window=6,
                                  block_q=8, block_k=128, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)


def test_fully_masked_rows_are_zero():
    """A row with no valid key yields 0 — the guard the kernels implement
    explicitly and `_naive_sdpa` gained for parity with `_chunked_sdpa`."""
    S = T = 8
    q, k, v, _ = _rand(1, S, T, 2, 1, 16)
    # every key strictly in the future of every query -> causal masks all
    got, _ = fa.flash_prefill(q, k, v, 0, 100, causal=True,
                              block_q=8, block_k=128, interpret=True)
    assert np.all(np.asarray(got) == 0.0)
    dead = jnp.full((T,), -(10 ** 9))
    naive = attn_lib._naive_sdpa(q, k, v, jnp.arange(S), dead, True, None)
    assert np.all(np.isfinite(np.asarray(naive)))
    assert np.all(np.asarray(naive) == 0.0)
    chunked = attn_lib._chunked_sdpa(q, k, v, jnp.arange(S), dead, True,
                                     None, 4)
    assert np.all(np.asarray(chunked) == 0.0)
    qblock = attn_lib._q_block_sdpa(q, k, v, jnp.arange(S), dead, True,
                                    None, 4)
    assert np.all(np.asarray(qblock) == 0.0)


def test_naive_matches_independent_oracle():
    """The two oracles (layers._naive_sdpa, kernels.ref.sdpa_ref) agree —
    they are deliberately independent implementations."""
    q, k, v, _ = _rand(2, 13, 13, 2, 2, 16)
    a = attn_lib._naive_sdpa(q, k, v, jnp.arange(13), jnp.arange(13),
                             True, 5)
    b = ref.sdpa_ref(q, k, v, jnp.arange(13), jnp.arange(13),
                     causal=True, window=5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# -- the q-block scan fallback (satellite) ------------------------------------


@pytest.mark.parametrize("window", [None, 9])
def test_q_block_scan_matches_naive(window):
    """The lax.scan rewrite of `_q_block_sdpa` (O(1) trace size) must stay
    bit-compatible with the naive oracle, including the runtime band skip."""
    S = T = 64
    q, k, v, _ = _rand(2, S, T, 2, 2, 16)
    qpos, kpos = jnp.arange(S), jnp.arange(T)
    want = attn_lib._naive_sdpa(q, k, v, qpos, kpos, True, window)
    got = attn_lib._q_block_sdpa(q, k, v, qpos, kpos, True, window, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_q_block_scan_trace_is_depth_independent():
    """The whole point of the scan: the jaxpr no longer grows with S."""
    def n_eqns(S):
        q = jnp.zeros((1, S, 2, 1, 16))
        k = jnp.zeros((1, S, 2, 16))
        jaxpr = jax.make_jaxpr(
            lambda q, k: attn_lib._q_block_sdpa(
                q, k, k, jnp.arange(S), jnp.arange(S), True, None, 16)
        )(q, k)
        return len(jaxpr.jaxpr.eqns)
    assert n_eqns(256) == n_eqns(64)


# -- ring-cache decode --------------------------------------------------------


@pytest.mark.parametrize("L,idxs,window", [
    (8, [3], None),            # scalar idx, unwrapped
    (8, [11], 8),              # scalar idx, wrapped ring
    (8, [3, 11], 8),           # per-slot idx, mixed wrap state
    (10, [5, 20, 16], 7),      # odd L through tile padding
])
def test_decode_ring_equivalence(L, idxs, window):
    B, K, G, h = len(idxs), 2, 2, 16
    q, _, _, kk = _rand(B, 1, L, K, G, h)
    k = jax.random.normal(kk, (B, L, K, h))
    v = jax.random.normal(jax.random.fold_in(kk, 1), (B, L, K, h))
    idx = (jnp.asarray(idxs, jnp.int32) if B > 1
           else jnp.int32(idxs[0]))
    want = jnp.concatenate([
        ref.sdpa_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                     jnp.array([idxs[b]]), _ring_kpos(idxs[b], L),
                     causal=True, window=window)
        for b in range(B)], axis=0)
    got = fa.flash_decode(q, k, v, idx, window=window, block_k=128,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_mixed_cache_dtype():
    """bf16 KV cache under an fp32 query (and vice versa) promotes
    per-tile in VMEM instead of failing the kernel dot."""
    B, L, K, G, h = 2, 8, 2, 2, 16
    q, k, v, _ = _rand(B, 1, L, K, G, h)
    idx = jnp.int32(5)
    want = ref.sdpa_ref(q, k.astype(jnp.bfloat16).astype(jnp.float32),
                        v.astype(jnp.bfloat16).astype(jnp.float32),
                        jnp.array([5]), _ring_kpos(5, L), causal=True)
    got = fa.flash_decode(q, k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16), idx, block_k=128,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-2)


# -- backward -----------------------------------------------------------------


@pytest.mark.parametrize("route", ["pallas", "xla"])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 5)])
def test_backward_vs_einsum_vjp(route, causal, window, monkeypatch):
    """Both kernel-backward routes (flash Pallas kernels, compiled XLA
    recompute) against autodiff of the einsum oracle."""
    S = T = 24
    q, k, v, _ = _rand(2, S, T, 2, 2, 16)
    monkeypatch.setenv("REPRO_KERNEL_BWD", route)
    kops._make_flash_attention.cache_clear()

    def loss(use_kernel_bwd):
        return lambda q, k, v: (kops.flash_attention(
            q, k, v, causal=causal, window=window,
            use_kernel_bwd=use_kernel_bwd) ** 2).sum()

    want = jax.grad(loss(False), argnums=(0, 1, 2))(q, k, v)
    got = jax.jit(jax.grad(loss(True), argnums=(0, 1, 2)))(q, k, v)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    kops._make_flash_attention.cache_clear()


def test_e2e_grad_through_attention_block(monkeypatch):
    """Jitted jax.grad through a flash-routed `layers.attention` block
    equals the einsum-path gradient (same params, same loss)."""
    from repro.core import factory

    d_model, n_heads, n_kv, hd = 32, 4, 2, 8
    lc = factory.DENSE
    p = attn_lib.init_attention(KEY, d_model, n_heads, n_kv, hd, lc)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (2, 16, d_model))

    def make_loss(flash):
        def loss(p, x):
            o, _ = attn_lib.attention(
                p, x, n_heads=n_heads, n_kv=n_kv, head_dim=hd, lin_cfg=lc,
                causal=True, flash=flash)
            return (o ** 2).sum()
        return loss

    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    g_flash = jax.jit(jax.grad(make_loss(True)))(p, x)
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "xla")
    g_ref = jax.jit(jax.grad(make_loss(False)))(p, x)
    for a, b in zip(jax.tree.leaves(g_flash), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# -- dispatch -----------------------------------------------------------------


def _spied(monkeypatch):
    calls = {"prefill": 0, "decode": 0}
    real_p, real_d = kops.flash_attention, kops.flash_decode

    def spy_p(*a, **kw):
        calls["prefill"] += 1
        return real_p(*a, **kw)

    def spy_d(*a, **kw):
        calls["decode"] += 1
        return real_d(*a, **kw)

    monkeypatch.setattr(kops, "flash_attention", spy_p)
    monkeypatch.setattr(kops, "flash_decode", spy_d)
    return calls


def _attn(p, x, lc, *, flash=True, **kw):
    return attn_lib.attention(p, x, n_heads=4, n_kv=2, head_dim=8,
                              lin_cfg=lc, causal=True, flash=flash, **kw)


def test_dispatch_routes_and_fallbacks(monkeypatch):
    from jax.sharding import Mesh
    from repro.core import factory
    from repro.sharding import ctx as shard_ctx

    lc = factory.DENSE
    p = attn_lib.init_attention(KEY, 32, 4, 2, 8, lc)
    x = jax.random.normal(KEY, (2, 8, 32))
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    calls = _spied(monkeypatch)

    # positive control: plain forward routes to the prefill kernel
    _attn(p, x, lc)
    assert calls["prefill"] == 1

    # cache prefill routes to the prefill kernel; decode to the decode one
    cache = attn_lib.init_kv_cache(2, 16, 2, 8, jnp.float32)
    _, c = _attn(p, x, lc, cache=cache)
    assert calls["prefill"] == 2
    _attn(p, x[:, :1], lc, cache=c)
    assert calls["decode"] == 1

    # cross-attention falls back (separate K/V positions, no kernel path)
    _attn(p, x, lc, kv_input=jax.random.normal(KEY, (2, 12, 32)))
    # PR 8: an active sharding context KEEPS the kernel route (the TP
    # wrappers in kernels/tp.py run the same grids per shard) — only the
    # REPRO_KERNEL_TP=off hatch demotes it to the einsum path
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
        _attn(p, x, lc)
        assert calls["prefill"] == 3
        monkeypatch.setenv("REPRO_KERNEL_TP", "off")
        _attn(p, x, lc)
        assert calls["prefill"] == 3
        monkeypatch.delenv("REPRO_KERNEL_TP")
    # non-contiguous/per-batch positions on the no-cache path fall back
    _attn(p, x, lc, positions=jnp.tile(jnp.arange(8), (2, 1)))
    # flash=False (the config gate) and REPRO_KERNEL_ATTN=xla fall back
    _attn(p, x, lc, flash=False)
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "xla")
    _attn(p, x, lc)
    assert calls == {"prefill": 3, "decode": 1}


def test_attn_route_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    assert kops.attn_route() == "flash"
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "xla")
    assert kops.attn_route() == "xla"
    monkeypatch.delenv("REPRO_KERNEL_ATTN")
    assert kops.attn_route() == ("flash" if jax.default_backend() == "tpu"
                                 else "xla")


# -- autotune integration -----------------------------------------------------


def test_flash_tiles_resolved_at_trace_time(cache, monkeypatch):
    """Acceptance spy: tuned flash_prefill/flash_decode tiles are consulted
    AT TRACE TIME of jitted kernel-routed calls."""
    from repro.perf import autotune as at

    S, K, G, h, L = 16, 2, 2, 8, 32
    tuned_p = {"block_b": 8, "block_o": 128, "block_k": 128}
    tuned_d = {"block_b": 1, "block_o": 128, "block_k": 256}
    cache.put(tune_key("flash_prefill", S, K, h, S, d_mid=G), tuned_p,
              us=1.0)
    cache.put(tune_key("flash_decode", 2, K, h, L, d_mid=G), tuned_d,
              us=1.0)

    seen = {}
    real = at.get_tuned_blocks

    def spy(op, *a, **kw):
        out = real(op, *a, **kw)
        seen[op] = dict(out)
        return out

    monkeypatch.setattr(at, "get_tuned_blocks", spy)
    q = jnp.zeros((2, S, K, G, h))
    kv = jnp.zeros((2, S, K, h))
    jax.jit(lambda q, k, v: kops.flash_attention(q, k, v)).lower(q, kv, kv)
    qd = jnp.zeros((2, 1, K, G, h))
    ckv = jnp.zeros((2, L, K, h))
    jax.jit(lambda q, k, v: kops.flash_decode(q, k, v, jnp.int32(3))).lower(
        qd, ckv, ckv)
    assert seen["flash_prefill"] == tuned_p
    assert seen["flash_decode"] == tuned_d


def test_autotune_sweeps_flash_ops(cache):
    blocks, us = autotune.autotune_dyad(
        "flash_prefill", 32, 2, 16, 32, d_mid=2, iters=1,
        candidates=[{"block_b": 16, "block_o": 128, "block_k": 128},
                    {"block_b": 32, "block_o": 128, "block_k": 128}])
    assert blocks["block_b"] in (16, 32) and us > 0
    blocks, _ = autotune.autotune_dyad(
        "flash_decode", 2, 2, 16, 32, d_mid=2, iters=1,
        candidates=[{"block_b": 1, "block_o": 128, "block_k": 128}])
    assert blocks["block_k"] == 128
    with pytest.raises(ValueError):
        autotune.autotune_dyad("flash_prefill", 32, 2, 16, 32, iters=1)


def test_ensure_tuned_covers_flash(cache, monkeypatch):
    from repro import configs
    from repro.perf.autotune import ensure_tuned_for_model

    cfg = configs.get("qwen3_0_6b", smoke=True)
    assert cfg.flash_attn
    # the sweep only runs when dispatch will consult the tiles: inactive
    # route (CPU default) skips it entirely
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "xla")
    assert ensure_tuned_for_model(cfg, tokens=2, iters=1, seq_len=16,
                                  kv_len=32) == {}
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    tuned = ensure_tuned_for_model(cfg, tokens=2, iters=1, seq_len=16,
                                   kv_len=32)
    assert any(k.startswith("flash_prefill") for k in tuned)
    assert any(k.startswith("flash_decode") for k in tuned)
    # window-bounded ring caches clamp the decode kv length
    wcfg = cfg.replace(window=8)
    tuned_w = ensure_tuned_for_model(wcfg, tokens=2, iters=1, kv_len=32)
    assert any("|o8|" in k for k in tuned_w if k.startswith("flash_decode"))
    # non-flash configs stay untouched
    plain = cfg.replace(flash_attn=False)
    assert ensure_tuned_for_model(plain, tokens=2, iters=1, seq_len=16,
                                  kv_len=32) == {}


def test_candidate_blocks_attn_vmem_filter():
    cands = autotune.candidate_blocks_attn(4096, 4096, 128, 8, "float32")
    assert cands and all(
        autotune.vmem_estimate_attn(c["block_b"], c["block_k"], 128, 8,
                                    "float32") <= autotune.VMEM_BUDGET_BYTES
        for c in cands)
    dec = autotune.candidate_blocks_attn(8, 4096, 128, 8, "float32",
                                         decode=True)
    assert dec and all(c["block_b"] == 1 for c in dec)


# -- model-level equivalence --------------------------------------------------


def test_model_flash_vs_xla_routes(monkeypatch):
    """Forward, fresh prefill, and ring decode through the real model:
    the flash route (forced on CPU) must reproduce the einsum route."""
    from repro import configs
    from repro.models import model

    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)

    def run():
        out = {}
        full, _ = model.forward(cfg, p, {"tokens": toks})
        out["fwd"] = full
        c = model.init_cache(cfg, 2, 10, dtype=jnp.float32)
        lo, c = model.prefill(cfg, p, c, toks)
        out["prefill"] = lo
        tok = jnp.argmax(lo[:, -1:], axis=-1)
        out["decode"], _ = model.decode_step(cfg, p, c, tok)
        return out

    monkeypatch.setenv("REPRO_KERNEL_ATTN", "xla")
    want = run()
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    got = run()
    for name in want:
        np.testing.assert_allclose(np.asarray(got[name]),
                                   np.asarray(want[name]), atol=3e-3,
                                   err_msg=name)


def test_warm_cache_continuation_prefill(monkeypatch):
    """Chunked prompt ingestion: a SECOND prefill on a warm cache
    (idx > 0) must still see the first chunk's keys on the flash route —
    the S < L flash path attends the post-write cache, not just the
    in-flight K/V."""
    from repro import configs
    from repro.models import model

    cfg = configs.get("qwen3_0_6b", smoke=True)
    p = model.init_params(cfg, KEY)
    t1 = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    t2 = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 3), 0,
                            cfg.vocab_size)

    def run():
        c = model.init_cache(cfg, 2, 12, dtype=jnp.float32)
        _, c = model.prefill(cfg, p, c, t1)
        lo, c = model.prefill(cfg, p, c, t2)
        return lo

    monkeypatch.setenv("REPRO_KERNEL_ATTN", "xla")
    want = run()
    monkeypatch.setenv("REPRO_KERNEL_ATTN", "flash")
    got = run()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-3)
