"""Resilience layer: deterministic fault injection (repro.faults), request
lifecycle hardening (deadlines, cancel, typed retire reasons), victim
preemption under page pressure, the NaN guard + route demotion ladder,
checkpoint retry/backoff, the trainer's skip-step + rollback, and the
SIGTERM -> resume contract of the training launcher.

The central invariants, driven under randomized fault schedules:

* the engine always drains — no fault schedule can wedge it;
* pages balance — after a drain every page is back in the pool with
  refcount 0, whatever was injected;
* survivors are exact — a request that finishes (not cancelled / deadline /
  faulted) produces tokens IDENTICAL to a fault-free run, even across
  preemption and NaN retries (greedy decoding);
* with no faults configured, nothing changes: zero demotions, zero
  preemptions, zero extra work on the hot path.
"""
import functools
import os
import random
import signal
import subprocess
import sys
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs, faults
from repro.errors import (AdmissionError, CheckpointIOError, DeadlineExceeded,
                          NumericalFault, PageAccountingError, PageExhausted,
                          ReproError)
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.models import model as model_lib
from repro.optim import AdamW, schedule
from repro.serve import ContinuousBatchingEngine, PageAllocator, RetireReason
from repro.train import Trainer, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _clean_faults():
    """Every test starts and ends with no fault schedule installed."""
    faults.reset()
    yield
    faults.reset()


@functools.lru_cache(maxsize=None)
def _small_model():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    return cfg, model_lib.init_params(cfg, KEY)


def _prompts(n, cfg, base_len=6):
    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size,
                         max(1, base_len - i % 3)).astype(np.int32)
            for i in range(n)]


# -- fault registry -----------------------------------------------------------


def test_fault_parse_syntax():
    specs = faults.parse(
        "page_exhaustion:p=0.05;nan_logits:at_step=3;slow_step:ms=50;"
        "kernel_nan:route=ff_fused")
    assert specs["page_exhaustion"].p == 0.05
    assert specs["nan_logits"].at_step == 3
    assert specs["nan_logits"].times == 1      # at_step fires once by default
    assert specs["slow_step"].ms == 50.0
    assert specs["kernel_nan"].route == "ff_fused"
    with pytest.raises(ValueError):
        faults.parse("x:p=0.5,at_step=2")       # exclusive knobs
    with pytest.raises(ValueError):
        faults.parse("x:p=1.5")                 # p out of range
    with pytest.raises(ValueError):
        faults.parse("x:bogus=1")               # unknown knob
    with pytest.raises(ValueError):
        faults.parse("x:p=0.1;x:p=0.2")         # duplicate site


def test_fault_streams_are_order_independent():
    """A site's firing sequence depends only on (seed, site, check index) —
    interleaving checks of OTHER sites must not perturb it."""
    reg1 = faults.FaultRegistry(faults.parse("a:p=0.4;b:p=0.4"), seed=7)
    seq_interleaved = []
    for _ in range(64):
        seq_interleaved.append(reg1.check("a") is not None)
        reg1.check("b")
    reg2 = faults.FaultRegistry(faults.parse("a:p=0.4;b:p=0.4"), seed=7)
    seq_alone = [reg2.check("a") is not None for _ in range(64)]
    assert seq_interleaved == seq_alone
    assert any(seq_alone) and not all(seq_alone)


def test_fault_at_step_and_times():
    reg = faults.FaultRegistry(faults.parse("s:at_step=2"), seed=0)
    fired = [reg.check("s") is not None for _ in range(6)]
    assert fired == [False, False, True, False, False, False]
    reg = faults.FaultRegistry(faults.parse("s:times=2"), seed=0)
    fired = [reg.check("s") is not None for _ in range(5)]
    assert fired == [True, True, False, False, False]


def test_fault_route_mismatch_consumes_nothing():
    reg = faults.FaultRegistry(faults.parse("k:route=ff_fused,at_step=0"),
                               seed=0)
    assert reg.check("k", route="ff_split") is None
    assert reg.checks["k"] == 0                 # mismatch: no draw consumed
    assert reg.check("k", route="ff_fused") is not None


def test_fault_env_and_configure(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "slow_step:ms=5")
    faults.reset()
    assert faults.active()
    assert faults.fire("slow_step").ms == 5.0
    faults.configure(None)                      # explicit config wins
    assert not faults.active() and faults.fire("slow_step") is None
    faults.configure("slow_step:ms=9", seed=1)
    assert faults.snapshot() == {"slow_step": {"checks": 0, "fired": 0}}


def test_poison_is_trace_time_and_route_gated():
    x = jax.numpy.ones((4,))
    assert np.isfinite(np.asarray(faults.poison(x, "kernel_nan"))).all()
    faults.configure("kernel_nan:route=ff_fused")
    ok = jax.jit(lambda v: faults.poison(v, "kernel_nan",
                                         route="ff_split"))(x)
    bad = jax.jit(lambda v: faults.poison(v, "kernel_nan",
                                          route="ff_fused"))(x)
    assert np.isfinite(np.asarray(ok)).all()
    assert np.isnan(np.asarray(bad)).all()


# -- typed errors + allocator guards ------------------------------------------


def test_error_hierarchy_preserves_builtin_contracts():
    assert issubclass(AdmissionError, ValueError)
    assert issubclass(DeadlineExceeded, TimeoutError)
    assert issubclass(NumericalFault, ArithmeticError)
    assert issubclass(CheckpointIOError, RuntimeError)
    assert issubclass(PageExhausted, RuntimeError)
    assert issubclass(PageAccountingError, ValueError)
    for e in (AdmissionError, DeadlineExceeded, NumericalFault,
              CheckpointIOError, PageExhausted, PageAccountingError):
        assert issubclass(e, ReproError)


def test_page_allocator_double_release_raises():
    pool = PageAllocator(4)
    page = pool.alloc()
    assert pool.release(page)
    with pytest.raises(PageAccountingError):
        pool.release(page)                      # double release
    with pytest.raises(PageAccountingError):
        pool.retain(page)                       # retain of a free page
    with pytest.raises(PageAccountingError):
        pool.release(0)                         # scratch page is untouchable


def test_page_allocator_corrupt_free_list_detected():
    pool = PageAllocator(3)
    page = pool.alloc()
    pool._free.append(page)                     # simulate corrupted handback
    with pytest.raises(PageAccountingError):
        while True:
            pool.alloc()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_page_allocator_guards_under_random_schedules(seed):
    """Randomized schedules with deliberate invalid ops sprinkled in: the
    guards must raise (never corrupt), and valid accounting must stay
    exact — all references drained returns every page to the pool."""
    rng = random.Random(seed)
    pool = PageAllocator(rng.randrange(2, 12))
    held = []
    for _ in range(rng.randrange(1, 80)):
        op = rng.random()
        if op < 0.35 and pool.free_pages:
            held.append(pool.alloc())
        elif op < 0.55 and held:
            page = rng.choice(held)
            pool.retain(page)
            held.append(page)                   # track the extra reference
        elif op < 0.8 and held:
            pool.release(held.pop(rng.randrange(len(held))))
        else:
            # invalid op: releasing a page with zero outstanding refs from
            # THIS schedule must raise and must not change the pool
            free_before = pool.free_pages
            victim = rng.randrange(pool.n_pages)
            if held.count(victim) == 0:
                with pytest.raises(PageAccountingError):
                    pool.release(victim)
                assert pool.free_pages == free_before
    for page in held:
        pool.release(page)
    assert pool.free_pages == pool.n_pages - 1
    assert (pool.refcount == 0).all()


# -- engine lifecycle: typed admission, deadlines, cancel ---------------------


def test_submit_raises_typed_admission_errors():
    cfg, p = _small_model()
    eng = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=16,
                                   page_size=4, n_pages=4)   # 3 usable pages
    with pytest.raises(AdmissionError):
        eng.submit(np.zeros(30, np.int32), 4)          # exceeds max_len
    with pytest.raises(AdmissionError):
        eng.submit(np.zeros(4, np.int32), 0)           # max_new < 1
    with pytest.raises(AdmissionError):
        eng.submit(np.zeros(4, np.int32), 4, deadline_s=-1.0)
    with pytest.raises(AdmissionError):
        eng.submit(np.zeros(10, np.int32), 6)   # needs 4 of 3 usable pages
    # the typed errors still satisfy the seed-era except ValueError contract
    with pytest.raises(ValueError):
        eng.submit(np.zeros(30, np.int32), 4)
    assert eng.metrics_summary()["counters"]["admission_rejects"] == 5


def test_deadline_retires_with_partial_output():
    cfg, p = _small_model()
    eng = ContinuousBatchingEngine(cfg, p, n_slots=1, max_len=32)
    # slot-occupying request without a deadline; one queued WITH a deadline
    # that expires while it waits for the slot
    u0 = eng.submit(np.arange(4, dtype=np.int32), 8)
    u1 = eng.submit(np.arange(5, dtype=np.int32), 8, deadline_s=1e-4)
    time.sleep(0.01)
    res = eng.run()
    assert len(res[u0]) == 8
    assert res[u1] == []                        # expired while queued
    c = eng.metrics_summary()["counters"]
    assert c["retired_deadline"] == 1
    assert c["retired_max_new"] == 1
    assert c["requests_finished"] == 2


def test_deadline_mid_decode_keeps_tokens():
    cfg, p = _small_model()
    eng = ContinuousBatchingEngine(cfg, p, n_slots=1, max_len=64)
    uid = eng.submit(np.arange(4, dtype=np.int32), 40, deadline_s=1e-4)
    eng.step()                                  # admitted; first token out
    time.sleep(0.01)
    res = eng.run()
    assert 1 <= len(res[uid]) < 40
    assert eng.metrics_summary()["counters"]["retired_deadline"] == 1


def test_cancel_queued_and_active():
    cfg, p = _small_model()
    eng = ContinuousBatchingEngine(cfg, p, n_slots=1, max_len=32)
    u0 = eng.submit(np.arange(4, dtype=np.int32), 8)
    u1 = eng.submit(np.arange(6, dtype=np.int32), 8)
    assert eng.cancel(u1)                       # queued: never ran
    assert eng.finished[-1].retire_reason is RetireReason.CANCELLED
    assert eng.cancel(u0)                       # active: slot released
    assert not eng.cancel(u0)                   # already finished
    assert not eng.cancel(999)                  # unknown uid
    res = eng.run()
    assert res[u1] == [] and len(res[u0]) >= 1  # u0 keeps its prefill token
    c = eng.metrics_summary()["counters"]
    assert c["retired_cancelled"] == 2
    assert eng.slots.free_slots == 1 and not eng.queue


def test_run_drain_deadline_raises():
    cfg, p = _small_model()
    eng = ContinuousBatchingEngine(cfg, p, n_slots=1, max_len=32)
    uid = eng.submit(np.arange(4, dtype=np.int32), 6)
    with pytest.raises(DeadlineExceeded):
        eng.run(deadline_s=0.0)
    res = eng.run()                             # engine intact: drains fine
    assert len(res[uid]) == 6


# -- preemption ---------------------------------------------------------------


def _paged_engine(cfg, p, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 24)
    kw.setdefault("page_size", 4)
    return ContinuousBatchingEngine(cfg, p, **kw)


def test_preemption_under_page_pressure_is_token_exact():
    """A fresh request that cannot fit preempts the youngest decoding
    victim; the victim resumes later and its output is IDENTICAL to an
    undisturbed run (greedy: re-prefill + resume_token re-seeding)."""
    cfg, p = _small_model()
    prompts = _prompts(2, cfg, base_len=8)
    # baseline: ample pool, no preemption possible
    base = _paged_engine(cfg, p, n_pages=32)
    b_uids = [base.submit(q, 8) for q in prompts]
    b_res = base.run()
    assert base.metrics_summary()["counters"].get("preemptions", 0) == 0
    assert base.demoted == []

    # each request needs ceil((8 + 8 - 1) / 4) = 4 pages; 6 usable pages
    # hold one request but not two -> submitting the second preempts the
    # first (it already holds its prefill token)
    eng = _paged_engine(cfg, p, n_pages=7)
    u0 = eng.submit(prompts[0], 8)
    assert len(eng.slots.active) == 1
    u1 = eng.submit(prompts[1], 8)
    c = eng.metrics_summary()["counters"]
    assert c["preemptions"] == 1
    res = eng.run()
    for b, u in zip(b_uids, (u0, u1)):
        assert res[u] == b_res[b]
    c = eng.metrics_summary()["counters"]
    assert c["retired_max_new"] + c.get("retired_eos", 0) == 2
    assert eng.pages.free_pages == eng.pages.n_pages - 1
    assert (eng.pages.refcount == 0).all()


def test_resumed_request_cannot_retrigger_preemption():
    """The anti-livelock rule: once preempted, a request head-of-line
    blocks instead of preempting — totals are bounded by submissions."""
    cfg, p = _small_model()
    prompts = _prompts(3, cfg, base_len=8)
    eng = _paged_engine(cfg, p, n_slots=3, n_pages=7)
    uids = [eng.submit(q, 8) for q in prompts]
    res = eng.run()
    c = eng.metrics_summary()["counters"]
    assert c["preemptions"] <= 3                 # bounded by submissions
    assert all(len(res[u]) == 8 for u in uids)
    assert eng.pages.free_pages == eng.pages.n_pages - 1


# -- NaN guard + demotion ladder ----------------------------------------------


def test_nan_logits_transient_recovers_without_demotion():
    """An injected transient NaN on the decode logits costs ONE same-route
    retry: outputs stay identical to a clean run and nothing demotes."""
    cfg, p = _small_model()
    prompts = _prompts(2, cfg)
    base = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=32)
    b_uids = [base.submit(q, 6) for q in prompts]
    b_res = base.run()

    faults.configure("nan_logits:at_step=1", seed=0)
    eng = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=32)
    uids = [eng.submit(q, 6) for q in prompts]
    res = eng.run()
    for b, u in zip(b_uids, uids):
        assert res[u] == b_res[b]
    snap = eng.metrics_summary()
    assert snap["counters"]["nan_steps"] == 1
    assert "demotions" not in snap["counters"]
    assert eng.demoted == []
    assert snap["faults"]["nan_logits"]["fired"] == 1


def test_persistent_nan_walks_ladder_and_retires_faulted():
    """``nan_logits`` armed on EVERY check defeats the retry AND every
    demotion rung (the poison is route-independent): the decoding lanes
    must retire as FAULTED — the engine never wedges or emits garbage."""
    cfg, p = _small_model()
    faults.configure("nan_logits:p=1.0", seed=0)
    eng = ContinuousBatchingEngine(cfg, p, n_slots=2, max_len=32)
    try:
        uids = [eng.submit(q, 6) for q in _prompts(2, cfg)]
        res = eng.run()
        c = eng.metrics_summary()["counters"]
        assert c["retired_faulted"] == 2
        # every request still surfaces (with its prefill token only)
        assert all(len(res[u]) == 1 for u in uids)
        assert len(eng.demoted) == 3             # full ladder walked
        assert c["demotions"] >= 1
    finally:
        eng.reset_demotions()
    assert eng.demoted == []


def test_kernel_nan_demotion_recovers_new_requests(monkeypatch):
    """A 'broken kernel' on the fused-ff route: the first victim's cache
    is poisoned beyond recovery (FAULTED), the ladder demotes ff to the
    split route, and requests admitted AFTER the demotion complete
    cleanly — the serving process survives a bad kernel."""
    for var in ("REPRO_KERNEL_QUANT", "REPRO_KERNEL_FF", "REPRO_KERNEL_ATTN"):
        monkeypatch.delenv(var, raising=False)
    cfg_k = configs.get("qwen3_0_6b", smoke=True,
                        linear=configs.linear_cfg("dyad_it_4_kernel_ffused"))
    p = model_lib.init_params(cfg_k, KEY)
    faults.configure("kernel_nan:route=ff_fused", seed=0)
    eng = ContinuousBatchingEngine(cfg_k, p, n_slots=1, max_len=32)
    try:
        u0 = eng.submit(np.arange(5, dtype=np.int32), 4)
        res0 = eng.run()
        c = eng.metrics_summary()["counters"]
        assert c["retired_faulted"] == 1
        assert "ff" in eng.demoted
        assert os.environ.get("REPRO_KERNEL_FF") == "split"
        # post-demotion admission re-traces on the split route: clean
        u1 = eng.submit(np.arange(5, dtype=np.int32), 4)
        res1 = eng.run()
        assert len(res1[u1]) == 4
        c = eng.metrics_summary()["counters"]
        assert c["retired_faulted"] == 1         # no new faults
        assert c["retired_max_new"] == 1
        _ = res0, u0
    finally:
        eng.reset_demotions()
    assert os.environ.get("REPRO_KERNEL_FF") in (None, "")


# -- randomized chaos schedules ----------------------------------------------


@functools.lru_cache(maxsize=None)
def _chaos_baseline():
    cfg, p = _small_model()
    prompts = tuple(tuple(int(t) for t in q) for q in _prompts(6, cfg))
    eng = _paged_engine(cfg, p, n_slots=3, n_pages=25, prefill_chunk=4,
                        prefix_cache=True)
    uids = [eng.submit(np.asarray(q, np.int32), 5) for q in prompts]
    res = eng.run()
    snap = eng.metrics_summary()
    assert "faults" not in snap                  # no schedule: no tallies
    assert snap["counters"].get("preemptions", 0) == 0
    assert "demotions" not in snap["counters"]
    return prompts, tuple(tuple(res[u]) for u in uids)


@settings(max_examples=4, deadline=None)
@given(case=st.sampled_from([(0, 0), (7, 2), (123, 5), (9001, 9)]))
def test_chaos_schedule_drains_and_survivors_match(case):
    """page_exhaustion + a one-shot nan_logits under randomized seeds: the
    engine drains, pages balance, and EVERY request's tokens equal the
    fault-free baseline (faults here are all recoverable)."""
    seed, at = case
    cfg, p = _small_model()
    prompts, expect = _chaos_baseline()
    faults.configure(f"page_exhaustion:p=0.2;nan_logits:at_step={at}",
                     seed=seed)
    eng = _paged_engine(cfg, p, n_slots=3, n_pages=25, prefill_chunk=4,
                        prefix_cache=True)
    uids = [eng.submit(np.asarray(q, np.int32), 5) for q in prompts]
    res = eng.run()
    for u, want in zip(uids, expect):
        assert tuple(res[u]) == want
    assert eng.pages.free_pages == eng.pages.n_pages - 1
    assert (eng.pages.refcount == 0).all()
    assert eng._prefix == {} and eng._page_hash == {}
    assert eng.demoted == []
    assert not eng.queue and not eng.slots.active


# -- checkpoint I/O faults ----------------------------------------------------


def _tiny_state():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "t": np.int64(3)}


def test_ckpt_retry_absorbs_transient_io_fault(tmp_path):
    faults.configure("ckpt_io:at_step=0", seed=0)   # first attempt fails
    mgr = CheckpointManager(str(tmp_path), async_save=False,
                            retries=2, backoff_s=0.001)
    mgr.save(7, _tiny_state())
    assert mgr.latest_step() == 7
    step, tree = mgr.restore(_tiny_state())
    np.testing.assert_array_equal(tree["w"], _tiny_state()["w"])
    assert faults.snapshot()["ckpt_io"] == {"checks": 2, "fired": 1}


def test_ckpt_retry_budget_exhausted_raises(tmp_path):
    faults.configure("ckpt_io")                     # every attempt fails
    mgr = CheckpointManager(str(tmp_path), async_save=False,
                            retries=2, backoff_s=0.001)
    with pytest.raises(CheckpointIOError):
        mgr.save(1, _tiny_state())
    assert mgr.latest_step() is None                # nothing half-written


def test_ckpt_async_failure_surfaces_at_wait(tmp_path):
    faults.configure("ckpt_io")
    mgr = CheckpointManager(str(tmp_path), async_save=True,
                            retries=0, backoff_s=0.001)
    mgr.save(1, _tiny_state())                      # async: returns at once
    with pytest.raises(CheckpointIOError):
        mgr.wait()
    faults.configure(None)
    mgr.save(2, _tiny_state())                      # manager still usable
    mgr.wait()
    assert mgr.latest_step() == 2


# -- trainer: skip-step + rollback -------------------------------------------


@functools.lru_cache(maxsize=None)
def _train_fixture():
    cfg = configs.get("qwen3_0_6b", smoke=True)
    opt = AdamW(lr=schedule.constant(1e-3))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=8, global_batch=2,
                       seed=0)
    return cfg, opt, data


def _fresh_state():
    cfg, opt, _ = _train_fixture()
    return init_train_state(cfg, opt, jax.random.PRNGKey(0))


def test_train_step_skips_nonfinite_in_jit():
    """The donation-safe skip-step: a poisoned batch leaves the state
    bitwise unchanged and reports metrics['nonfinite']=1."""
    cfg, opt, data = _train_fixture()
    step = jax.jit(make_train_step(cfg, opt))
    state = _fresh_state()
    batch = dict(data.batch(0))
    batch["_fault_poison"] = np.float32(1.0)
    before = jax.tree.map(np.asarray, state)
    new_state, metrics = step(state, batch)
    assert float(metrics["nonfinite"]) == 1.0
    assert not np.isfinite(float(metrics["loss"]))
    for a, b in zip(jax.tree.leaves(before),
                    jax.tree.leaves(jax.tree.map(np.asarray, new_state))):
        np.testing.assert_array_equal(a, b)
    batch["_fault_poison"] = np.float32(0.0)
    new_state, metrics = step(new_state, batch)
    assert float(metrics["nonfinite"]) == 0.0
    assert np.isfinite(float(metrics["loss"]))


def test_trainer_rollback_matches_clean_run(tmp_path):
    """nan_loss striking twice mid-run: skip-step + rollback must land the
    trainer on EXACTLY the state a fault-free run reaches (the skipped
    batches re-run cleanly after the rollback)."""
    cfg, opt, data = _train_fixture()
    step = jax.jit(make_train_step(cfg, opt))

    ref = Trainer(step, _fresh_state(), data, log_fn=lambda *a: None)
    ref_state, _ = ref.run(8)

    t = Trainer(step, _fresh_state(), data, ckpt_dir=str(tmp_path),
                ckpt_every=4, nan_strikes=2, log_fn=lambda *a: None)
    t.run(4)                                    # clean prefix + checkpoint
    faults.configure("nan_loss:p=1.0,times=2", seed=0)
    state, _ = t.run(8)                         # 2 strikes -> rollback -> ok
    c = t.metrics.snapshot()["counters"]
    assert c["nonfinite_steps"] == 2
    assert c["rollbacks"] == 1
    assert t.step == 8
    for a, b in zip(jax.tree.leaves(jax.tree.map(np.asarray, ref_state)),
                    jax.tree.leaves(jax.tree.map(np.asarray, state))):
        np.testing.assert_array_equal(a, b)     # includes AdamW m/v


def test_trainer_nan_without_checkpoint_raises():
    cfg, opt, data = _train_fixture()
    step = jax.jit(make_train_step(cfg, opt))
    faults.configure("nan_loss:p=1.0", seed=0)
    t = Trainer(step, _fresh_state(), data, nan_strikes=2,
                log_fn=lambda *a: None)
    with pytest.raises(NumericalFault):
        t.run(8)
    assert t.metrics.snapshot()["counters"]["nonfinite_steps"] == 2


# -- SIGTERM -> resume (subprocess, whole launcher) ---------------------------


def _train_cmd(ckpt_dir, steps, extra=()):
    return [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen3_0_6b", "--smoke", "--steps", str(steps), "--batch", "2",
            "--seq-len", "8", "--ckpt-every", "4", "--ckpt-dir",
            str(ckpt_dir), *extra]


def _train_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.pop("REPRO_FAULT", None)
    return env


def test_sigterm_checkpoint_resume_bitwise(tmp_path):
    """Full launcher contract: SIGTERM mid-run -> final checkpoint + exit
    0; relaunch resumes and the final optimizer state is BITWISE identical
    to an uninterrupted run.  slow_step stretches the first run so the
    signal reliably lands mid-training (sleep only — no numerics)."""
    steps = 24
    d_int, d_ref = tmp_path / "interrupted", tmp_path / "reference"
    env = _train_env()
    proc = subprocess.Popen(
        _train_cmd(d_int, steps, ("--faults", "slow_step:ms=150")),
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    deadline = time.time() + 300
    try:
        while time.time() < deadline and proc.poll() is None:
            if any(d_int.glob("ckpt_*/manifest.json")):
                break
            time.sleep(0.1)
        assert proc.poll() is None, (
            "run finished before SIGTERM:\n" + proc.communicate()[0])
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert proc.returncode == 0, out
    assert "preempted" in out
    mgr = CheckpointManager(str(d_int))
    stopped_at = mgr.latest_step()
    assert stopped_at is not None and stopped_at < steps

    done = subprocess.run(_train_cmd(d_int, steps), env=env, timeout=300,
                          capture_output=True, text=True)
    assert done.returncode == 0, done.stdout + done.stderr
    assert f"resumed from step {stopped_at}" in done.stdout

    ref = subprocess.run(_train_cmd(d_ref, steps), env=env, timeout=300,
                         capture_output=True, text=True)
    assert ref.returncode == 0, ref.stdout + ref.stderr

    a = np.load(d_int / f"ckpt_{steps}" / "arrays.npz")
    b = np.load(d_ref / f"ckpt_{steps}" / "arrays.npz")
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        assert a[k].dtype == b[k].dtype
        assert a[k].tobytes() == b[k].tobytes(), f"mismatch at {k}"
