"""Gradient compression: codecs, error feedback, convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW, Compressor, schedule


def test_int8_roundtrip_accuracy():
    c = Compressor(codec="int8")
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    st = c.init(g)
    dec, st = c.compress_decompress(g, st)
    err = np.abs(np.asarray(dec["w"]) - np.asarray(g["w"])).max()
    scale = float(jnp.abs(g["w"]).max()) / 127
    assert err <= scale * 0.51 + 1e-6


def test_error_feedback_conserves_gradient_mass():
    """The error-feedback invariant: sum of decoded gradients + residual
    error == sum of true gradients, EXACTLY (nothing is ever lost)."""
    c = Compressor(codec="topk", topk_frac=0.25)
    g = {"w": jnp.asarray([1.0, 0.1, 0.01, 0.001])}
    st = c.init(g)
    T = 40
    total = np.zeros(4)
    for _ in range(T):
        dec, st = c.compress_decompress(g, st)
        total += np.asarray(dec["w"])
    np.testing.assert_allclose(total + np.asarray(st["err"]["w"]),
                               T * np.asarray(g["w"]), rtol=1e-5, atol=1e-5)
    # the dominant element flushes nearly every round (it loses the top-1
    # slot only on rounds where another element's accumulated error wins)
    assert 0.9 <= total[0] / T <= 1.0 + 1e-6


def test_compressed_training_converges():
    """Quadratic bowl: int8-compressed Adam still converges."""
    opt = AdamW(lr=schedule.constant(0.05), weight_decay=0.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    p = {"w": jnp.zeros(3)}
    st = opt.init(p)
    for codec in ("int8", "topk"):
        c = Compressor(codec=codec, topk_frac=0.5)
        cs = c.init(p)
        p_run, st_run = p, st
        for _ in range(200):
            g = jax.grad(lambda q: ((q["w"] - target) ** 2).sum())(p_run)
            g, cs = c.compress_decompress(g, cs)
            p_run, st_run, _ = opt.update(g, st_run, p_run)
        np.testing.assert_allclose(np.asarray(p_run["w"]), np.asarray(target),
                                   atol=0.05)


def test_none_codec_passthrough():
    c = Compressor(codec="none")
    g = {"w": jnp.ones(4)}
    dec, st = c.compress_decompress(g, c.init(g))
    np.testing.assert_array_equal(np.asarray(dec["w"]), np.ones(4))
