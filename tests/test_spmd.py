"""SPMD integration on a small fake-device mesh (subprocess: device count is
locked at first jax init, so multi-device tests must re-exec)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 570):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """The SAME train step under a (2,4) mesh must produce the same loss and
    params as unsharded execution — the SPMD-correctness contract."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.optim import AdamW, schedule
from repro.train import init_train_state, make_train_step
from repro.sharding import MeshRules, state_shardings, batch_shardings
from repro.data import SyntheticLM

cfg = configs.get("qwen3_0_6b", smoke=True).replace(
    vocab_size=256, compute_dtype="float32")
opt = AdamW(lr=schedule.constant(1e-3))
data = SyntheticLM(vocab_size=256, seq_len=16, global_batch=8)
batch = data.batch(0)
state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
step = make_train_step(cfg, opt)

ref_state, ref_m = jax.jit(step)(state, batch)

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
rules = MeshRules(model="model", dp=("data",), fsdp=("data",))
st_sh = state_shardings(mesh, jax.eval_shape(lambda: state), rules)
b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch), rules)
sharded = jax.jit(step, in_shardings=(st_sh, b_sh),
                  out_shardings=(st_sh, NamedSharding(mesh, P())))
sp_state, sp_m = sharded(state, batch)
assert abs(float(ref_m["loss"]) - float(sp_m["loss"])) < 1e-3, (
    float(ref_m["loss"]), float(sp_m["loss"]))
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(sp_state["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("SPMD == single-device OK, loss", float(sp_m["loss"]))
""")
    assert "OK" in out


def test_compressed_psum_shard_map():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum
from repro.launch.mesh import compat_make_mesh, compat_shard_map

mesh = compat_make_mesh((8,), ("dp",))
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) / 7.0

def f(xs):
    return compressed_psum(xs[0], "dp")

# check_vma=False: the all-gather+sum result is replicated by construction
# but the varying-axes checker cannot infer that through the int8 round-trip
y = jax.jit(compat_shard_map(f, mesh=mesh, in_specs=P("dp"), out_specs=P(),
                             check_vma=False))(x)
expect = np.asarray(x).sum(0)
np.testing.assert_allclose(np.asarray(y), expect, rtol=0.02, atol=0.02)
print("compressed_psum OK")
""")
    assert "OK" in out


def test_dryrun_entrypoint_smoke_cell():
    """End-to-end dryrun CLI on ONE real cell (512 fake devices) — proves the
    production path works exactly as documented."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3_0_6b",
         "--shape", "decode_32k", "--mesh", "multi", "--outdir",
         "/tmp/dryrun_pytest"],
        capture_output=True, text=True, timeout=570, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "all requested dry-run cells passed" in r.stdout
    f = "/tmp/dryrun_pytest/multi/qwen3_0_6b__decode_32k__dyad_it_4.json"
    res = json.load(open(f))
    assert res["mesh"] == {"pod": 2, "data": 16, "model": 16}
    assert res["flops_per_device"] > 0
    assert res["bottleneck"] in ("compute", "memory", "collective")
