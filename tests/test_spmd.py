"""SPMD integration on a small fake-device mesh (subprocess: device count is
locked at first jax init, so multi-device tests must re-exec)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 570):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """The SAME train step under a (2,4) mesh must produce the same loss and
    params as unsharded execution — the SPMD-correctness contract."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import configs
from repro.optim import AdamW, schedule
from repro.train import init_train_state, make_train_step
from repro.sharding import MeshRules, state_shardings, batch_shardings
from repro.data import SyntheticLM

cfg = configs.get("qwen3_0_6b", smoke=True).replace(
    vocab_size=256, compute_dtype="float32")
opt = AdamW(lr=schedule.constant(1e-3))
data = SyntheticLM(vocab_size=256, seq_len=16, global_batch=8)
batch = data.batch(0)
state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
step = make_train_step(cfg, opt)

ref_state, ref_m = jax.jit(step)(state, batch)

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
rules = MeshRules(model="model", dp=("data",), fsdp=("data",))
st_sh = state_shardings(mesh, jax.eval_shape(lambda: state), rules)
b_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch), rules)
sharded = jax.jit(step, in_shardings=(st_sh, b_sh),
                  out_shardings=(st_sh, NamedSharding(mesh, P())))
sp_state, sp_m = sharded(state, batch)
assert abs(float(ref_m["loss"]) - float(sp_m["loss"])) < 1e-3, (
    float(ref_m["loss"]), float(sp_m["loss"]))
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(sp_state["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
print("SPMD == single-device OK, loss", float(sp_m["loss"]))
""")
    assert "OK" in out


def test_compressed_psum_shard_map():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum
from repro.launch.mesh import compat_make_mesh, compat_shard_map

mesh = compat_make_mesh((8,), ("dp",))
x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) / 7.0

def f(xs):
    return compressed_psum(xs[0], "dp")

# check_vma=False: the all-gather+sum result is replicated by construction
# but the varying-axes checker cannot infer that through the int8 round-trip
y = jax.jit(compat_shard_map(f, mesh=mesh, in_specs=P("dp"), out_specs=P(),
                             check_vma=False))(x)
expect = np.asarray(x).sum(0)
np.testing.assert_allclose(np.asarray(y), expect, rtol=0.02, atol=0.02)
print("compressed_psum OK")
""")
    assert "OK" in out


def test_tp_ff_fused_forward_and_grad_match_fallback():
    """The shard_map TP megakernel route (kernels.tp.dyad_ff_tp) must be
    numerically equivalent to both the einsum fallback (REPRO_KERNEL_TP=off)
    and unsharded execution — forward and jax.grad — across tp=2, tp=4 and
    dp-x-tp meshes, with ZERO tp_fallback dispatches on the fused runs."""
    out = _run("""
import os
os.environ["REPRO_KERNEL_FF"] = "fused"
import jax, jax.numpy as jnp, numpy as np
from repro import configs, obs
from repro.launch.mesh import make_test_mesh
from repro.layers import mlp
from repro.sharding import ctx as shard_ctx

lin = configs.linear_cfg("dyad_it_4_kernel_ffused")
d, dff = 128, 512
params = mlp.init_mlp(jax.random.PRNGKey(0), d, dff, lin, act="swiglu")
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, d))

def loss(p, x):
    return jnp.sum(mlp.apply_mlp(p, x, lin, act="swiglu") ** 2)

ref = jax.jit(lambda p, x: mlp.apply_mlp(p, x, lin, act="swiglu"))(params, x)
g_ref = jax.jit(jax.grad(loss))(params, x)

for shape in ((4, 2), (2, 4)):          # dp x tp: tp=2 and tp=4
    mesh = make_test_mesh(shape)
    with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
        obs.reset_route_counts()
        out = jax.jit(lambda p, x: mlp.apply_mlp(p, x, lin,
                                                 act="swiglu"))(params, x)
        g_tp = jax.jit(jax.grad(loss))(params, x)
        counts = obs.route_counts()
        assert counts.get(("ff_tp", "tp_fallback"), 0) == 0, counts
        assert counts.get(("ff_tp", "tp_fused"), 0) > 0, counts
        os.environ["REPRO_KERNEL_TP"] = "off"
        try:
            fb = jax.jit(lambda p, x: mlp.apply_mlp(p, x, lin,
                                                    act="swiglu"))(params, x)
            g_fb = jax.jit(jax.grad(loss))(params, x)
        finally:
            del os.environ["REPRO_KERNEL_TP"]
        counts = obs.route_counts()
        assert counts.get(("ff_tp", "tp_fallback"), 0) > 0, counts
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(fb), atol=2e-5)
    for a, b, c in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_tp),
                       jax.tree.leaves(g_fb)):
        scale = max(float(jnp.max(jnp.abs(a))), 1.0)
        np.testing.assert_allclose(np.asarray(b) / scale,
                                   np.asarray(a) / scale, atol=2e-6)
        np.testing.assert_allclose(np.asarray(b) / scale,
                                   np.asarray(c) / scale, atol=2e-6)
    print("tp", shape, "OK")
print("ff TP fused == fallback == single-device OK")
""")
    assert "ff TP fused == fallback == single-device OK" in out


def test_tp_flash_kernels_match_single_device():
    """The shard_map flash wrappers (KV-head axis per shard, GQA groups
    intact, scalar-prefetch machinery per device) must be exact vs the
    single-device kernels: prefill fwd+grad, ring decode, paged decode."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.sharding import ctx as shard_ctx
from repro.kernels import ops as kops, tp as ktp

key = jax.random.PRNGKey(0)
B, S, K, G, h, T = 4, 16, 4, 2, 32, 16
q = jax.random.normal(key, (B, S, K, G, h))
k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, K, h))
v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, K, h))

mesh = make_test_mesh((2, 4))
ref = jax.jit(lambda q, k, v: kops.flash_attention(q, k, v, 0, 0))(q, k, v)
gref = jax.jit(jax.grad(
    lambda q, k, v: jnp.sum(kops.flash_attention(q, k, v, 0, 0) ** 2),
    argnums=(0, 1, 2)))(q, k, v)
with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
    ctx = shard_ctx.current()
    out = jax.jit(lambda q, k, v: ktp.flash_attention_tp(
        q, k, v, 0, 0, ctx=ctx))(q, k, v)
    gtp = jax.jit(jax.grad(
        lambda q, k, v: jnp.sum(ktp.flash_attention_tp(
            q, k, v, 0, 0, ctx=ctx) ** 2), argnums=(0, 1, 2)))(q, k, v)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
for a, b in zip(gref, gtp):
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-4)

L = 32
kc = jax.random.normal(jax.random.fold_in(key, 3), (B, L, K, h))
vc = jax.random.normal(jax.random.fold_in(key, 4), (B, L, K, h))
idx = jnp.array([5, 9, 13, 17], jnp.int32)
qd = jax.random.normal(jax.random.fold_in(key, 5), (B, 1, K, G, h))
refd = jax.jit(lambda q, k, v, i: kops.flash_decode(q, k, v, i))(
    qd, kc, vc, idx)
with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
    ctx = shard_ctx.current()
    outd = jax.jit(lambda q, k, v, i: ktp.flash_decode_tp(
        q, k, v, i, ctx=ctx))(qd, kc, vc, idx)
np.testing.assert_array_equal(np.asarray(outd), np.asarray(refd))

P_, NP = 8, 17
pk = jax.random.normal(jax.random.fold_in(key, 6), (NP, P_, K, h))
pv = jax.random.normal(jax.random.fold_in(key, 7), (NP, P_, K, h))
bt = jnp.array([[1, 2, 3, 4], [5, 6, 0, 0], [7, 8, 9, 0],
                [10, 11, 12, 13]], jnp.int32)
refp = jax.jit(lambda q, pk, pv, bt, i: kops.flash_decode_paged(
    q, pk, pv, bt, i))(qd, pk, pv, bt, idx)
with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
    ctx = shard_ctx.current()
    outp = jax.jit(lambda q, pk, pv, bt, i: ktp.flash_decode_paged_tp(
        q, pk, pv, bt, i, ctx=ctx))(qd, pk, pv, bt, idx)
np.testing.assert_array_equal(np.asarray(outp), np.asarray(refp))
print("flash TP == single-device OK")
""")
    assert "OK" in out


def test_tp_engine_decode_token_equality():
    """End-to-end: Engine decode under a dp-x-tp mesh with the fused TP
    kernels must emit EXACTLY the tokens of the einsum fallback
    (REPRO_KERNEL_TP=off), with zero tp_fallback dispatches."""
    out = _run("""
import os
os.environ["REPRO_KERNEL_FF"] = "fused"
os.environ["REPRO_KERNEL_ATTN"] = "flash"
import jax, jax.numpy as jnp, numpy as np
from repro import configs, obs
from repro.launch.mesh import make_test_mesh
from repro.serve import Engine
from repro.sharding import ctx as shard_ctx

cfg = configs.get("qwen3_0_6b", smoke=True,
                  linear=configs.linear_cfg("dyad_it_4_kernel_ffused"))
cfg = cfg.replace(vocab_size=256, compute_dtype="float32")
key = jax.random.PRNGKey(0)
from repro.models import model
params = model.init_params(cfg, key)
prompts = jax.random.randint(jax.random.fold_in(key, 1), (4, 8), 0, 256)

mesh = make_test_mesh((2, 2))   # dp=2 x tp=2 (kv heads = 2 divide)
with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
    obs.reset_route_counts()
    eng = Engine(cfg, params, max_len=16)
    toks_tp = np.asarray(eng.generate(prompts, 8))
    counts = obs.route_counts()
assert counts.get(("ff_tp", "tp_fallback"), 0) == 0, counts
assert counts.get(("attn_tp", "tp_fallback"), 0) == 0, counts
assert counts.get(("ff_tp", "tp_fused"), 0) > 0, counts
assert counts.get(("attn_tp", "tp_fused"), 0) > 0, counts

os.environ["REPRO_KERNEL_TP"] = "off"
with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
    obs.reset_route_counts()
    eng_fb = Engine(cfg, params, max_len=16)
    toks_fb = np.asarray(eng_fb.generate(prompts, 8))
    counts = obs.route_counts()
assert counts.get(("ff_tp", "tp_fused"), 0) == 0, counts
np.testing.assert_array_equal(toks_tp, toks_fb)
print("engine decode tokens TP fused == fallback OK", toks_tp[:, :4].tolist())
""")
    assert "OK" in out


def test_tp_paged_pool_shardings():
    """cache_shardings on a paged cache: the page-pool axis (one pool
    shared by every slot) must NOT shard over dp, KV heads shard over
    model when divisible, block tables shard their batch axis over dp."""
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_test_mesh
from repro.sharding import MeshRules
from repro.sharding.rules import cache_shardings

mesh = make_test_mesh((2, 4))
rules = MeshRules(model="model", dp=("data",))
specs = {
    "pages_k": jax.ShapeDtypeStruct((2, 18, 8, 4, 16), jnp.float32),
    "pages_v": jax.ShapeDtypeStruct((2, 18, 8, 4, 16), jnp.float32),
    "block_table": jax.ShapeDtypeStruct((2, 4, 2), jnp.int32),
    "idx": jax.ShapeDtypeStruct((2, 4), jnp.int32),
}
sh = cache_shardings(mesh, specs, rules)
assert sh["pages_k"].spec == P(None, None, None, "model", None), sh["pages_k"].spec
assert sh["pages_v"].spec == P(None, None, None, "model", None), sh["pages_v"].spec
assert sh["block_table"].spec[1] == "data", sh["block_table"].spec
assert sh["idx"].spec == P(), sh["idx"].spec
print("paged pool shardings OK")
""")
    assert "OK" in out


def test_dryrun_entrypoint_smoke_cell():
    """End-to-end dryrun CLI on ONE real cell (512 fake devices) — proves the
    production path works exactly as documented."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3_0_6b",
         "--shape", "decode_32k", "--mesh", "multi", "--outdir",
         "/tmp/dryrun_pytest"],
        capture_output=True, text=True, timeout=570, env=env, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "all requested dry-run cells passed" in r.stdout
    f = "/tmp/dryrun_pytest/multi/qwen3_0_6b__decode_32k__dyad_it_4.json"
    res = json.load(open(f))
    assert res["mesh"] == {"pod": 2, "data": 16, "model": 16}
    assert res["flops_per_device"] > 0
    assert res["bottleneck"] in ("compute", "memory", "collective")
