"""Sharding rule unit tests (pure spec logic; no devices needed)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.sharding import MeshRules, param_spec

RULES = MeshRules(model="model", dp=("data",), fsdp=None)
RULES_FSDP = MeshRules(model="model", dp=("data",), fsdp=("data",))
SIZES = {"data": 16, "model": 16}


def _specs(arch):
    cfg = configs.get(arch)
    return configs.params_specs(cfg)


def _spec_of(tree, path_str, rules=RULES):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for p, leaf in flat:
        from repro.sharding.rules import _path_parts
        if "/".join(_path_parts(p)) == path_str:
            return param_spec(p, leaf, rules, SIZES), leaf
    raise KeyError(path_str)


def test_dyad_up_down_tp_pattern():
    t = _specs("qwen3_0_6b")
    s, _ = _spec_of(t, "layers/mlp/gate/w1")
    assert s == P(None, None, "model", None)     # stacked + d_out sharded
    s, _ = _spec_of(t, "layers/mlp/down/w1")
    assert s == P(None, None, None, "model")     # d_in (contracting) sharded
    s, _ = _spec_of(t, "layers/attn/wo/w")
    assert s == P(None, None, "model")           # dense row-parallel


def test_embedding_vocab_sharded_when_divisible():
    t = _specs("qwen3_0_6b")
    s, leaf = _spec_of(t, "embed/table")
    assert s == P("model", None) and leaf.shape[0] % 16 == 0


def test_odd_vocab_falls_back_to_replication():
    t = _specs("whisper_medium")                  # vocab 51865, not /16
    s, _ = _spec_of(t, "embed/table")
    assert s == P(None, None)


def test_moe_experts_ep_sharded():
    t = _specs("qwen2_moe_a2_7b")
    s, leaf = _spec_of(t, "layers/moe/experts/gate/w1")
    assert s[1] == "model" and leaf.shape[1] == 64   # padded experts / EP
    s, _ = _spec_of(t, "layers/moe/router/w")
    assert s == P(None, None, None)               # router replicated


def test_small_leaves_replicated():
    t = _specs("mamba2_780m")
    for path in ("layers/norm1/scale", "layers/ssm/A_log",
                 "layers/ssm/conv", "layers/ssm/dt_bias"):
        s, _ = _spec_of(t, path)
        assert all(a is None for a in s), path


def test_fsdp_adds_data_axis():
    t = _specs("llama3_405b")
    s, _ = _spec_of(t, "layers/mlp/gate/w1", RULES_FSDP)
    assert s == P(None, None, "model", "data")
    # attn stays dense under the paper's ff-only scope
    s, _ = _spec_of(t, "layers/attn/wq/w", RULES_FSDP)
    assert s == P(None, "model", "data")


def test_every_leaf_gets_a_legal_spec():
    """No rule may produce an indivisible placement for any arch."""
    for arch in configs.ARCHS:
        t = _specs(arch)
        flat = jax.tree_util.tree_flatten_with_path(t)[0]
        for p, leaf in flat:
            spec = param_spec(p, leaf, RULES_FSDP, SIZES)
            from repro.sharding.rules import _axes_size
            for dim, axes in zip(leaf.shape[len(leaf.shape) - len(spec):],
                                 spec):
                n = _axes_size(axes, SIZES)
                assert dim % max(n, 1) == 0, (arch, p, spec, leaf.shape)
