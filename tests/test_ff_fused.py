"""The ff megakernel (up → act → down in one Pallas grid) vs the split
kernel chain vs the einsum oracle: forward, both backward routes, dispatch
from the mlp layer, and the 4-axis tile planner — all in interpret mode."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import factory
from repro.kernels import ops, ref
from repro.kernels.dyad_mm import dyad_ff_fused, plan_ff_tiles
from repro.layers import mlp as mlp_lib

KEY = jax.random.PRNGKey(0)

# (B, n, d_in, d_ff_b, d_out): healthy, odd/prime hidden (exercising
# plan_ff_tiles padding on the j axis), prime-everything, just-past-lane
FF_SHAPES = [
    (16, 4, 32, 64, 32),
    (10, 2, 24, 37, 24),
    (8, 3, 7, 5, 11),
    (12, 2, 129, 130, 129),
]


def _ff_weights(n, d_in, d_ff_b, d_out, dtype=jnp.float32, gated=False):
    def w(i, shape):
        return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)

    ws = {"wu1": w(1, (n, d_ff_b, d_in)), "wu2": w(2, (n, d_ff_b, d_in)),
          "wd1": w(3, (n, d_out, d_ff_b)), "wd2": w(4, (n, d_out, d_ff_b))}
    if gated:
        ws["wg1"] = w(5, (n, d_ff_b, d_in))
        ws["wg2"] = w(6, (n, d_ff_b, d_in))
    return ws


def _close(got, want, tol):
    """allclose with atol scaled to the reference magnitude — ff outputs
    grow with sqrt(d_in * d_ff), so a flat atol misreads bf16 rounding on
    near-zero elements as error."""
    want = np.asarray(want, np.float32)
    scale = max(float(np.max(np.abs(want))), 1.0)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=tol, atol=tol * scale)


def _params(ws):
    p = {"up": {"w1": ws["wu1"], "w2": ws["wu2"]},
         "down": {"w1": ws["wd1"], "w2": ws["wd2"]}}
    if "wg1" in ws:
        p["gate"] = {"w1": ws["wg1"], "w2": ws["wg2"]}
    return p


@pytest.mark.parametrize("act", ["gelu", "relu", "silu", "swiglu"])
@pytest.mark.parametrize("B,n,d_in,d_ff_b,d_out", FF_SHAPES)
def test_megakernel_matches_oracle(act, B, n, d_in, d_ff_b, d_out):
    gated = act == "swiglu"
    ws = _ff_weights(n, d_in, d_ff_b, d_out, gated=gated)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, n * d_in))
    x1, x2 = ref.block_views(x, n, "it")
    want = ref.dyad_ff_ref(x, ws["wu1"], ws["wu2"], ws["wd1"], ws["wd2"],
                           ws.get("wg1"), ws.get("wg2"), act=act)
    z1, z2 = dyad_ff_fused(x1, x2, ws["wu1"], ws["wu2"], ws["wd1"],
                           ws["wd2"], wg1=ws.get("wg1"), wg2=ws.get("wg2"),
                           act=act, interpret=True)
    got = ref.combine(z1, z2, "ot")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 4e-2)])
@pytest.mark.parametrize("act", ["gelu", "swiglu"])
def test_megakernel_dtypes(act, dtype, tol):
    """bf16 activations: the megakernel keeps the hidden in fp32 until the
    down dot's input cast, so it can only be MORE accurate than the split
    path — compare against the fp32 oracle at bf16 tolerance."""
    gated = act == "swiglu"
    ws = _ff_weights(4, 32, 64, 32, gated=gated)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 128)).astype(dtype)
    y = ops.dyad_ff(_params(ws), x, act=act)
    assert y.dtype == dtype
    want = ref.dyad_ff_ref(x.astype(jnp.float32), ws["wu1"], ws["wu2"],
                           ws["wd1"], ws["wd2"], ws.get("wg1"),
                           ws.get("wg2"), act=act)
    _close(y, want, tol)


def test_megakernel_tiling_invariance():
    """Result must not depend on the tile choice (sweeps j and k blocks,
    the two axes the megakernel sequences)."""
    ws = _ff_weights(2, 32, 64, 24)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
    x1, x2 = ref.block_views(x, 2, "it")
    args = (x1, x2, ws["wu1"], ws["wu2"], ws["wd1"], ws["wd2"])
    base = ref.combine(*dyad_ff_fused(*args, act="gelu", interpret=True),
                       "ot")
    for bb, bo, bj, bk in [(4, 8, 8, 8), (16, 24, 64, 32), (8, 12, 16, 16),
                           (16, 24, 32, 8)]:
        out = ref.combine(*dyad_ff_fused(
            *args, act="gelu", block_b=bb, block_o=bo, block_j=bj,
            block_k=bk, interpret=True), "ot")
        # fp32 accumulation ORDER differs per tiling across two chained
        # matmuls — compare at fp32-chain tolerance, not bit-exactness
        _close(out, base, 1e-5)


@pytest.mark.parametrize("act", ["relu", "swiglu"])
def test_fused_vs_split_route(act, monkeypatch):
    """REPRO_KERNEL_FF=split runs the two/three-dispatch kernel chain —
    same numbers as the megakernel route to fp32 tolerance."""
    gated = act == "swiglu"
    ws = _ff_weights(4, 16, 32, 16, gated=gated)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    p = _params(ws)

    monkeypatch.setenv("REPRO_KERNEL_FF", "fused")
    ops._make_dyad_ff.cache_clear()
    y_fused = ops.dyad_ff(p, x, act=act)
    monkeypatch.setenv("REPRO_KERNEL_FF", "split")
    ops._make_dyad_ff.cache_clear()
    y_split = ops.dyad_ff(p, x, act=act)
    ops._make_dyad_ff.cache_clear()
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_split),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("act", ["gelu", "relu", "swiglu"])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_ff_bwd_matches_einsum_oracle(act, dtype, tol):
    """Default backward route (compiled direct-layout XLA off-TPU) vs
    autodiff of the einsum oracle."""
    gated = act == "swiglu"
    ws = _ff_weights(4, 16, 32, 16, gated=gated)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64)).astype(dtype)
    p = _params(ws)
    f_k = lambda p, x: (ops.dyad_ff(p, x, act=act) ** 2).mean()
    f_e = lambda p, x: (ops.dyad_ff(p, x, act=act,
                                    use_kernel_bwd=False) ** 2).mean()
    gk = jax.grad(f_k, argnums=(0, 1))(p, x)
    ge = jax.grad(f_e, argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(ge)):
        _close(a, b, tol)


@pytest.mark.parametrize("act", ["gelu", "swiglu"])
def test_ff_pallas_bwd_matches_oracle(act, monkeypatch):
    """REPRO_KERNEL_BWD=pallas forces the rematerialize + dgrad/wgrad
    kernel composition off-TPU (interpret mode) — still oracle-exact."""
    monkeypatch.setenv("REPRO_KERNEL_BWD", "pallas")
    ops._make_dyad_ff.cache_clear()
    gated = act == "swiglu"
    ws = _ff_weights(2, 24, 37, 24, gated=gated)     # odd hidden: j padding
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 48))
    p = _params(ws)
    f_k = lambda p, x: (ops.dyad_ff(p, x, act=act) ** 2).mean()
    f_e = lambda p, x: (ops.dyad_ff(p, x, act=act,
                                    use_kernel_bwd=False) ** 2).mean()
    gk = jax.grad(f_k, argnums=(0, 1))(p, x)
    ge = jax.grad(f_e, argnums=(0, 1))(p, x)
    ops._make_dyad_ff.cache_clear()
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(ge)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_ff_bwd_mixed_weight_dtypes():
    """Weight cotangents come back in each tensor's OWN dtype."""
    ws = _ff_weights(4, 16, 32, 16)
    ws["wd2"] = ws["wd2"].astype(jnp.bfloat16)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    g = jax.grad(lambda p, x: (ops.dyad_ff(p, x, act="gelu") ** 2).mean())(
        _params(ws), x)
    assert g["down"]["w1"].dtype == jnp.float32
    assert g["down"]["w2"].dtype == jnp.bfloat16


def test_grad_through_jitted_ff_block():
    """End-to-end jax.grad through a jitted loss over the fused ff op must
    match the plain-jnp mlp path (fuse_mlp einsum fusion as reference)."""
    lc_k = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                             use_kernel=True, fuse_ff_kernel=True)
    lc_e = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                             fuse_mlp=True)
    p = mlp_lib.init_mlp(KEY, 32, 64, lc_k, act="swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32))

    def loss(p, x, lc):
        return (mlp_lib.apply_mlp(p, x, lc, act="swiglu") ** 2).mean()

    gk = jax.jit(jax.grad(lambda p, x: loss(p, x, lc_k)))(p, x)
    ge = jax.jit(jax.grad(lambda p, x: loss(p, x, lc_e)))(p, x)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(ge)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# -- dispatch from the mlp layer ----------------------------------------------


@pytest.mark.parametrize("act", ["gelu", "swiglu"])
def test_apply_mlp_dispatches_megakernel(act):
    """fuse_ff_kernel config routes apply_mlp through ops.dyad_ff — the
    MIXED-VARIANT dataflow (up=IT, down=OT), i.e. the same function the
    fuse_mlp einsum fusion computes, and the same explicit
    IT-up/OT-down composition from core.dyad."""
    from repro.core import dyad

    lc = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                           use_kernel=True, fuse_ff_kernel=True)
    p = mlp_lib.init_mlp(KEY, 32, 64, lc, act=act)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32))
    y = mlp_lib.apply_mlp(p, x, lc, act=act)
    y_fmlp = mlp_lib.apply_mlp(p, x, lc.replace(fuse_mlp=True,
                                                fuse_ff_kernel=False,
                                                use_kernel=False), act=act)
    spec_it = dyad.DyadSpec(n_dyad=4, variant="it")
    spec_ot = dyad.DyadSpec(n_dyad=4, variant="ot")
    if act == "swiglu":
        h = (jax.nn.silu(dyad.apply(p["gate"], x, spec_it))
             * dyad.apply(p["up"], x, spec_it))
    else:
        h = jax.nn.gelu(dyad.apply(p["up"], x, spec_it))
    y_mix = dyad.apply(p["down"], h, spec_ot)
    _close(y, y_fmlp, 2e-4)
    _close(y, y_mix, 2e-4)


def test_apply_mlp_megakernel_requires_bias_free():
    """Biased ff params must fall back to the unfused path (the megakernel
    has no bias epilogue) — numbers still match the plain path."""
    lc = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                           use_kernel=True, fuse_ff_kernel=True)
    p = mlp_lib.init_mlp(KEY, 32, 64, lc, act="gelu", bias=True)
    assert not mlp_lib._ff_kernel_ready(p, lc, "gelu")
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    y = mlp_lib.apply_mlp(p, x, lc, act="gelu")
    y_plain = mlp_lib.apply_mlp(p, x, factory.LinearCfg(
        impl="dyad", n_dyad=4, variant="it"), act="gelu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_plain),
                               rtol=2e-4, atol=2e-4)


def test_megakernel_dispatch_under_sharding_ctx():
    """PR 8 contract: an active sharding context no longer demotes the
    megakernel — the shard_map TP wrappers (kernels/tp.py) keep the kernel
    route, and REPRO_KERNEL_TP=off is the explicit hatch back to the
    einsum fallback (route counters record the choice either way)."""
    from jax.sharding import Mesh
    from repro import obs
    from repro.sharding import ctx as shard_ctx

    lc = factory.LinearCfg(impl="dyad", n_dyad=4, variant="it",
                           use_kernel=True, fuse_ff_kernel=True)
    p = mlp_lib.init_mlp(KEY, 32, 64, lc, act="gelu")
    assert mlp_lib._ff_kernel_ready(p, lc, "gelu")
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with shard_ctx.activation_sharding(mesh, dp=("data",), model="model"):
        obs.reset_route_counts()
        assert mlp_lib._ff_kernel_ready(p, lc, "gelu")
        assert obs.routes_snapshot() == {"ff_tp:tp_fused": 1}
        os.environ["REPRO_KERNEL_TP"] = "off"
        try:
            assert not mlp_lib._ff_kernel_ready(p, lc, "gelu")
            assert obs.routes_snapshot()["ff_tp:tp_fallback"] == 1
        finally:
            del os.environ["REPRO_KERNEL_TP"]
    assert mlp_lib._ff_kernel_ready(p, lc, "gelu")


def test_linear_cfg_spec_token():
    from repro import configs

    lc = configs.linear_cfg("dyad_it_4_kernel_ffused")
    assert lc.use_kernel and lc.fuse_ff_kernel
    assert not configs.linear_cfg("dyad_it_4_kernel").fuse_ff_kernel


# -- tile planning ------------------------------------------------------------


def test_plan_ff_tiles_never_degenerate():
    plan = plan_ff_tiles(521, 1031, 769, 1031, 256, 256, 512, 512)
    assert plan.bB >= 8 and plan.bO >= 128 and plan.bJ >= 128
    assert plan.bK >= 128
    for dim, tile in [(plan.padded_b, plan.bB), (plan.padded_o, plan.bO),
                      (plan.padded_j, plan.bJ), (plan.padded_k, plan.bK)]:
        assert dim % tile == 0
    assert plan.grid_steps <= 128
    # healthy dims are untouched
    plan = plan_ff_tiles(64, 192, 768, 192, 256, 256, 512, 512)
    assert (plan.padded_b, plan.padded_o, plan.padded_j,
            plan.padded_k) == (64, 192, 768, 192)
    assert (plan.bB, plan.bO, plan.bJ, plan.bK) == (64, 192, 384, 192)


def test_megakernel_validates_gate_args():
    ws = _ff_weights(2, 16, 32, 16)
    x = jax.random.normal(KEY, (4, 32))
    x1, x2 = ref.block_views(x, 2, "it")
    with pytest.raises(ValueError, match="swiglu"):
        dyad_ff_fused(x1, x2, ws["wu1"], ws["wu2"], ws["wd1"], ws["wd2"],
                      act="swiglu", interpret=True)
    # HALF a gate is as wrong as none
    with pytest.raises(ValueError, match="swiglu"):
        dyad_ff_fused(x1, x2, ws["wu1"], ws["wu2"], ws["wd1"], ws["wd2"],
                      wg1=ws["wu1"], act="swiglu", interpret=True)
