"""Batched serving example: prefill a batch of prompts, decode new tokens,
report tokens/s — the interactive twin of the decode_32k dry-run cells.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3_0_6b
"""
import argparse
import time

import jax

from repro import configs
from repro.models import model
from repro.serve import Engine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3_0_6b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=12)
ap.add_argument("--new-tokens", type=int, default=20)
ap.add_argument("--temperature", type=float, default=0.8)
args = ap.parse_args()

cfg = configs.get(args.arch, smoke=True)
key = jax.random.PRNGKey(0)
params = model.init_params(cfg, key)
print(f"serving {cfg.name} ({model.param_count(params):,} params, "
      f"linear={cfg.linear.impl})")

engine = Engine(cfg, params, max_len=args.prompt_len + args.new_tokens)
prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                             cfg.vocab_size)
frames = None
if cfg.family == "encdec":
    frames = jax.random.normal(key, (args.batch, cfg.n_frames,
                                     cfg.frontend_dim))

t0 = time.perf_counter()
out = engine.generate(prompts, args.new_tokens,
                      temperature=args.temperature, key=key, frames=frames)
dt = time.perf_counter() - t0
print(f"generated {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s "
      f"({out.size / dt:.1f} tok/s, greedy-deterministic cache decode)")
print(out)
