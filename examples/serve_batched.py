"""Batched serving example: single-pass prefill + scan-compiled decode, then
the same prompts through the continuous-batching engine.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3_0_6b
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import model
from repro.serve import ContinuousBatchingEngine, Engine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3_0_6b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=12)
ap.add_argument("--new-tokens", type=int, default=20)
ap.add_argument("--temperature", type=float, default=0.8)
ap.add_argument("--slots", type=int, default=2)
args = ap.parse_args()

cfg = configs.get(args.arch, smoke=True)
key = jax.random.PRNGKey(0)
params = model.init_params(cfg, key)
print(f"serving {cfg.name} ({model.param_count(params):,} params, "
      f"linear={cfg.linear.impl})")

# --- homogeneous batch: one jitted prefill + one jitted scan decode ---------
engine = Engine(cfg, params, max_len=args.prompt_len + args.new_tokens)
prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                             cfg.vocab_size)
frames = None
if cfg.family == "encdec":
    frames = jax.random.normal(key, (args.batch, cfg.n_frames,
                                     cfg.frontend_dim))

t0 = time.perf_counter()
out = engine.generate(prompts, args.new_tokens,
                      temperature=args.temperature, key=key, frames=frames)
dt = time.perf_counter() - t0
print(f"generated {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s "
      f"({out.size / dt:.1f} tok/s, scan-compiled cache decode)")
print(out)

# --- continuous batching: heterogeneous requests over few slots -------------
if cfg.family not in ("encdec", "vlm"):
    cbe = ContinuousBatchingEngine(
        cfg, params, n_slots=args.slots,
        max_len=args.prompt_len + args.new_tokens)
    lengths = [max(1, args.prompt_len - i) for i in range(args.batch)]
    reqs = [np.asarray(prompts[i, :lengths[i]]) for i in range(args.batch)]
    t0 = time.perf_counter()
    uids = [cbe.submit(r, args.new_tokens) for r in reqs]
    results = cbe.run()
    dt = time.perf_counter() - t0
    total = sum(len(results[u]) for u in uids)
    print(f"continuous: {len(reqs)} variable-length requests over "
          f"{args.slots} slots -> {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s)")
    for u in uids:
        print(f"  req {u}: {results[u][:10]}")
