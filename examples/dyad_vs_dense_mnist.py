"""The paper's vision probe (§3.4.5) as a runnable example: an MLP classifier
with DENSE vs DYAD-IT linear layers on the synthetic-clusters task (offline
MNIST stand-in), run on CPU exactly like the paper's Macbook experiment.

    PYTHONPATH=src python examples/dyad_vs_dense_mnist.py
"""
from benchmarks import bench_mnist

print("name,us_per_call,derived")
bench_mnist.run()
