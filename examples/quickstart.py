"""Quickstart: DYAD as a drop-in replacement for a dense linear layer.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import dyad, linear

key = jax.random.PRNGKey(0)
f_in, f_out, batch = 256, 512, 32

# --- a dense layer and its DYAD-IT replacement -----------------------------
p_dense = linear.init(key, f_in, f_out)
spec = dyad.DyadSpec(n_dyad=4, variant="it")
p_dyad = dyad.init(key, f_in, f_out, spec)

x = jax.random.normal(key, (batch, f_in))
y_dense = linear.apply(p_dense, x)
y_dyad = dyad.apply(p_dyad, x, spec)
print(f"dense out {y_dense.shape}, dyad out {y_dyad.shape}")

# --- the paper's accounting -------------------------------------------------
print(f"dense params: {linear.param_count(f_in, f_out):,}")
print(f"dyad  params: {dyad.param_count(f_in, f_out, 4):,} "
      f"({4 / 2:.0f}x fewer weights)")
print(f"dense flops/batch: {linear.flops(batch, f_in, f_out):,}")
print(f"dyad  flops/batch: {dyad.flops(batch, f_in, f_out, 4):,}")

# --- exactness: the 3-D computation == the structured matrix ---------------
W = dyad.to_dense(p_dyad, spec)
err = jnp.abs(y_dyad - (x @ W.T + p_dyad["b"])).max()
print(f"max |dyad_apply - structured_matrix @ x| = {err:.2e}")

# --- the fused Pallas kernel path (interpret mode on CPU) ------------------
y_kernel = dyad.apply(p_dyad, x, dyad.DyadSpec(n_dyad=4, variant="it",
                                               use_kernel=True))
print(f"max |kernel - reference| = {jnp.abs(y_kernel - y_dyad).max():.2e}")

# --- gradient flow ----------------------------------------------------------
g = jax.grad(lambda p: (dyad.apply(p, x, spec) ** 2).sum())(p_dyad)
print(f"grad norms: w1={jnp.linalg.norm(g['w1']):.3f} "
      f"w2={jnp.linalg.norm(g['w2']):.3f}")
