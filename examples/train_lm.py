"""End-to-end training driver: pretrain an OPT-125m-family LM with DYAD ff
layers next to its DENSE twin (the paper's §3 experiment, self-contained).

Default preset is CPU-sized so the script finishes in minutes; pass --full to
train the real 125M-parameter config for --steps steps (the same driver a
TPU pod would run via repro.launch.train).

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --full --steps 300   # 125M
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.data import SyntheticLM
from repro.models import model
from repro.optim import AdamW, schedule
from repro.train import Trainer, init_train_state, make_train_step


def pretrain(arch_kwargs, linear_spec, steps, seq_len, batch, label):
    cfg = configs.get("opt125m", linear=configs.linear_cfg(linear_spec),
                      **arch_kwargs)
    opt = AdamW(lr=schedule.warmup_cosine(3e-3, steps // 10 + 1, steps))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len,
                       global_batch=batch)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    n_params = model.param_count(state["params"])
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
    trainer = Trainer(step, state, data, log_every=max(steps // 6, 1),
                      log_fn=lambda m: print(f"  [{label}] {m}"))
    _, metrics = trainer.run(steps)
    return float(metrics["loss"]), n_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true",
                    help="real OPT-125m config (slow on CPU)")
    args = ap.parse_args()

    if args.full:
        kw, seq, batch = {}, 512, 8
    else:
        kw = dict(n_layers=2, d_model=128, vocab_size=512, n_heads=4,
                  n_kv_heads=4, head_dim=32, d_ff=512, max_position=256,
                  iota_embed=False)
        seq, batch = 64, 16

    results = {}
    for spec in ("dense", "dyad_it_4"):
        print(f"== pretraining {spec} ==")
        loss, n = pretrain(kw, spec, args.steps, seq, batch, spec)
        results[spec] = (loss, n)
        print(f"  final loss {loss:.4f}  params {n:,}")

    d_loss, d_n = results["dense"]
    y_loss, y_n = results["dyad_it_4"]
    vocab = 512 if not args.full else 50272
    floor = float(np.log(vocab))
    rel = (floor - y_loss) / max(floor - d_loss, 1e-9)
    print(f"\nDYAD/DENSE learning-gain ratio: {rel:.3f} "
          f"(paper bar: >= 0.90) — params {y_n:,} vs {d_n:,} "
          f"({d_n / y_n:.2f}x reduction)")


if __name__ == "__main__":
    main()
